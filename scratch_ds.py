import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, collections
import jax
from repro.launch.dryrun import build_lowered
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_cost

mesh = make_production_mesh()
(lowered, cfg, shape), _ = build_lowered("deepseek-v2-236b", "train_4k", mesh, "opt")
txt = lowered.compile().as_text()
comps, shapes = hlo_cost._parse(txt)
rows = collections.defaultdict(float)
def cost(cn, in_fusion, mult):
    for op in comps.get(cn, []):
        oc = op.opcode
        trip = 1.0
        called = []
        for m in hlo_cost._CALLED_RE.finditer(op.rest):
            if m.group(1): called.append(m.group(1))
            else: called += re.findall(r"%([\w\.\-]+)", m.group(2))
        if oc == "while":
            tm = hlo_cost._TRIP_RE.search(op.rest)
            trip = float(tm.group(1)) if tm else 1.0
        child_fusion = in_fusion or oc == "fusion"
        for ch in called:
            cost(ch, child_fusion, mult*trip)
        if in_fusion: continue
        if oc == "fusion" and called:
            b = hlo_cost._fusion_bytes(comps.get(called[0], []), op.result)
        elif oc in hlo_cost._FREE_OPS or oc == "while":
            continue
        else:
            opnds = op.operands()
            b = hlo_cost._shape_bytes(op.result) + sum(hlo_cost._shape_bytes(shapes.get(o,"")) for o in opnds)
        rows[(oc, op.result[:44])] += mult * b
entry = re.search(r"^ENTRY\s+%([\w\.\-]+)", txt, re.M).group(1)
cost(entry, False, 1.0)
for k, v in sorted(rows.items(), key=lambda kv: -kv[1])[:14]:
    print(f"{v/1e12:8.2f}TB {k[0]:16s} {k[1]}")
print("total", sum(rows.values())/1e12)
