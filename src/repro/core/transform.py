"""Composable gradient-transform algebra and the chain -> engine compiler.

The paper's SNGM (Algorithm 1) is structurally a pipeline — normalize ->
momentum -> scale-by-schedule — and so are all its large-batch baselines.
This module makes that pipeline a first-class object: a
``GradientTransform`` is an optax-style ``(init, update)`` pair, and
``chain()`` composes them left to right::

    tx = chain(add_decayed_weights(1e-4),
               normalize_by_global_norm(),
               trace(beta=0.9),
               scale_by_schedule(poly_power(1.6, 1000)))
    opt = compile_chain(tx, fused="multi_tensor")   # an Optimizer

Every norm-taking transform uses the engine's canonical ``leaf_sumsq``
chunked reduction, so numerics are path-independent by construction.

Execution is three-tier (the segment compiler):

  * ``match_chain`` recognizes whole chains shaped like the engine's
    fused kinds (``sngm_global``, ``sngm_per_tensor``, ``msgd``,
    ``lars``, ``lamb``), each optionally prefixed by
    ``clip_by_global_norm`` (compiled as a two-round norm pass) and —
    for the momentum kinds — with ``trace(nesterov=True)`` fused into
    the update kernel.  A whole match compiles to the kind-level
    optimizer in ``core.optim`` — the bit-exact jnp reference path, the
    O(1)-launch Pallas engine, and the ``FlatOptState`` resident fast
    path all stay available, exactly as before the chain API existed.
  * Everything else goes through ``plan_chain``, which builds a
    ``SegmentPlan``: the LONGEST suffix of the chain matching a fused
    kind becomes one engine-lowered segment (with a mid-chain clip
    folded into its coefficient round and a TRAILING clip compiled as a
    deferred-apply third pass), ``ema_params`` stages anywhere become
    resident ``FlatOptState.e_flats`` slots (zero launches), and the
    remaining verifiably-stateless prefix stages interleave as plain
    jnp nodes between input and segment — novel stages no longer
    de-fuse their neighbors.  ``compile_chain`` hands fusible plans to
    ``core.optim._plan_optimizer`` when ``fused="multi_tensor"``.
  * A chain with no fusible tail falls back to the **interpreter**: the
    transforms run leaf-wise in pure jnp, state is a ``ChainOptState``
    (a pytree, so it jits / shards / checkpoints like any other), and the
    final update is applied as ``w <- (w - u).astype(w.dtype)``.  If a
    fused mode was requested for such a chain a ``UserWarning`` names
    the exact stage that blocked fusion and the degenerate plan.

Both tiers consume/produce the unified ``TrainState``
(``core.optim``) through ``Optimizer.init_state`` / ``step_state``:
interpreter-run chains carry ``TrainState(params, ChainOptState)``
(params always materialized — a ``ChainOptState`` owns no parameter
bytes), while matched chains on the resident engine path carry
``TrainState(None, FlatOptState)`` with the flat buffers as the single
parameter owner.  Either form is donation-safe: jit the train step with
``donate_argnums`` on the state and XLA aliases params, momentum, and
Adam moments in place across steps.

Weight-decay coupling is positional, not a flag: ``add_decayed_weights``
placed *before* a normalize/trust transform is coupled decay (the decayed
gradient is what gets normalized — the paper's setup), placed *after* it
is decoupled decay (pure shrinkage, AdamW-style).

Stats: transforms report into a dict merged left to right (later
transforms win).  ``normalize_*`` / ``clip_by_global_norm`` /
``trust_ratio`` report ``grad_norm`` of their input; ``trace`` reports
``update_norm`` of the momentum; ``scale_by_schedule`` reports ``lr``
and the pre-scaling ``update_norm`` — so every chain built by the
``core.optim`` builders reports the same three keys the monolithic
optimizers always did.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.multi_tensor import global_norm, leaf_sumsq
from repro.core.schedules import Schedule

PyTree = Any
Stats = Dict[str, jnp.ndarray]
InitFn = Callable[[PyTree], Any]
UpdateFn = Callable[[PyTree, Any, PyTree], Tuple[PyTree, Any, Stats]]


@dataclasses.dataclass(frozen=True)
class GradientTransform:
    """One stage of an optimizer pipeline.

    ``update(updates, state, params) -> (updates, new_state, stats)``
    maps an update pytree (initially the gradients) to a transformed
    update pytree.  ``meta`` carries the transform's static parameters as
    ``(key, value)`` pairs for ``compile_chain``'s pattern matcher;
    ``parts`` is non-empty only for ``chain()`` results.
    """
    name: str
    init: InitFn
    update: UpdateFn
    meta: Tuple[Tuple[str, Any], ...] = ()
    parts: Tuple["GradientTransform", ...] = ()

    def get(self, key: str, default=None):
        return dict(self.meta).get(key, default)


# ---------------------------------------------------------------------------
# transform states (NamedTuples => automatically pytrees: they jit, shard,
# and checkpoint like any parameter tree)
# ---------------------------------------------------------------------------

class EmptyState(NamedTuple):
    """Stateless transform marker."""


class TraceState(NamedTuple):
    momentum: PyTree               # f32, mirrors params


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray             # scalar int32


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray             # scalar int32
    m: PyTree                      # f32 first moment
    v: PyTree                      # f32 second moment


class EmaParamsState(NamedTuple):
    ema: PyTree                    # f32 shadow of the params


class ChainOptState(NamedTuple):
    """Interpreter-path optimizer state: step counter + one sub-state per
    chained transform (in chain order)."""
    step: jnp.ndarray
    inner: Tuple[Any, ...]


def _zeros_f32_like(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def _stateless(name: str, update_fn, meta=()) -> GradientTransform:
    def init(params):
        del params
        return EmptyState()

    def update(updates, state, params):
        out, stats = update_fn(updates, params)
        return out, state, stats

    return GradientTransform(name, init, update, tuple(meta))


# ---------------------------------------------------------------------------
# the transforms
# ---------------------------------------------------------------------------

def add_decayed_weights(weight_decay: float = 0.0) -> GradientTransform:
    """u <- u + wd * w, leaf-wise in the incoming dtype.

    Coupled vs decoupled is positional (module docstring): before a
    normalize/trust transform this is the paper's coupled decay (§5);
    after ``trace``/``scale_by_adam`` it is decoupled shrinkage."""
    wd = float(weight_decay)

    def fn(updates, params):
        if wd == 0.0:
            return updates, {}
        return jax.tree.map(lambda g, w: g + wd * w, updates, params), {}

    return _stateless("add_decayed_weights", fn,
                      meta=(("weight_decay", wd),))


def normalize_by_global_norm(eps: float = 1e-12) -> GradientTransform:
    """u <- u / (||u||_2 + eps) over the WHOLE tree — Algorithm 1's
    normalization (Lemma 4: the traced momentum stays <= 1/(1-beta))."""
    def fn(updates, params):
        del params
        gnorm = global_norm(updates)
        inv = 1.0 / (gnorm + eps)
        out = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, updates)
        return out, {"grad_norm": gnorm}

    return _stateless("normalize_by_global_norm", fn, meta=(("eps", eps),))


def normalize_per_tensor(eps: float = 1e-12) -> GradientTransform:
    """Block-normalized SNGM variant: each leaf divided by its own norm
    (LARS-flavoured; Lemma 4 then holds per tensor).  Reports the global
    norm, matching the monolithic optimizer's stats."""
    def fn(updates, params):
        del params
        gnorm = global_norm(updates)

        def upd(g):
            n = jnp.sqrt(leaf_sumsq(g))
            return g.astype(jnp.float32) * (1.0 / (n + eps))

        return jax.tree.map(upd, updates), {"grad_norm": gnorm}

    return _stateless("normalize_per_tensor", fn, meta=(("eps", eps),))


def clip_by_global_norm(max_norm: float) -> GradientTransform:
    """u <- u * min(1, max_norm / ||u||) — the standard large-batch guard
    against loss spikes (Keskar et al. 2017 pathologies)."""
    max_norm = float(max_norm)

    def fn(updates, params):
        del params
        gnorm = global_norm(updates)
        scale = max_norm / jnp.maximum(gnorm, max_norm)   # <= 1, no eps
        out = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                      ).astype(g.dtype), updates)
        return out, {"grad_norm": gnorm}

    return _stateless("clip_by_global_norm", fn, meta=(("max_norm", max_norm),))


def trace(beta: float = 0.9, nesterov: bool = False) -> GradientTransform:
    """Polyak momentum (f32 accumulator): m <- beta * m + u; output m, or
    beta * m + u for ``nesterov=True``."""
    beta = float(beta)

    def init(params):
        return TraceState(momentum=_zeros_f32_like(params))

    def update(updates, state, params):
        del params
        new_m = jax.tree.map(lambda m, u: beta * m + u.astype(jnp.float32),
                             state.momentum, updates)
        out = (jax.tree.map(lambda m, u: beta * m + u.astype(jnp.float32),
                            new_m, updates) if nesterov else new_m)
        return out, TraceState(new_m), {"update_norm": global_norm(out)}

    return GradientTransform("trace", init, update,
                             (("beta", beta), ("nesterov", bool(nesterov))))


def trust_ratio(trust: float = 0.001, weight_decay: float = 0.0,
                eps: float = 1e-12) -> GradientTransform:
    """LARS layer-wise adaptive scaling (You et al. 2017), matching the
    pytorch-lars implementation the paper benchmarked against::

        local = trust * ||w|| / (||g|| + wd * ||w|| + eps)    per tensor
        u <- local * (g + wd * w)        (local = 1 where ||w|| == 0)

    Weight decay is entangled with the ratio here (it appears in both the
    denominator and the decayed gradient), which is why LARS chains do
    not carry a separate ``add_decayed_weights`` stage."""
    trust, wd = float(trust), float(weight_decay)

    def fn(updates, params):
        def upd(g, w):
            g32 = g.astype(jnp.float32)
            wn = jnp.sqrt(leaf_sumsq(w))
            gn = jnp.sqrt(leaf_sumsq(g32))
            local = trust * wn / (gn + wd * wn + eps)
            local = jnp.where(wn > 0, local, 1.0)
            return local * (g32 + wd * w)

        out = jax.tree.map(upd, updates, params)
        return out, {"grad_norm": global_norm(updates)}

    return _stateless("trust_ratio", fn,
                      (("trust", trust), ("weight_decay", wd), ("eps", eps)))


def scale_by_trust_ratio(eps: float = 0.0) -> GradientTransform:
    """LAMB-style per-tensor rescale: u <- (||w|| / ||u||) * u, with the
    ratio defaulting to 1 where either norm is zero (You et al. 2020)."""
    eps = float(eps)

    def fn(updates, params):
        def upd(u, w):
            wn = jnp.sqrt(leaf_sumsq(w))
            un = jnp.sqrt(leaf_sumsq(u))
            ratio = jnp.where((wn > 0) & (un > 0), wn / (un + eps), 1.0)
            return ratio * u.astype(jnp.float32)

        return jax.tree.map(upd, updates, params), {}

    return _stateless("scale_by_trust_ratio", fn, (("eps", eps),))


def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-6) -> GradientTransform:
    """Bias-corrected Adam direction (f32 moments): u <- m_hat /
    (sqrt(v_hat) + eps).  Gradients are cast to f32 before both moments."""
    b1, b2, eps = float(b1), float(b2), float(eps)

    def init(params):
        return ScaleByAdamState(count=jnp.zeros((), jnp.int32),
                                m=_zeros_f32_like(params),
                                v=_zeros_f32_like(params))

    def update(updates, state, params):
        del params
        t = state.count.astype(jnp.float32) + 1.0
        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.m, updates)
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.v, updates)
        out = jax.tree.map(
            lambda m, v: (m / (1 - b1 ** t)) / (jnp.sqrt(v / (1 - b2 ** t))
                                                + eps),
            new_m, new_v)
        return out, ScaleByAdamState(state.count + 1, new_m, new_v), {}

    return GradientTransform("scale_by_adam", init, update,
                             (("b1", b1), ("b2", b2), ("eps", eps)))


def scale_by_schedule(schedule: Schedule) -> GradientTransform:
    """u <- lr_t * u with lr_t from the schedule at the transform's own
    step count.  Reports ``lr`` and the PRE-scaling ``update_norm`` (the
    norm of what lr multiplies — for the canonical chains that is the
    momentum, matching the monolithic optimizers' stats)."""
    def init(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros((), jnp.int32))

    def update(updates, state, params):
        del params
        lr = schedule(state.count)
        out = jax.tree.map(lambda u: lr * u, updates)
        return out, ScaleByScheduleState(state.count + 1), \
            {"lr": lr, "update_norm": global_norm(updates)}

    return GradientTransform("scale_by_schedule", init, update,
                             (("schedule", schedule),))


def ema_params(decay: float = 0.999) -> GradientTransform:
    """Polyak-averaged shadow parameters for evaluation: maintains
    ``ema <- decay * ema + (1 - decay) * w`` (f32) and passes updates
    through untouched.  Read the shadow tree out of the chain state
    (``ChainOptState.inner[i].ema``)."""
    decay = float(decay)

    def init(params):
        # copy=True: astype on an f32 leaf returns the SAME buffer, and a
        # shadow aliasing the live params would donate one buffer twice
        # under the donated TrainState step
        return EmaParamsState(
            ema=jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params))

    def update(updates, state, params):
        new_ema = jax.tree.map(
            lambda e, w: decay * e + (1 - decay) * w.astype(jnp.float32),
            state.ema, params)
        return updates, EmaParamsState(new_ema), {}

    return GradientTransform("ema_params", init, update, (("decay", decay),))


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------

def chain(*transforms: GradientTransform) -> GradientTransform:
    """Compose transforms left to right.  Nested chains are flattened, so
    the compiler always sees the primitive sequence."""
    parts: Tuple[GradientTransform, ...] = ()
    for t in transforms:
        parts += t.parts if t.parts else (t,)

    def init(params):
        return tuple(p.init(params) for p in parts)

    def update(updates, state, params):
        stats: Stats = {}
        new_state = []
        for p, s in zip(parts, state):
            updates, ns, st = p.update(updates, s, params)
            stats.update(st)
            new_state.append(ns)
        return updates, tuple(new_state), stats

    return GradientTransform("chain", init, update, parts=parts)


# ---------------------------------------------------------------------------
# the chain -> multi-tensor compiler
# ---------------------------------------------------------------------------

# Chain shapes the compiler recognizes, mapped to the engine's fused kinds.
# '?'-suffixed stages are optional: ``add_decayed_weights`` absent == wd 0,
# ``clip_by_global_norm`` absent == no clip round.  A nesterov trace fuses
# into the momentum kinds' update kernel; an adam eps <= 0 (pad invariance)
# or any other deviation falls through to the segment planner.
_PATTERNS = (
    ("sngm_global",
     ("clip_by_global_norm?", "add_decayed_weights?",
      "normalize_by_global_norm", "trace", "scale_by_schedule")),
    ("sngm_per_tensor",
     ("clip_by_global_norm?", "add_decayed_weights?", "normalize_per_tensor",
      "trace", "scale_by_schedule")),
    ("msgd",
     ("clip_by_global_norm?", "add_decayed_weights?", "trace",
      "scale_by_schedule")),
    ("lars",
     ("clip_by_global_norm?", "trust_ratio", "scale_by_schedule", "trace")),
    ("lamb",
     ("clip_by_global_norm?", "scale_by_adam", "add_decayed_weights?",
      "scale_by_trust_ratio", "scale_by_schedule")),
)


def _try_match(parts, pattern):
    """Return {name: transform} for a full match of ``pattern`` (with
    optional '?'-suffixed stages) against the chain parts, else None."""
    got: Dict[str, GradientTransform] = {}
    i = 0
    for want in pattern:
        optional = want.endswith("?")
        want = want.rstrip("?")
        if i < len(parts) and parts[i].name == want:
            got[want] = parts[i]
            i += 1
        elif not optional:
            return None
    return got if i == len(parts) else None


def _kind_params(kind: str, got: Dict[str, GradientTransform]
                 ) -> Dict[str, Any]:
    """Extract the kind-level optimizer parameters from a pattern match."""
    kp = {"schedule": got["scale_by_schedule"].get("schedule"),
          "clip": None}
    if "clip_by_global_norm" in got:
        kp["clip"] = got["clip_by_global_norm"].get("max_norm")
    wd = (got["add_decayed_weights"].get("weight_decay")
          if "add_decayed_weights" in got else 0.0)
    if kind == "lamb":
        adam = got["scale_by_adam"]
        kp.update(b1=adam.get("b1"), b2=adam.get("b2"),
                  eps=adam.get("eps"), weight_decay=wd,
                  trust_eps=got["scale_by_trust_ratio"].get("eps"))
        return kp
    kp.update(beta=got["trace"].get("beta"),
              nesterov=bool(got["trace"].get("nesterov")),
              weight_decay=wd, eps=1e-12, trust=0.001)
    for src in ("normalize_by_global_norm", "normalize_per_tensor"):
        if src in got:
            kp["eps"] = got[src].get("eps")
    if "trust_ratio" in got:
        tr = got["trust_ratio"]
        kp.update(trust=tr.get("trust"),
                  weight_decay=tr.get("weight_decay"),
                  eps=tr.get("eps"))
    return kp


def match_chain(tx: GradientTransform) -> Optional[Tuple[str, Dict[str, Any]]]:
    """Pattern-match a WHOLE chain onto a fused kind.  Returns ``(kind,
    params)``: for the momentum kinds params are ``{schedule, beta,
    nesterov, weight_decay, eps, trust, clip}``, for ``lamb`` they are
    ``{schedule, b1, b2, eps, weight_decay, trust_eps, clip}``.  Returns
    None when the chain is not one of the five whole-chain shapes —
    callers should then consult ``plan_chain``, which fuses the longest
    canonical SUFFIX instead of requiring a whole match (migration note:
    before the segment compiler, ``match_chain is None`` meant
    "interpreter-only"; now it only means "not a whole-chain kind", and
    a ``trace(nesterov=True)`` momentum chain — previously rejected —
    matches with ``params["nesterov"] = True``)."""
    parts = tx.parts if tx.parts else (tx,)
    for kind, pattern in _PATTERNS:
        got = _try_match(parts, pattern)
        if got is None:
            continue
        if kind == "lamb" and got["scale_by_adam"].get("eps") <= 0.0:
            return None   # engine pad invariance needs eps > 0
        return kind, _kind_params(kind, got)
    return None


# ---------------------------------------------------------------------------
# the segment planner: longest canonical suffix -> one fused engine segment
# ---------------------------------------------------------------------------

# transforms the planner may leave in a plan's jnp prefix without probing:
# stateless by construction, with interpreter-exact leafwise updates
_STATELESS_NAMES = frozenset((
    "add_decayed_weights", "normalize_by_global_norm", "normalize_per_tensor",
    "clip_by_global_norm", "trust_ratio", "scale_by_trust_ratio"))

# per-stage state tags recorded in FlatOptState's ("chain", slots) form
_SLOT_TAGS = {"trace": "trace", "scale_by_schedule": "sched",
              "scale_by_adam": "adam", "ema_params": "ema"}

# kinds whose apply pass carries the schedule lr in the shared scalar ``c``
# — the only ones a TRAILING clip can fold into (the deferred-apply pass 3
# rescales c*u; lars bakes lr into its per-chunk coefficients and lamb into
# its scale_apply, so a suffix clip would double-count it)
_SUFFIX_CLIP_KINDS = ("sngm_global", "sngm_per_tensor", "msgd")


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """One node of a ``SegmentPlan``.

    ``op`` is ``"jnp"`` (a stateless prefix stage run leafwise,
    interpreter-exact, zero launches), ``"ema"`` (an ``ema_params``
    stage compiled to a resident ``FlatOptState.e_flats`` slot, zero
    launches), or ``"fused"`` (the engine-lowered tail segment).
    ``stages`` are the chain indices the node covers; ``launches`` is
    the node's engine launch count per dtype bucket per step."""
    op: str
    stages: Tuple[int, ...]
    label: str
    launches: int
    transform: Optional[GradientTransform] = None   # op == "jnp"
    kind: Optional[str] = None                      # op == "fused"
    kwargs: Tuple[Tuple[str, Any], ...] = ()        # op in ("fused", "ema")

    def arg(self, key: str, default=None):
        return dict(self.kwargs).get(key, default)


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """The segment compiler's IR: what ``compile_chain`` executes and what
    launch accounting / tests / benchmarks inspect.

    ``nodes`` run in chain order; ``slots`` tags every ORIGINAL chain
    stage's state ("empty"|"trace"|"sched"|"adam"|"ema") — the
    ``FlatOptState`` form aux a plan-compiled optimizer carries, which is
    what makes ``to_pytree``/``from_pytree`` lossless for plan states.
    ``kind`` is the fused tail's engine kind, or None when the chain has
    no fusible suffix (then ``blocker`` names the (index, stage-name)
    that broke fusion and the nodes merely describe the all-interpreter
    fallback)."""
    nodes: Tuple[PlanNode, ...]
    slots: Tuple[str, ...]
    kind: Optional[str]
    blocker: Optional[Tuple[int, str]] = None

    @property
    def fused(self) -> Optional[PlanNode]:
        return next((n for n in self.nodes if n.op == "fused"), None)

    def launches_per_bucket(self) -> int:
        """Engine launches per step per dtype bucket (multiply by the
        layout's bucket count for the per-step total)."""
        return sum(n.launches for n in self.nodes)

    def describe(self) -> str:
        return " -> ".join(n.label for n in self.nodes)


def _match_tail(parts) -> Optional[Tuple[str, Dict[str, Any], int,
                                         Optional[float]]]:
    """Longest suffix of ``parts`` matching a fused-kind pattern,
    optionally absorbing ONE trailing ``clip_by_global_norm`` into the
    kinds whose apply pass carries the lr (compiled as the deferred-apply
    suffix-clip pass).  Returns (kind, got, start, suffix_clip) or None."""
    suffix_clip = None
    body = list(parts)
    if body and body[-1].name == "clip_by_global_norm":
        suffix_clip = body[-1].get("max_norm")
        body = body[:-1]
    patterns = (_PATTERNS if suffix_clip is None else
                tuple((k, p) for k, p in _PATTERNS
                      if k in _SUFFIX_CLIP_KINDS))
    for start in range(len(body)):
        for kind, pattern in patterns:
            got = _try_match(body[start:], pattern)
            if got is None:
                continue
            if kind == "lamb" and got["scale_by_adam"].get("eps") <= 0.0:
                continue
            return kind, got, start, suffix_clip
    return None


def _is_stateless(p: GradientTransform) -> bool:
    """Whether a stage can interleave as a jnp plan node: known-stateless
    by name, or its ``init`` provably returns ``EmptyState`` (probed on an
    empty pytree, which every ``_stateless``-built transform ignores)."""
    if p.name in _STATELESS_NAMES:
        return True
    try:
        return isinstance(p.init({}), EmptyState)
    except Exception:
        return False


def _fused_launches(kind: str, kp: Dict[str, Any], whole: bool) -> int:
    """Engine launches per dtype bucket for one fused segment.  ``whole``
    marks a plan equivalent to a whole-chain match (executed by the
    kind-level optimizer, where msgd runs its norm pass for the grad_norm
    stat; a plan-executed msgd tail receives that stat from the prefix or
    the jnp fallback and skips pass 1)."""
    if kind == "lamb":
        return 2 + (1 if kp.get("clip") is not None else 0)
    n = 1                                        # fused update pass
    if kp.get("clip") is not None:
        n += 1                                   # raw-norm clip round
    if kp.get("suffix_clip") is not None:
        n += 1                                   # deferred-apply rescale
    if kind == "lars":
        n += 2                                   # ||g|| and ||w|| rounds
    elif kind in ("sngm_global", "sngm_per_tensor"):
        n += 1                                   # normalization norm round
    elif (whole and kp.get("clip") is None
          and kp.get("suffix_clip") is None):
        n += 1                                   # msgd grad_norm stat pass
    return n


def plan_chain(tx: GradientTransform) -> SegmentPlan:
    """Compile a chain to a ``SegmentPlan``: ``ema_params`` stages
    (position-independent — they read the PRE-step params and pass
    updates through) become resident-slot nodes, the longest canonical
    suffix of what remains becomes one fused engine segment, and the
    stages before it interleave as jnp nodes if they are verifiably
    stateless.  Always returns a plan; ``plan.kind is None`` (with
    ``plan.blocker`` set) marks a chain that can only interpret."""
    parts = tx.parts if tx.parts else (tx,)
    slots = tuple(_SLOT_TAGS.get(p.name, "empty") for p in parts)

    def no_plan(blocker):
        nodes = tuple(PlanNode("jnp", (i,), f"interp:{p.name}", 0)
                      for i, p in enumerate(parts))
        return SegmentPlan(nodes=nodes, slots=slots, kind=None,
                           blocker=blocker)

    indexed = list(enumerate(parts))
    core = [(i, p) for i, p in indexed if p.name != "ema_params"]
    emas = [(i, p) for i, p in indexed if p.name == "ema_params"]
    if not core:
        return no_plan((indexed[-1][0], indexed[-1][1].name))
    tail = _match_tail([p for _, p in core])
    if tail is None:
        # fused tails end in schedule/trace(/clip): blame the last stage
        return no_plan((core[-1][0], core[-1][1].name))
    kind, got, start, suffix_clip = tail
    for i, p in core[:start]:
        if not _is_stateless(p):
            return no_plan((i, p.name))

    kp = _kind_params(kind, got)
    if suffix_clip is not None:
        kp["suffix_clip"] = suffix_clip
    whole = start == 0 and not emas and suffix_clip is None
    marks = "".join(
        ["+clip" if kp.get("clip") is not None else "",
         "+suffix_clip" if suffix_clip is not None else "",
         "+nesterov" if kp.get("nesterov") else ""])
    nodes = [PlanNode("jnp", (i,), f"jnp:{p.name}", 0, transform=p)
             for i, p in core[:start]]
    nodes += [PlanNode("ema", (i,), f"ema[{j}]:{p.get('decay')}", 0,
                       kwargs=(("decay", p.get("decay")),))
              for j, (i, p) in enumerate(emas)]
    nodes.append(PlanNode(
        "fused", tuple(i for i, _ in core[start:]), f"fused:{kind}{marks}",
        _fused_launches(kind, kp, whole), kind=kind,
        kwargs=tuple(kp.items())))
    nodes.sort(key=lambda n: n.stages[0])
    return SegmentPlan(nodes=tuple(nodes), slots=slots, kind=kind)


def interpreter_step(tx: GradientTransform, grads, state: ChainOptState,
                     params):
    """One jnp-interpreter chain step — the oracle every compiled path is
    validated against, shared by ``compile_chain``'s interpreter
    optimizer and the fused optimizers' cross-form fallback (a restored
    ``ChainOptState`` fed to a fused optimizer steps here)."""
    if params is None:
        raise TypeError(
            "interpreter-run chains carry no resident parameter "
            "buffers; build the TrainState with params (opt.init_state "
            "does this — only FlatOptState owners set params=None)")
    updates, inner, stats = tx.update(grads, state.inner, params)
    new_p = jax.tree.map(lambda w, u: (w - u).astype(w.dtype),
                         params, updates)
    stats = dict(stats)
    if "grad_norm" not in stats:
        stats["grad_norm"] = global_norm(grads)
    if "update_norm" not in stats:
        stats["update_norm"] = global_norm(updates)
    if "lr" not in stats:
        stats["lr"] = jnp.float32(float("nan"))
    return new_p, ChainOptState(state.step + 1, inner), stats


def compile_chain(tx: GradientTransform, *, fused: Optional[str] = None,
                  name: Optional[str] = None, interpret: bool = False,
                  mesh=None):
    """Compile a chain into an ``Optimizer``.

    Whole-chain shapes (``match_chain``) compile onto the kind-level
    optimizer: bit-identical to the pre-chain monolithic implementations
    in every execution mode — pure jnp, ``fused="per_leaf"``,
    ``fused="multi_tensor"``, and the ``FlatOptState`` resident path with
    its O(1) Pallas launches per step.  Other chains go through
    ``plan_chain``: a plan with a fused tail runs on the multi-tensor
    engine under ``fused="multi_tensor"`` (resident state, jnp prefix
    stages interleaved), and on the interpreter otherwise.  A chain with
    no fusible tail runs on the jnp interpreter (``ChainOptState``);
    requesting a fused mode for one warns — naming the stage that broke
    fusion — and falls back rather than silently changing numerics.
    ``interpret=True`` skips the compiler entirely and runs ANY chain on
    the interpreter — the oracle the compiled paths are validated
    against.  The returned optimizer carries its ``SegmentPlan`` as
    ``opt.plan`` (None under ``interpret=True``).
    """
    from repro.core import optim   # deferred: optim builds chains from here

    plan = None if interpret else plan_chain(tx)
    matched = None if interpret else match_chain(tx)
    if matched is not None:
        kind, kp = matched
        if kind == "lamb":
            opt = optim._lamb_optimizer(
                kp["schedule"], b1=kp["b1"], b2=kp["b2"], eps=kp["eps"],
                weight_decay=kp["weight_decay"], trust_eps=kp["trust_eps"],
                clip=kp["clip"], fused_mode=fused, name=name or kind,
                mesh=mesh)
        else:
            opt = optim._kind_optimizer(
                kind, kp["schedule"], beta=kp["beta"],
                nesterov=kp["nesterov"], weight_decay=kp["weight_decay"],
                eps=kp["eps"], trust=kp["trust"], clip=kp["clip"],
                fused_mode=fused, name=name or kind, mesh=mesh)
        return dataclasses.replace(opt, plan=plan)
    if plan is not None and plan.kind is not None:
        if fused == "multi_tensor":
            return optim._plan_optimizer(
                tx, plan, name=name or f"chain[{plan.kind}]", mesh=mesh)
        if fused is not None:
            warnings.warn(
                f"chain {tuple(p.name for p in (tx.parts or (tx,)))} "
                f"compiles to the segment plan [{plan.describe()}], which "
                f"runs only on the multi-tensor engine; fused={fused!r} is "
                f"ignored and the chain runs on the jnp interpreter",
                UserWarning, stacklevel=2)
    elif fused is not None:
        if plan is not None and plan.blocker is not None:
            i, nm = plan.blocker
            detail = (f": stage {i} ({nm!r}) blocks segment fusion and the "
                      f"plan degenerates to [{plan.describe()}]")
        else:
            detail = ""
        warnings.warn(
            f"chain {tuple(p.name for p in (tx.parts or (tx,)))} does not "
            f"match any fused kind{detail}; fused={fused!r} is ignored and "
            f"the chain runs on the jnp interpreter", UserWarning,
            stacklevel=2)

    def init(params):
        return ChainOptState(step=jnp.zeros((), jnp.int32),
                             inner=tx.init(params))

    def step_fn(grads, state, params):
        return interpreter_step(tx, grads, state, params)

    return optim.Optimizer(name=name or "chain", init=init, step=step_fn,
                           plan=plan)


def as_optimizer(opt_or_tx, *, fused: Optional[str] = None):
    """Accept either an ``Optimizer`` or a raw ``GradientTransform`` chain
    (compiled on the spot) — the coercion ``make_train_step`` applies so
    novel chains plug straight into training."""
    if isinstance(opt_or_tx, GradientTransform):
        return compile_chain(opt_or_tx, fused=fused)
    return opt_or_tx


def place_chain_state(state: ChainOptState, shardings) -> ChainOptState:
    """Re-place a restored ChainOptState onto a mesh: any sub-state field
    whose tree structure mirrors the parameter tree (momentum, Adam
    moments, EMA shadows) is device_put with the parameter shardings;
    counters and scalars keep their default placement."""
    pstruct = jax.tree_util.tree_structure(shardings)

    def place_field(x):
        if jax.tree_util.tree_structure(x) == pstruct:
            return jax.device_put(x, shardings)
        return x

    inner = tuple(type(s)(*(place_field(getattr(s, f)) for f in s._fields))
                  for s in state.inner)
    return ChainOptState(step=state.step, inner=inner)
