"""Optimizers: SNGM (the paper, Algorithm 1) and its baselines, built as
gradient-transform chains.

Every optimizer here is a one-line composition over ``core.transform``::

    sngm  =  add_decayed_weights . normalize_by_global_norm . trace
             . scale_by_schedule
    msgd  =  add_decayed_weights . trace . scale_by_schedule
    lars  =  trust_ratio . scale_by_schedule . trace
    lamb  =  scale_by_adam . add_decayed_weights . scale_by_trust_ratio
             . scale_by_schedule

``compile_chain`` pattern-matches those shapes onto the multi-tensor
engine's fused kinds, so the chain builders return exactly the same
optimizers the monolithic implementations used to: bit-identical
numerics in every execution mode, ``OptState``/``FlatOptState`` state
forms, and O(1) Pallas launches per step when fused.  Novel chains (any
composition the compiler does not recognize) run on the jnp interpreter
with a ``ChainOptState`` — see ``core/transform.py``.

The shared optax-like interface is pytree- and mesh-agnostic: state
pytrees mirror the parameter pytree exactly, so under pjit the optimizer
state inherits the parameter sharding and the update is fully local
except for the norm reductions (a scalar all-reduce), which is precisely
the property that makes SNGM cheap to distribute (DESIGN.md §3).

    opt = sngm(schedule, beta=0.9, weight_decay=1e-4)
    state = opt.init(params)
    params, state, stats = opt.step(grads, state, params)

Fused execution: ``sngm``/``msgd``/``lars``/``lamb`` accept ``fused=``

  * ``None``           — pure jnp (the reference path).
  * ``"multi_tensor"`` — the multi-tensor engine (core/multi_tensor.py):
                         dtype-bucketed flat buffers, one Pallas norm pass
                         + one fused update pass per bucket, O(1) kernel
                         launches per step.  Bit-identical to the jnp path.
  * ``"per_leaf"``     — the original one-kernel-per-tensor Pallas path
                         (kernels/fused_sngm, kernels/fused_lars); kept as
                         the baseline bench_optimizer_overhead.py compares
                         against.

``use_pallas=True`` is the DEPRECATED legacy spelling of
``fused="multi_tensor"`` and emits a ``DeprecationWarning``; migrate by
passing ``fused="multi_tensor"`` explicitly (README "Optimizer API").

State forms: with ``fused="multi_tensor"``, ``opt.init(params)`` returns
a ``FlatOptState`` — params and momentum resident as dtype-bucketed flat
buffers plus the cached ``TreeLayout`` — so steady-state steps pack only
the gradients (1/3 of the per-step packing traffic on an fp32 tree).
``opt.step`` dispatches on the state type and accepts EITHER form from
ANY execution path: a ``FlatOptState`` fed to the jnp path materializes
its pytree view, and an ``OptState`` fed to the fused path takes the
per-step flatten route.  ``to_pytree`` / ``from_pytree`` interconvert
losslessly (e.g. around checkpoints saved in the other form).

With a resident state, ``opt.step``'s ``params`` argument is only a
convenience view: the authoritative parameter values are
``state.p_flats`` (the two agree by construction when params come from
the previous step's output).  The donation-safe spelling is the
``TrainState`` API (``opt.init_state`` / ``opt.step_state``): on the
resident path the flat buffers are the SINGLE owner of the parameters
(``TrainState.params`` is None), the step never returns a second
materialized pytree, and jitting with ``donate_argnums`` on the state
aliases params and optimizer slots in place across steps — ~1x parameter
bytes live instead of the 2x the (params, FlatOptState) pairing held.

Serialization: ``OptimizerSpec`` is the JSON-safe identity of an
optimizer (registry name + kwargs + a declarative schedule spec).
``make_optimizer`` accepts one directly, and ``launch/train.py``
round-trips it through ``train_meta.json`` so ``--resume`` reconstructs
the exact optimizer of the original run.
"""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Any, Callable, Mapping, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import transform as T
from repro.core.multi_tensor import (
    FlatGrads, FlatOptState, _clip_flats_round, _clip_tree_round,
    _engine_mesh, _require_matching_layout, build_layout, ema_flats_update,
    flat_global_norm, flatten, global_norm, init_ema_flats,
    init_flat_adam_state, init_flat_state, leaf_sumsq, mesh_shards,
    multi_tensor_lamb_step_flat, multi_tensor_step, multi_tensor_step_flat,
    place_flat_state, resident_lamb_step, resident_step, tree_squared_norm,
    unflatten)
from repro.core.schedules import Schedule, make_schedule

PyTree = Any


# ---------------------------------------------------------------------------
# tree utilities (canonical reductions live in core.multi_tensor; re-exported
# here because this module has always been their public home)
# ---------------------------------------------------------------------------

def tree_add_scaled(a: PyTree, b: PyTree, scale) -> PyTree:
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# optimizer interface
# ---------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    momentum: PyTree           # mirrors params


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init/step pair.  ``step`` returns (new_params, new_state, stats).
    The state is an ``OptState`` pytree, a flat-buffer-resident
    ``FlatOptState`` (``fused="multi_tensor"``), or a ``ChainOptState``
    (interpreter-run novel chains).  ``kind`` names the fused engine kind
    a compiled chain matched (the whole-chain kind or a segment plan's
    tail kind), or None for interpreter-run chains.  ``plan`` carries the
    chain compiler's ``SegmentPlan`` — the launch-accounting IR — for any
    compiled chain, fused or not (None for optimizers built outside
    ``compile_chain`` or under ``interpret=True``).

    ``step_state`` is the ``TrainState``-level entry every training loop
    should use: it consumes/produces the unified state (params + optimizer
    slots + schedule position) and on the resident path never materializes
    a second parameter pytree — the step's outputs hold the parameters
    exactly once, in ``FlatOptState.p_flats``, so jitting it with
    ``donate_argnums`` on the state aliases the whole update in place."""
    name: str
    init: Callable[[PyTree], Any]
    step: Callable[[PyTree, Any, PyTree], Tuple[PyTree, Any, dict]]
    kind: Optional[str] = None
    plan: Any = None

    def init_state(self, params: PyTree) -> "TrainState":
        """Build the unified ``TrainState``.  When ``init`` returns a
        resident ``FlatOptState`` the flat buffers become the SINGLE
        owner of the parameters: ``TrainState.params`` is None and the
        input pytree is dropped (its leaves are consumed into the
        buffers), so device memory holds one parameter copy."""
        return TrainState.wrap(params, self.init(params))

    def step_state(self, grads: PyTree,
                   state: "TrainState") -> Tuple["TrainState", dict]:
        """One optimizer step over a ``TrainState``.  On the resident
        path (``state.params is None``) the underlying step returns no
        pytree view — ``new_state.opt_state.p_flats`` stays the single
        parameter owner.  A resident state fed to a non-engine optimizer
        materializes its view and continues in pytree form (params +
        ``OptState``), still one live parameter copy."""
        new_p, new_s, stats = self.step(grads, state.opt_state, state.params)
        if new_p is None and not isinstance(new_s, FlatOptState):
            raise TypeError(
                f"optimizer {self.name!r} returned no params view and a "
                f"non-resident state {type(new_s).__name__}; a TrainState "
                f"with params=None requires a FlatOptState owner")
        return TrainState(params=new_p, opt_state=new_s), stats


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class TrainState:
    """The unified training state threaded through a donated train step:
    parameters (or their resident flat-buffer owner), optimizer slots,
    and the schedule position (the shared step counter inside
    ``opt_state``).

    Single-owner invariant: on the resident fast path
    (``fused="multi_tensor"``) ``params`` is **None** and
    ``opt_state.p_flats`` are the only live parameter copy; the forward
    pass reads a temporary unflattened view (``params_view``) that XLA
    frees inside the step.  On every other path ``params`` is the plain
    pytree and ``opt_state`` holds no parameter bytes.  Either way the
    state carries ~1x parameter bytes, and jitting the train step with
    ``donate_argnums`` on it lets XLA alias params and optimizer slots
    across steps instead of double-buffering them."""
    params: Optional[PyTree]
    opt_state: Any

    def tree_flatten_with_keys(self):
        G = jax.tree_util.GetAttrKey
        return (((G("params"), self.params),
                 (G("opt_state"), self.opt_state)), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        params, opt_state = children
        return cls(params=params, opt_state=opt_state)

    @classmethod
    def wrap(cls, params: Optional[PyTree], opt_state: Any) -> "TrainState":
        """Apply the single-owner rule: a resident ``FlatOptState`` owns
        the parameters (the pytree is dropped); any other state form
        carries them.  The one place the rule lives — ``init_state`` and
        the launcher's resume path both build states through here."""
        if isinstance(opt_state, FlatOptState):
            return cls(params=None, opt_state=opt_state)
        return cls(params=params, opt_state=opt_state)

    @property
    def step(self) -> jnp.ndarray:
        return self.opt_state.step

    @property
    def params_view(self) -> PyTree:
        """The parameter pytree: ``params`` itself, or a materialized
        read-only view of the resident flat buffers (bit-equal to them by
        the zero-padding invariant).  Use for ``loss_fn``, logging, and
        checkpointing — never feed it back in as a second live copy."""
        if self.params is not None:
            return self.params
        return self.opt_state.params


def init_train_state(opt: Optimizer, params: PyTree) -> TrainState:
    """Module-level spelling of ``opt.init_state(params)``."""
    return opt.init_state(params)


def _init(params: PyTree) -> OptState:
    # momentum is always fp32, independent of parameter storage dtype
    mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), momentum=mom)


AnyOptState = Union[OptState, FlatOptState]


def _chain_state_of_flat(state: FlatOptState) -> T.ChainOptState:
    """Rebuild the interpreter's ChainOptState for an Adam-family flat
    state: the ``form`` aux records the compiled chain's stateless-stage
    arities, and every per-stage counter equals the step (they advance in
    lockstep by construction)."""
    _, n_prefix, n_mid = state.form
    m, v = state.moments
    inner = ((T.EmptyState(),) * n_prefix
             + (T.ScaleByAdamState(count=state.step, m=m, v=v),)
             + (T.EmptyState(),) * n_mid
             + (T.ScaleByScheduleState(count=state.step),))
    return T.ChainOptState(step=state.step, inner=inner)


def _chain_state_of_chain_form(state: FlatOptState) -> T.ChainOptState:
    """Rebuild the interpreter's ChainOptState for a segment-plan flat
    state: the ``("chain", slots)`` form tags every stage's state, the
    momentum/moment views come from the resident buffers, EMA shadows
    from ``e_flats`` (in stage order), and every counter equals the step
    (they advance in lockstep by construction)."""
    _, slots = state.form
    emas = state.ema_views
    j, inner = 0, []
    for tag in slots:
        if tag == "trace":
            inner.append(T.TraceState(momentum=state.momentum))
        elif tag == "sched":
            inner.append(T.ScaleByScheduleState(count=state.step))
        elif tag == "adam":
            m, v = state.moments
            inner.append(T.ScaleByAdamState(count=state.step, m=m, v=v))
        elif tag == "ema":
            inner.append(T.EmaParamsState(ema=emas[j]))
            j += 1
        else:
            inner.append(T.EmptyState())
    return T.ChainOptState(step=state.step, inner=tuple(inner))


def to_pytree(state) -> Union[OptState, "T.ChainOptState"]:
    """FlatOptState -> its pytree form, lossless: OptState (pytree
    momentum) for the momentum kinds, the interpreter's ChainOptState for
    the Adam family and for segment-plan chain states (so a fused
    checkpoint loads straight into the interpreter path).
    OptState/ChainOptState pass through.  Use to hand a resident state to
    code that expects per-leaf state (checkpoints, external tooling)."""
    if not isinstance(state, FlatOptState):
        return state
    if isinstance(state.form, tuple) and state.form[0] == "chain":
        return _chain_state_of_chain_form(state)
    if state.m_flats:
        return _chain_state_of_flat(state)
    return OptState(step=state.step, momentum=state.momentum)


def _flat_of_chain_state(state: T.ChainOptState, params: PyTree,
                         layout) -> FlatOptState:
    """General ChainOptState -> segment-plan ``("chain", slots)`` flat
    form: momentum into ``u_flats`` OR Adam moments into
    ``m_flats``/``v_flats`` (a chain carrying both has no single-slot
    flat form), EMA shadows into ``e_flats`` in stage order."""
    slots, traces, adams, emas = [], [], [], []
    for s in state.inner:
        if isinstance(s, T.TraceState):
            slots.append("trace")
            traces.append(s)
        elif isinstance(s, T.ScaleByScheduleState):
            slots.append("sched")
        elif isinstance(s, T.ScaleByAdamState):
            slots.append("adam")
            adams.append(s)
        elif isinstance(s, T.EmaParamsState):
            slots.append("ema")
            emas.append(s)
        elif isinstance(s, T.EmptyState):
            slots.append("empty")
        else:
            raise TypeError(
                f"from_pytree: no flat slot for chain stage state "
                f"{type(s).__name__}; only the canonical transform states "
                f"(trace/sched/adam/ema/stateless) have a flat form")
    if len(traces) > 1 or len(adams) > 1 or (traces and adams):
        raise TypeError(
            "from_pytree: only canonical single-momentum chain states have "
            "a flat form (at most one trace XOR one scale_by_adam); got "
            f"inner types {[type(s).__name__ for s in state.inner]}")
    u_flats = (tuple(flatten(traces[0].momentum, layout,
                             cast_to=jnp.float32)) if traces else ())
    if adams:
        m_flats = tuple(flatten(adams[0].m, layout, cast_to=jnp.float32))
        v_flats = tuple(flatten(adams[0].v, layout, cast_to=jnp.float32))
    else:
        m_flats, v_flats = (), ()
    return FlatOptState(
        step=state.step, p_flats=tuple(flatten(params, layout)),
        u_flats=u_flats, layout=layout, m_flats=m_flats, v_flats=v_flats,
        e_flats=tuple(tuple(flatten(e.ema, layout, cast_to=jnp.float32))
                      for e in emas),
        form=("chain", tuple(slots)))


def from_pytree(state, params: PyTree, mesh=None) -> FlatOptState:
    """pytree form -> FlatOptState (flat-buffer-resident), lossless;
    FlatOptState passes through.  ``params`` supplies the layout and the
    resident parameter buffers.  A ChainOptState with the canonical
    Adam-family shape (one ScaleByAdamState, schedule last, all other
    stages stateless) keeps the ``("lamb", ...)`` form; any other
    canonical-stage chain state (momentum / EMA / mixed) lands in the
    segment planner's ``("chain", slots)`` form.  Per-stage counters are
    assumed equal to the step, which the chain update guarantees.
    ``mesh``: build the layout for (and commit the buffers to) the
    mesh's shard count — the launcher's resume path uses this to re-place
    a restored state on the sharded engine."""
    if isinstance(state, FlatOptState):
        if mesh is not None and state.layout.shards != mesh_shards(mesh):
            # bucket padding differs per shard count: round-trip through
            # the pytree form to re-pack for this mesh (lossless)
            return from_pytree(to_pytree(state), params, mesh=mesh)
        return place_flat_state(state, mesh)
    layout = build_layout(params, shards=mesh_shards(mesh))
    if isinstance(state, T.ChainOptState):
        adam_i = [i for i, s in enumerate(state.inner)
                  if isinstance(s, T.ScaleByAdamState)]
        others_ok = all(isinstance(s, T.EmptyState)
                        for i, s in enumerate(state.inner)
                        if i not in adam_i and i != len(state.inner) - 1)
        if (len(adam_i) == 1 and others_ok
                and isinstance(state.inner[-1], T.ScaleByScheduleState)):
            adam = state.inner[adam_i[0]]
            n_mid = len(state.inner) - adam_i[0] - 2
            return place_flat_state(FlatOptState(
                step=state.step,
                p_flats=tuple(flatten(params, layout)),
                u_flats=(), layout=layout,
                m_flats=tuple(flatten(adam.m, layout, cast_to=jnp.float32)),
                v_flats=tuple(flatten(adam.v, layout, cast_to=jnp.float32)),
                form=("lamb", adam_i[0], n_mid)), mesh)
        return place_flat_state(_flat_of_chain_state(state, params, layout),
                                mesh)
    return place_flat_state(FlatOptState(
        step=state.step,
        p_flats=tuple(flatten(params, layout)),
        u_flats=tuple(flatten(state.momentum, layout,
                              cast_to=jnp.float32)),
        layout=layout), mesh)


def _decayed(grads: PyTree, params: PyTree, weight_decay: float) -> PyTree:
    """PyTorch-SGD-style coupled weight decay: g <- g + wd * w (paper §5)."""
    if weight_decay == 0.0:
        return grads
    return jax.tree.map(lambda g, w: g + weight_decay * w, grads, params)


def _resolve_fused(use_pallas: bool, fused: Optional[str],
                   allowed=("per_leaf", "multi_tensor")) -> Optional[str]:
    if use_pallas:
        warnings.warn(
            "use_pallas=True is deprecated; pass fused='multi_tensor' "
            "instead (it routes to the same multi-tensor engine). "
            "use_pallas will be removed in a future release.",
            DeprecationWarning, stacklevel=3)
    if fused is None:
        return "multi_tensor" if use_pallas else None
    if fused not in allowed:
        raise ValueError(f"fused={fused!r}; expected one of {allowed} or None")
    return fused


# ---------------------------------------------------------------------------
# kind-level execution: one implementation per fused-engine kind, shared by
# every chain the compiler matches.  The jnp branch below is the bit-exact
# reference the engine is validated against — its expression graphs must
# not change.
# ---------------------------------------------------------------------------

_PER_LEAF_KINDS = ("sngm_global", "lars")


def _clip_tree(grads: PyTree, clip: float):
    """The interpreter's exact clip_by_global_norm: returns the clipped
    gradient tree (scaled in f32, cast back per leaf) and the RAW norm."""
    raw = global_norm(grads)
    scale = clip / jnp.maximum(raw, clip)
    clipped = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
    return clipped, raw


def _jnp_kind_step(kind: str, grads: PyTree, momentum: PyTree, params: PyTree,
                   *, lr, beta: float, weight_decay: float, eps: float,
                   trust: float, clip: Optional[float] = None,
                   nesterov: bool = False):
    """Pure-jnp reference step for one engine kind.  Returns
    (new_params, new_momentum, stats).  ``nesterov=True`` applies the
    interpreter's look-ahead momentum: the per-kind ``upd`` expression is
    applied a second time with the fresh momentum in place of the old
    (exactly ``trace(nesterov=True)``'s second tree.map); the momentum
    STATE stays the plain trace."""
    raw_gnorm = None
    if clip is not None:
        grads, raw_gnorm = _clip_tree(grads, clip)
    if kind == "lars":
        def upd(v, g, w):
            g = g.astype(jnp.float32)
            wn = jnp.sqrt(leaf_sumsq(w))
            gn = jnp.sqrt(leaf_sumsq(g))
            local = trust * wn / (gn + weight_decay * wn + eps)
            # scalars (biases/norm scales, ||w|| ~ 0 at init) fall back to 1
            local = jnp.where(wn > 0, local, 1.0)
            return beta * v + lr * local * (g + weight_decay * w)

        new_u = jax.tree.map(upd, momentum, grads, params)
        out_u = (jax.tree.map(upd, new_u, grads, params) if nesterov
                 else new_u)
        new_p = jax.tree.map(lambda w, v: (w - v).astype(w.dtype),
                             params, out_u)
        gnorm = global_norm(grads)
    else:
        g = _decayed(grads, params, weight_decay)
        gnorm = global_norm(g)
        if kind == "sngm_global":
            inv = 1.0 / (gnorm + eps)
            def upd(u, gi):
                return beta * u + gi.astype(jnp.float32) * inv
        elif kind == "sngm_per_tensor":
            def upd(u, gi):
                n = jnp.sqrt(leaf_sumsq(gi))
                return beta * u + gi.astype(jnp.float32) * (1.0 / (n + eps))
        else:  # msgd
            def upd(v, gi):
                return beta * v + gi.astype(jnp.float32)
        new_u = jax.tree.map(upd, momentum, g)
        out_u = jax.tree.map(upd, new_u, g) if nesterov else new_u
        new_p = jax.tree.map(lambda w, u: (w - lr * u).astype(w.dtype),
                             params, out_u)
    if clip is not None and kind == "msgd":
        # a clipped msgd chain has no norm-emitting stage after the clip,
        # so the interpreter reports the RAW gradient norm
        gnorm = raw_gnorm
    stats = {"grad_norm": gnorm, "lr": lr, "update_norm": global_norm(out_u)}
    return new_p, new_u, stats


def _per_leaf_kind_step(kind: str, grads: PyTree, momentum: PyTree,
                        params: PyTree, *, lr, beta: float,
                        weight_decay: float, eps: float, trust: float):
    """The original one-kernel-per-tensor Pallas path (the O(n_leaves)
    baseline the multi-tensor engine is benchmarked against)."""
    if kind == "sngm_global":
        from repro.kernels.fused_sngm import ops as _k
        g = _decayed(grads, params, weight_decay)
        gnorm = global_norm(g)
        inv = 1.0 / (gnorm + eps)
        new_p, new_u = _k.fused_sngm_tree(params, g, momentum, inv, beta, lr)
    else:  # lars
        from repro.kernels.fused_lars.ops import lars_update
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_v = jax.tree_util.tree_leaves(momentum)
        ps, vs = [], []
        for w, g, v in zip(flat_p, flat_g, flat_v):
            wn, vn = lars_update(w, g, v, lr, beta=beta, wd=weight_decay,
                                 trust=trust, eps=eps)
            ps.append(wn.astype(w.dtype))
            vs.append(vn)
        new_p = jax.tree_util.tree_unflatten(treedef, ps)
        new_v = jax.tree_util.tree_unflatten(treedef, vs)
        new_u = new_v
        gnorm = global_norm(grads)
    stats = {"grad_norm": gnorm, "lr": lr, "update_norm": global_norm(new_u)}
    return new_p, new_u, stats


def _kind_optimizer(kind: str, schedule: Schedule, *, beta: float,
                    weight_decay: float = 0.0, eps: float = 1e-12,
                    trust: float = 0.001, clip: Optional[float] = None,
                    nesterov: bool = False,
                    fused_mode: Optional[str] = None,
                    name: Optional[str] = None, mesh=None) -> Optimizer:
    """Build the Optimizer for one fused-engine kind in the requested
    execution mode.  This is ``compile_chain``'s target for matched
    chains; all chains matching the same kind share this one
    implementation instead of re-implementing the four-way
    jnp/per_leaf/multi_tensor/resident dispatch.  ``clip`` prepends the
    two-round-norm clip_by_global_norm compilation (engine paths) or the
    equivalent leaf-wise pre-scale (jnp path); ``nesterov`` fuses
    ``trace(nesterov=True)`` into the update pass (jnp and multi_tensor
    modes; the per-leaf kernels have no look-ahead variant)."""
    if fused_mode == "per_leaf" and kind not in _PER_LEAF_KINDS:
        raise ValueError(f"fused='per_leaf' is not available for kind "
                         f"{kind!r}; only {_PER_LEAF_KINDS} have per-leaf "
                         f"kernels — use fused='multi_tensor'")
    if fused_mode == "per_leaf" and clip is not None:
        raise ValueError("fused='per_leaf' has no clip round; use "
                         "fused='multi_tensor' for clip-prefixed chains")
    if fused_mode == "per_leaf" and nesterov:
        raise ValueError("fused='per_leaf' has no nesterov variant; use "
                         "fused='multi_tensor' or fused=None for "
                         "trace(nesterov=True) chains")
    kw = dict(beta=beta, weight_decay=weight_decay, eps=eps, trust=trust,
              clip=clip, nesterov=nesterov)

    def step_fn(grads, state, params):
        lr = schedule(state.step)
        if fused_mode == "multi_tensor" and isinstance(state, FlatOptState):
            # params=None (the TrainState resident path) skips the
            # output pytree view so donation can alias fully in place
            return resident_step(kind, grads, state, lr=lr,
                                 materialize_view=params is not None,
                                 mesh=mesh, **kw)
        if isinstance(grads, FlatGrads):
            # only the resident engine consumes packed gradients directly
            grads = grads.tree
        if fused_mode == "multi_tensor":
            new_p, new_u, stats = multi_tensor_step(
                kind, params, grads, state.momentum, lr=lr, **kw)
            return new_p, OptState(state.step + 1, new_u), stats
        if params is None:
            # a resident state fed to a non-engine path: materialize the
            # authoritative buffer view and continue in pytree form
            params = state.params
        if fused_mode == "per_leaf":
            new_p, new_u, stats = _per_leaf_kind_step(
                kind, grads, state.momentum, params, lr=lr, beta=beta,
                weight_decay=weight_decay, eps=eps, trust=trust)
            return new_p, OptState(state.step + 1, new_u), stats
        # a FlatOptState fed to a non-engine path materializes its
        # momentum view and hands back a plain OptState
        new_p, new_u, stats = _jnp_kind_step(kind, grads, state.momentum,
                                             params, lr=lr, **kw)
        return new_p, OptState(state.step + 1, new_u), stats

    if fused_mode == "multi_tensor":
        def init(params):
            return init_flat_state(params, mesh=mesh)
    else:
        init = _init
    return Optimizer(name or kind, init, step_fn, kind=kind)


# ---------------------------------------------------------------------------
# the LAMB kind: Adam-family execution (fp32 m/v resident alongside params)
# ---------------------------------------------------------------------------

def _lamb_optimizer(schedule: Schedule, *, b1: float, b2: float, eps: float,
                    weight_decay: float = 0.0, trust_eps: float = 0.0,
                    clip: Optional[float] = None,
                    fused_mode: Optional[str] = None,
                    name: Optional[str] = None, mesh=None) -> Optimizer:
    """``compile_chain``'s target for the canonical LAMB chain
    ``(clip ->) scale_by_adam -> add_decayed_weights ->
    scale_by_trust_ratio -> scale_by_schedule``.

    The jnp reference path IS the chain interpreter (so the fused engine
    is validated against the exact transform expressions); the
    ``multi_tensor`` mode runs the two-pass LAMB pipeline in
    ``core.multi_tensor`` on the resident ``FlatOptState`` (with
    ``m_flats``/``v_flats``) that ``opt.init`` returns.  A
    ``ChainOptState`` fed to the fused optimizer runs the (bit-exact)
    interpreter step instead — the engine form is the flat state; convert
    with ``from_pytree`` to stay on the engine after a cross-form
    restore, which is exactly what the launcher does on ``--resume``."""
    if fused_mode not in (None, "multi_tensor"):
        raise ValueError(f"fused={fused_mode!r} is not available for lamb; "
                         f"use fused='multi_tensor' or None")
    prefix = (T.clip_by_global_norm(clip),) if clip is not None else ()
    tx = T.chain(*prefix,
                 T.scale_by_adam(b1, b2, eps),
                 T.add_decayed_weights(weight_decay),
                 T.scale_by_trust_ratio(trust_eps),
                 T.scale_by_schedule(schedule))
    form = ("lamb", len(prefix), 2)
    kw = dict(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
              trust_eps=trust_eps, clip=clip)

    def interp_step(grads, state, params):
        # identical to compile_chain's interpreter step_fn (the reference)
        updates, inner, stats = tx.update(grads, state.inner, params)
        new_p = jax.tree.map(lambda w, u: (w - u).astype(w.dtype),
                             params, updates)
        stats = dict(stats)
        if "grad_norm" not in stats:
            stats["grad_norm"] = global_norm(grads)
        return new_p, T.ChainOptState(state.step + 1, inner), stats

    def step_fn(grads, state, params):
        if fused_mode == "multi_tensor" and isinstance(state, FlatOptState):
            lr = schedule(state.step)
            return resident_lamb_step(grads, state, lr=lr,
                                      materialize_view=params is not None,
                                      mesh=mesh, **kw)
        if isinstance(grads, FlatGrads):
            grads = grads.tree
        # every other (mode, state-form) pairing runs the interpreter:
        # the engine form for lamb is the resident FlatOptState, and a
        # ChainOptState fed to the fused optimizer takes the bit-exact
        # interpreter step rather than a per-step packing path (whose
        # XLA fusion context would cost last-ulp identity; convert with
        # from_pytree to stay on the engine)
        if isinstance(state, FlatOptState):
            if params is None:
                params = state.params
            state = to_pytree(state)        # materialize the chain view
        if params is None:
            raise TypeError("lamb interpreter step needs params; only a "
                            "FlatOptState owner supports params=None")
        return interp_step(grads, state, params)

    def init(params):
        if fused_mode == "multi_tensor":
            return init_flat_adam_state(params, form=form, mesh=mesh)
        return T.ChainOptState(step=jnp.zeros((), jnp.int32),
                               inner=tx.init(params))

    return Optimizer(name or "lamb", init, step_fn, kind="lamb")


# ---------------------------------------------------------------------------
# segment-plan execution: jnp prefix stages + one fused engine tail +
# resident EMA slots, on the ("chain", slots) FlatOptState form
# ---------------------------------------------------------------------------

def _packing_cast(updates: PyTree, layout) -> Optional[Any]:
    """Packing dtype for a plan tail's update tree: None when every leaf
    still matches its layout (parameter) dtype, f32 when an earlier stage
    promoted every leaf (packing promoted updates at the bucket dtype
    would silently round them back)."""
    leaves = jax.tree_util.tree_leaves(updates)
    if all(leaves[s.index].dtype == s.dtype
           for b in layout.buckets for s in b.segments):
        return None
    if all(l.dtype == jnp.float32 for l in leaves):
        return jnp.float32
    raise ValueError(
        "segment plan tail got an update tree that neither matches the "
        "parameter dtypes leaf-for-leaf nor is uniformly f32; got dtypes "
        f"{sorted({jnp.dtype(l.dtype).name for l in leaves})}")


def _plan_optimizer(tx: "T.GradientTransform", plan: "T.SegmentPlan", *,
                    name: Optional[str] = None, mesh=None) -> Optimizer:
    """``compile_chain``'s target for segment plans (fused tail + jnp
    prefix + EMA slots) under ``fused="multi_tensor"``.

    State is a ``FlatOptState`` with the ``("chain", slots)`` form: the
    tail's momentum (or Adam moments) resident in ``u_flats``
    (``m_flats``/``v_flats``), one f32 shadow bucket set per
    ``ema_params`` stage in ``e_flats``.  Each step runs the plan's jnp
    prefix nodes leafwise (interpreter-exact, zero launches), folds a
    tail-adjacent clip through the two-round-norm machinery, lowers the
    tail onto the engine (nesterov / suffix-clip variants included), and
    advances every EMA slot elementwise on the PRE-step ``p_flats``.
    Stats merge left-to-right exactly like the interpreter; a tail with
    no norm-emitting stage (msgd/lamb) takes its ``grad_norm`` from the
    prefix's report or the interpreter's raw-gradient fallback.  A
    restored ``ChainOptState`` fed here steps on the interpreter (the
    lamb cross-form precedent); convert with ``from_pytree`` to get back
    on the engine, which is what the launcher does on ``--resume``."""
    fused_node = plan.fused
    kind = fused_node.kind
    kp = dict(fused_node.kwargs)
    schedule = kp["schedule"]
    jnp_nodes = tuple(n for n in plan.nodes if n.op == "jnp")
    ema_nodes = tuple(n for n in plan.nodes if n.op == "ema")
    form = ("chain", plan.slots)

    def init(params):
        if kind == "lamb":
            st = init_flat_adam_state(params, form=form, mesh=mesh)
        else:
            st = dataclasses.replace(init_flat_state(params, mesh=mesh),
                                     form=form)
        if ema_nodes:
            st = dataclasses.replace(st, e_flats=tuple(
                init_ema_flats(params, st.layout, mesh=mesh)
                for _ in ema_nodes))
            st = place_flat_state(st, mesh)
        return st

    def flat_step(grads, state, params):
        layout = state.layout
        emesh = _engine_mesh(layout, mesh)
        lr = schedule(state.step)
        # the prefix stages' params argument; under donation XLA schedules
        # these reads (and the EMA reads below) before the aliased write
        pview = params if params is not None else unflatten(state.p_flats,
                                                            layout)
        flat_in = isinstance(grads, FlatGrads)
        if flat_in:
            _require_matching_layout(grads, layout)
        raw_gnorm = (lambda: flat_global_norm(grads.flats, layout)) \
            if flat_in else (lambda: global_norm(grads))
        updates = grads.tree if (flat_in and jnp_nodes) else grads
        stats = {}
        for node in jnp_nodes:
            updates, _, st = node.transform.update(updates, T.EmptyState(),
                                                   pview)
            stats.update(st)
        stat_gnorm = None
        if isinstance(updates, FlatGrads):
            # no jnp prefix: the packed gradients feed the tail directly
            g_flats = list(updates.flats)
            if kp.get("clip") is not None:
                g_flats, stat_gnorm = _clip_flats_round(
                    g_flats, layout, float(kp["clip"]), "pallas",
                    mesh=emesh)
        else:
            cast = _packing_cast(updates, layout)
            if kp.get("clip") is not None:
                updates, stat_gnorm = _clip_tree_round(
                    updates, layout, float(kp["clip"]), "pallas",
                    cast_to=cast, mesh=emesh)
            g_flats = flatten(updates, layout, cast_to=cast)
        if kind == "lamb":
            if stat_gnorm is None:
                # the tail has no norm-emitting stage: keep the prefix's
                # grad_norm report, or the interpreter's raw fallback
                stat_gnorm = stats.get("grad_norm", raw_gnorm())
            po, mo, vo, tstats = multi_tensor_lamb_step_flat(
                layout, state.p_flats, g_flats, state.m_flats,
                state.v_flats, count=state.step, lr=lr, b1=kp["b1"],
                b2=kp["b2"], eps=kp["eps"],
                weight_decay=kp["weight_decay"],
                trust_eps=kp["trust_eps"], stat_gnorm=stat_gnorm,
                mesh=emesh)
            uo, mo, vo = (), tuple(mo), tuple(vo)
        else:
            if kind == "msgd" and stat_gnorm is None:
                stat_gnorm = stats.get("grad_norm", raw_gnorm())
            po, uo, tstats = multi_tensor_step_flat(
                kind, layout, state.p_flats, g_flats, state.u_flats,
                lr=lr, beta=kp["beta"], weight_decay=kp["weight_decay"],
                eps=kp["eps"], trust=kp["trust"],
                nesterov=kp.get("nesterov", False),
                suffix_clip=kp.get("suffix_clip"), stat_gnorm=stat_gnorm,
                mesh=emesh)
            uo, mo, vo = tuple(uo), (), ()
        stats.update(tstats)
        new_e = tuple(ema_flats_update(e, state.p_flats, n.arg("decay"))
                      for e, n in zip(state.e_flats, ema_nodes))
        new_state = FlatOptState(step=state.step + 1, p_flats=tuple(po),
                                 u_flats=uo, layout=layout, m_flats=mo,
                                 v_flats=vo, e_flats=new_e,
                                 form=state.form)
        view = unflatten(po, layout) if params is not None else None
        return view, new_state, stats

    def step_fn(grads, state, params):
        if isinstance(state, FlatOptState):
            if state.form != form:
                raise TypeError(
                    f"segment-plan optimizer {name!r} got a FlatOptState "
                    f"with form {state.form!r}, expected {form!r}; restore "
                    f"through from_pytree against the same chain")
            return flat_step(grads, state, params)
        if not isinstance(state, T.ChainOptState):
            raise TypeError(
                f"segment-plan optimizer expects a FlatOptState or "
                f"ChainOptState, got {type(state).__name__}")
        if isinstance(grads, FlatGrads):
            grads = grads.tree
        return T.interpreter_step(tx, grads, state, params)

    return Optimizer(name or f"chain[{kind}]", init, step_fn, kind=kind,
                     plan=plan)


# ---------------------------------------------------------------------------
# SNGM — the paper's Algorithm 1
# ---------------------------------------------------------------------------

def sngm(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         eps: float = 1e-12,
         norm_mode: str = "global",
         nesterov: bool = False,
         ema_decay: Optional[float] = None,
         use_pallas: bool = False,
         fused: Optional[str] = None, mesh=None) -> Optimizer:
    """Stochastic Normalized Gradient descent with Momentum (Algorithm 1).

        u_{t+1} = beta * u_t + g_t / ||g_t||
        w_{t+1} = w_t - eta_t * u_{t+1}

    ``norm_mode``:
      * "global"     — the paper: one Euclidean norm over the whole
                       gradient pytree (Lemma 4: ||u|| <= 1/(1-beta)).
      * "per_tensor" — beyond-paper block-normalized variant (LARS-
                       flavoured); each tensor normalized by its own norm.
                       Lemma 4 then holds per tensor.
    ``nesterov`` — look-ahead momentum (``trace(beta, nesterov=True)``);
    the engine fuses it into the update pass, so launch counts are
    unchanged.  ``ema_decay`` — keep an exponential moving average of the
    params (``ema_params`` stage); with ``fused="multi_tensor"`` the
    shadow params are resident f32 flat slots (``FlatOptState.e_flats``).
    ``fused`` / ``use_pallas`` — see module docstring; numerics identical
    to the jnp path (validated bitwise in tests/test_multi_tensor.py).
    """
    if norm_mode not in ("global", "per_tensor"):
        raise ValueError(norm_mode)
    fused_mode = _resolve_fused(use_pallas, fused)
    if fused_mode == "per_leaf" and norm_mode != "global":
        raise ValueError("fused='per_leaf' supports norm_mode='global' only; "
                         "use fused='multi_tensor' for per_tensor")
    normalize = (T.normalize_by_global_norm if norm_mode == "global"
                 else T.normalize_per_tensor)
    stages = [T.add_decayed_weights(weight_decay),
              normalize(eps),
              T.trace(beta, nesterov=nesterov),
              T.scale_by_schedule(schedule)]
    if ema_decay is not None:
        stages.append(T.ema_params(ema_decay))
    tx = T.chain(*stages)
    return T.compile_chain(tx, fused=fused_mode, name=f"sngm[{norm_mode}]",
                           mesh=mesh)


def sngd(schedule: Schedule,
         weight_decay: float = 0.0,
         eps: float = 1e-12,
         norm_mode: str = "global",
         use_pallas: bool = False,
         fused: Optional[str] = None, mesh=None) -> Optimizer:
    """Stochastic normalized gradient descent (Hazan et al. 2015) =
    SNGM with beta = 0 (the paper's degenerate case)."""
    opt = sngm(schedule, beta=0.0, weight_decay=weight_decay, eps=eps,
               norm_mode=norm_mode, use_pallas=use_pallas, fused=fused,
               mesh=mesh)
    return dataclasses.replace(opt, name="sngd")


# ---------------------------------------------------------------------------
# MSGD — the paper's main baseline (eqs. 2-3, Polyak momentum)
# ---------------------------------------------------------------------------

def msgd(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         nesterov: bool = False,
         use_pallas: bool = False,
         fused: Optional[str] = None, mesh=None) -> Optimizer:
    """Momentum SGD:  v_{t+1} = beta v_t + g_t ;  w_{t+1} = w_t - eta v_{t+1}.
    ``nesterov=True`` applies the look-ahead update w -= eta (beta v_{t+1}
    + g_t); the engine fuses it into the same update pass."""
    fused_mode = _resolve_fused(use_pallas, fused, allowed=("multi_tensor",))
    tx = T.chain(T.add_decayed_weights(weight_decay),
                 T.trace(beta, nesterov=nesterov),
                 T.scale_by_schedule(schedule))
    return T.compile_chain(tx, fused=fused_mode, name="msgd", mesh=mesh)


# ---------------------------------------------------------------------------
# LARS — the large-batch baseline the paper compares against (You et al. 2017)
# ---------------------------------------------------------------------------

def lars(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         trust: float = 0.001,
         eps: float = 1e-12,
         use_pallas: bool = False,
         fused: Optional[str] = None, mesh=None) -> Optimizer:
    """Layer-wise Adaptive Rate Scaling, matching the pytorch-lars
    implementation the paper used (github.com/noahgolmant/pytorch-lars):

        local_lr = trust * ||w|| / (||g|| + wd * ||w|| + eps)   per tensor
        v = beta v + eta * local_lr * (g + wd * w)
        w = w - v

    Note the chain order: the schedule scales what ENTERS the momentum
    (eta inside the v update), so ``scale_by_schedule`` precedes
    ``trace`` — the shape the compiler maps to the ``lars`` kind.
    """
    fused_mode = _resolve_fused(use_pallas, fused)
    tx = T.chain(T.trust_ratio(trust, weight_decay, eps),
                 T.scale_by_schedule(schedule),
                 T.trace(beta))
    return T.compile_chain(tx, fused=fused_mode, name="lars", mesh=mesh)


# ---------------------------------------------------------------------------
# LAMB — beyond-paper reference point (Adam-based layer-wise scaling)
# ---------------------------------------------------------------------------

def lamb(schedule: Schedule,
         b1: float = 0.9, b2: float = 0.999,
         weight_decay: float = 0.0, eps: float = 1e-6,
         fused: Optional[str] = None, mesh=None) -> Optimizer:
    """LAMB (You et al. 2020): bias-corrected Adam direction, decoupled
    weight decay, per-tensor trust-ratio rescale, schedule last.

    The chain compiles onto the engine's ``lamb`` kind: ``fused=None``
    runs the chain interpreter (the reference numerics), and
    ``fused="multi_tensor"`` runs the fused two-pass LAMB pipeline —
    fp32 Adam moments resident in the flat buffers (``FlatOptState``
    with ``m_flats``/``v_flats``), two Pallas launches per step, fp32
    bit-identical to the interpreter (bf16: see README tolerance
    policy).  All norms use the canonical ``leaf_sumsq`` chunked
    reduction; stats report {grad_norm, lr, update_norm} like the rest
    of the family, with update_norm taken pre-lr (the trust-rescaled
    direction) and grad_norm the RAW gradient norm (the interpreter
    chain has no norm-emitting stage, so its fallback default applies).
    """
    tx = T.chain(T.scale_by_adam(b1, b2, eps),
                 T.add_decayed_weights(weight_decay),
                 T.scale_by_trust_ratio(),
                 T.scale_by_schedule(schedule))
    return T.compile_chain(tx, fused=fused, name="lamb", mesh=mesh)


# ---------------------------------------------------------------------------
# registry + serializable specs
# ---------------------------------------------------------------------------

OPTIMIZERS = {"sngm": sngm, "sngd": sngd, "msgd": msgd, "lars": lars,
              "lamb": lamb}


def optimizer_names() -> Tuple[str, ...]:
    """Registry keys, sorted — the single source for CLI choices."""
    return tuple(sorted(OPTIMIZERS))


def register_optimizer(name: str, builder: Callable[..., Optimizer]) -> None:
    """Add a builder (``builder(schedule, **kwargs) -> Optimizer``) to the
    registry, making it reachable from ``make_optimizer``, CLI flags, and
    ``OptimizerSpec`` round-trips."""
    OPTIMIZERS[name] = builder


def builder_accepts(name: str, key: str) -> bool:
    """Whether the registered builder takes ``key`` as a keyword (the
    builders have explicit signatures, so this is exact — used by the
    launcher to map its fixed flag set onto each optimizer)."""
    return key in inspect.signature(OPTIMIZERS[name]).parameters


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """The JSON-safe identity of an optimizer: registry ``name`` plus the
    builder kwargs, with the schedule as a declarative
    ``{"name", "kwargs"}`` spec under ``kwargs["schedule"]`` (see
    ``core.schedules.make_schedule``).  Persisted in ``train_meta.json``
    so ``--resume`` rebuilds the exact optimizer of the original run."""
    name: str
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.name not in OPTIMIZERS:
            raise KeyError(f"unknown optimizer {self.name!r}; "
                           f"available {optimizer_names()}")
        if "schedule" not in self.kwargs:
            raise ValueError("OptimizerSpec.kwargs must carry a 'schedule' "
                             "spec ({'name': ..., 'kwargs': {...}})")

    def to_json(self) -> dict:
        import json
        out = {"name": self.name, "kwargs": dict(self.kwargs)}
        json.dumps(out)   # fail fast on non-serializable kwargs
        return out

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "OptimizerSpec":
        return cls(name=d["name"], kwargs=dict(d["kwargs"]))

    def build(self, mesh=None) -> Optimizer:
        kwargs = dict(self.kwargs)
        schedule = make_schedule(kwargs.pop("schedule"))
        if mesh is not None and builder_accepts(self.name, "mesh"):
            kwargs["mesh"] = mesh
        return OPTIMIZERS[self.name](schedule, **kwargs)


def make_optimizer(name: Union[str, OptimizerSpec],
                   schedule: Optional[Schedule] = None, **kw) -> Optimizer:
    """Build an optimizer from the registry.

    Two forms:
      * ``make_optimizer("sngm", schedule, beta=0.9, ...)`` — direct.
      * ``make_optimizer(spec)`` — from a serializable ``OptimizerSpec``
        (schedule built from its declarative spec; no extra kwargs).
    """
    if isinstance(name, OptimizerSpec):
        mesh = kw.pop("mesh", None)
        if schedule is not None or kw:
            raise TypeError("make_optimizer(spec) takes no extra arguments "
                            "(besides mesh); the spec already carries "
                            "schedule and kwargs")
        return name.build(mesh=mesh)
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; "
                       f"available {optimizer_names()}")
    if schedule is None:
        raise TypeError("make_optimizer(name, schedule, ...) requires a "
                        "schedule")
    return OPTIMIZERS[name](schedule, **kw)
