"""Optimizers: SNGM (the paper, Algorithm 1) and its baselines.

All optimizers share a tiny optax-like interface that is pytree- and
mesh-agnostic: state pytrees mirror the parameter pytree exactly, so
under pjit the optimizer state inherits the parameter sharding and the
update is fully local except for the norm reductions (a scalar
all-reduce), which is precisely the property that makes SNGM cheap to
distribute (DESIGN.md §3).

    opt = sngm(schedule, beta=0.9, weight_decay=1e-4)
    state = opt.init(params)
    params, state, stats = opt.step(grads, state, params)

Fused execution: ``sngm``/``msgd``/``lars`` accept ``fused=``

  * ``None``           — pure jnp (the reference path).
  * ``"multi_tensor"`` — the multi-tensor engine (core/multi_tensor.py):
                         dtype-bucketed flat buffers, one Pallas norm pass
                         + one fused update pass per bucket, O(1) kernel
                         launches per step.  Bit-identical to the jnp path.
  * ``"per_leaf"``     — the original one-kernel-per-tensor Pallas path
                         (kernels/fused_sngm, kernels/fused_lars); kept as
                         the baseline bench_optimizer_overhead.py compares
                         against.

``use_pallas=True`` is the legacy spelling and now routes to
``"multi_tensor"`` when ``fused`` is not given.

State forms: with ``fused="multi_tensor"``, ``opt.init(params)`` returns
a ``FlatOptState`` — params and momentum resident as dtype-bucketed flat
buffers plus the cached ``TreeLayout`` — so steady-state steps pack only
the gradients (1/3 of the per-step packing traffic on an fp32 tree).
``opt.step`` dispatches on the state type and accepts EITHER form from
ANY execution path: a ``FlatOptState`` fed to the jnp path materializes
its pytree view, and an ``OptState`` fed to the fused path takes the
per-step flatten route.  ``to_pytree`` / ``from_pytree`` interconvert
losslessly (e.g. around checkpoints saved in the other form).

With a resident state, ``opt.step``'s ``params`` argument is only a
convenience view: the authoritative parameter values are
``state.p_flats`` (the two agree by construction when params come from
the previous step's output, as in ``make_train_step``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.multi_tensor import (
    FlatOptState, build_layout, check_grad_dtypes, flatten, init_flat_state,
    leaf_sumsq, multi_tensor_step, multi_tensor_step_flat, unflatten)
from repro.core.schedules import Schedule, constant

PyTree = Any


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def tree_squared_norm(tree: PyTree) -> jnp.ndarray:
    """Sum of squared entries over the whole pytree (fp32 accumulate).

    Uses the engine's canonical chunked reduction (``leaf_sumsq``) so the
    jnp optimizer paths and the multi-tensor fused paths see bit-identical
    norms."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(leaf_sumsq(l) for l in leaves)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(tree_squared_norm(tree))


def tree_add_scaled(a: PyTree, b: PyTree, scale) -> PyTree:
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# optimizer interface
# ---------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    momentum: PyTree           # mirrors params


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init/step pair.  ``step`` returns (new_params, new_state, stats).
    The state is an ``OptState`` pytree or, for ``fused="multi_tensor"``,
    a flat-buffer-resident ``FlatOptState``; ``step`` accepts either."""
    name: str
    init: Callable[[PyTree], Any]
    step: Callable[[PyTree, Any, PyTree], Tuple[PyTree, Any, dict]]


def _init(params: PyTree) -> OptState:
    # momentum is always fp32, independent of parameter storage dtype
    mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), momentum=mom)


AnyOptState = Union[OptState, FlatOptState]


def to_pytree(state: AnyOptState) -> OptState:
    """FlatOptState -> OptState (pytree momentum), lossless; OptState
    passes through.  Use to hand a resident state to code that expects
    per-leaf momentum (old checkpoints, external tooling)."""
    if isinstance(state, OptState):
        return state
    return OptState(step=state.step, momentum=state.momentum)


def from_pytree(state: AnyOptState, params: PyTree) -> FlatOptState:
    """OptState -> FlatOptState (flat-buffer-resident), lossless;
    FlatOptState passes through.  ``params`` supplies the layout and the
    resident parameter buffers."""
    if isinstance(state, FlatOptState):
        return state
    layout = build_layout(params)
    return FlatOptState(
        step=state.step,
        p_flats=tuple(flatten(params, layout)),
        u_flats=tuple(flatten(state.momentum, layout,
                              cast_to=jnp.float32)),
        layout=layout)


def _flat_step(kind: str, grads: PyTree, state: FlatOptState, *, lr,
               beta: float, weight_decay: float = 0.0, eps: float = 1e-12,
               trust: float = 0.001):
    """The resident fast path: flatten ONLY the gradients; params and
    momentum stay in the buffers carried by ``state``."""
    layout = state.layout
    check_grad_dtypes(grads, layout)
    g_flats = flatten(grads, layout)
    po, uo, stats = multi_tensor_step_flat(
        kind, layout, state.p_flats, g_flats, state.u_flats, lr=lr,
        beta=beta, weight_decay=weight_decay, eps=eps, trust=trust)
    new_state = FlatOptState(step=state.step + 1, p_flats=tuple(po),
                             u_flats=tuple(uo), layout=layout)
    # pytree view for loss_fn/logging; bit-equal to what the per-step
    # path returns (buffer padding is invariantly zero, see multi_tensor)
    return unflatten(po, layout), new_state, stats


def _decayed(grads: PyTree, params: PyTree, weight_decay: float) -> PyTree:
    """PyTorch-SGD-style coupled weight decay: g <- g + wd * w (paper §5)."""
    if weight_decay == 0.0:
        return grads
    return jax.tree.map(lambda g, w: g + weight_decay * w, grads, params)


def _resolve_fused(use_pallas: bool, fused: Optional[str],
                   allowed=("per_leaf", "multi_tensor")) -> Optional[str]:
    if fused is None:
        return "multi_tensor" if use_pallas else None
    if fused not in allowed:
        raise ValueError(f"fused={fused!r}; expected one of {allowed} or None")
    return fused


# ---------------------------------------------------------------------------
# SNGM — the paper's Algorithm 1
# ---------------------------------------------------------------------------

def sngm(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         eps: float = 1e-12,
         norm_mode: str = "global",
         use_pallas: bool = False,
         fused: Optional[str] = None) -> Optimizer:
    """Stochastic Normalized Gradient descent with Momentum (Algorithm 1).

        u_{t+1} = beta * u_t + g_t / ||g_t||
        w_{t+1} = w_t - eta_t * u_{t+1}

    ``norm_mode``:
      * "global"     — the paper: one Euclidean norm over the whole
                       gradient pytree (Lemma 4: ||u|| <= 1/(1-beta)).
      * "per_tensor" — beyond-paper block-normalized variant (LARS-
                       flavoured); each tensor normalized by its own norm.
                       Lemma 4 then holds per tensor.
    ``fused`` / ``use_pallas`` — see module docstring; numerics identical
    to the jnp path (validated bitwise in tests/test_multi_tensor.py).
    """
    if norm_mode not in ("global", "per_tensor"):
        raise ValueError(norm_mode)
    fused_mode = _resolve_fused(use_pallas, fused)
    if fused_mode == "per_leaf" and norm_mode != "global":
        raise ValueError("fused='per_leaf' supports norm_mode='global' only; "
                         "use fused='multi_tensor' for per_tensor")

    def step_fn(grads, state, params):
        lr = schedule(state.step)
        if fused_mode == "multi_tensor":
            kind = ("sngm_global" if norm_mode == "global"
                    else "sngm_per_tensor")
            if isinstance(state, FlatOptState):
                return _flat_step(kind, grads, state, lr=lr, beta=beta,
                                  weight_decay=weight_decay, eps=eps)
            new_p, new_u, stats = multi_tensor_step(
                kind, params, grads, state.momentum, lr=lr, beta=beta,
                weight_decay=weight_decay, eps=eps)
            return new_p, OptState(state.step + 1, new_u), stats

        g = _decayed(grads, params, weight_decay)
        if norm_mode == "global":
            gnorm = global_norm(g)
            inv = 1.0 / (gnorm + eps)
            if fused_mode == "per_leaf":
                from repro.kernels.fused_sngm import ops as _k
                new_p, new_u = _k.fused_sngm_tree(params, g, state.momentum,
                                                  inv, beta, lr)
            else:
                new_u = jax.tree.map(
                    lambda u, gi: beta * u + gi.astype(jnp.float32) * inv,
                    state.momentum, g)
                new_p = jax.tree.map(
                    lambda w, u: (w - lr * u).astype(w.dtype), params, new_u)
        else:
            gnorm = global_norm(g)  # reported only

            def upd(u, gi):
                n = jnp.sqrt(leaf_sumsq(gi))
                return beta * u + gi.astype(jnp.float32) * (1.0 / (n + eps))
            new_u = jax.tree.map(upd, state.momentum, g)
            new_p = jax.tree.map(
                lambda w, u: (w - lr * u).astype(w.dtype), params, new_u)
        stats = {"grad_norm": gnorm, "lr": lr,
                 "update_norm": global_norm(new_u)}
        return new_p, OptState(state.step + 1, new_u), stats

    init = init_flat_state if fused_mode == "multi_tensor" else _init
    return Optimizer(f"sngm[{norm_mode}]", init, step_fn)


def sngd(schedule: Schedule, weight_decay: float = 0.0, **kw) -> Optimizer:
    """Stochastic normalized gradient descent (Hazan et al. 2015) =
    SNGM with beta = 0 (the paper's degenerate case)."""
    opt = sngm(schedule, beta=0.0, weight_decay=weight_decay, **kw)
    return dataclasses.replace(opt, name="sngd")


# ---------------------------------------------------------------------------
# MSGD — the paper's main baseline (eqs. 2-3, Polyak momentum)
# ---------------------------------------------------------------------------

def msgd(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         use_pallas: bool = False,
         fused: Optional[str] = None) -> Optimizer:
    """Momentum SGD:  v_{t+1} = beta v_t + g_t ;  w_{t+1} = w_t - eta v_{t+1}."""
    fused_mode = _resolve_fused(use_pallas, fused, allowed=("multi_tensor",))

    def step_fn(grads, state, params):
        lr = schedule(state.step)
        if fused_mode == "multi_tensor":
            if isinstance(state, FlatOptState):
                return _flat_step("msgd", grads, state, lr=lr, beta=beta,
                                  weight_decay=weight_decay)
            new_p, new_v, stats = multi_tensor_step(
                "msgd", params, grads, state.momentum, lr=lr, beta=beta,
                weight_decay=weight_decay)
            return new_p, OptState(state.step + 1, new_v), stats

        g = _decayed(grads, params, weight_decay)
        new_v = jax.tree.map(lambda v, gi: beta * v + gi.astype(jnp.float32),
                             state.momentum, g)
        new_p = jax.tree.map(lambda w, v: (w - lr * v).astype(w.dtype),
                             params, new_v)
        stats = {"grad_norm": global_norm(g), "lr": lr,
                 "update_norm": global_norm(new_v)}
        return new_p, OptState(state.step + 1, new_v), stats

    init = init_flat_state if fused_mode == "multi_tensor" else _init
    return Optimizer("msgd", init, step_fn)


# ---------------------------------------------------------------------------
# LARS — the large-batch baseline the paper compares against (You et al. 2017)
# ---------------------------------------------------------------------------

def lars(schedule: Schedule,
         beta: float = 0.9,
         weight_decay: float = 0.0,
         trust: float = 0.001,
         eps: float = 1e-12,
         use_pallas: bool = False,
         fused: Optional[str] = None) -> Optimizer:
    """Layer-wise Adaptive Rate Scaling, matching the pytorch-lars
    implementation the paper used (github.com/noahgolmant/pytorch-lars):

        local_lr = trust * ||w|| / (||g|| + wd * ||w|| + eps)   per tensor
        v = beta v + eta * local_lr * (g + wd * w)
        w = w - v
    """
    fused_mode = _resolve_fused(use_pallas, fused)

    def step_fn(grads, state, params):
        lr = schedule(state.step)
        if fused_mode == "multi_tensor":
            if isinstance(state, FlatOptState):
                return _flat_step("lars", grads, state, lr=lr, beta=beta,
                                  weight_decay=weight_decay, eps=eps,
                                  trust=trust)
            new_p, new_v, stats = multi_tensor_step(
                "lars", params, grads, state.momentum, lr=lr, beta=beta,
                weight_decay=weight_decay, eps=eps, trust=trust)
            return new_p, OptState(state.step + 1, new_v), stats

        if fused_mode == "per_leaf":
            from repro.kernels.fused_lars.ops import lars_update
            flat_p, treedef = jax.tree_util.tree_flatten(params)
            flat_g = jax.tree_util.tree_leaves(grads)
            flat_v = jax.tree_util.tree_leaves(state.momentum)
            ps, vs = [], []
            for w, g, v in zip(flat_p, flat_g, flat_v):
                wn, vn = lars_update(w, g, v, lr, beta=beta, wd=weight_decay,
                                     trust=trust, eps=eps)
                ps.append(wn.astype(w.dtype))
                vs.append(vn)
            new_p = jax.tree_util.tree_unflatten(treedef, ps)
            new_v = jax.tree_util.tree_unflatten(treedef, vs)
        else:
            def upd(v, g, w):
                g = g.astype(jnp.float32)
                wn = jnp.sqrt(leaf_sumsq(w))
                gn = jnp.sqrt(leaf_sumsq(g))
                local = trust * wn / (gn + weight_decay * wn + eps)
                # scalars (biases/norm scales, ||w|| ~ 0 at init) fall back to 1
                local = jnp.where(wn > 0, local, 1.0)
                return beta * v + lr * local * (g + weight_decay * w)

            new_v = jax.tree.map(upd, state.momentum, grads, params)
            new_p = jax.tree.map(lambda w, v: (w - v).astype(w.dtype),
                                 params, new_v)
        stats = {"grad_norm": global_norm(grads), "lr": lr,
                 "update_norm": global_norm(new_v)}
        return new_p, OptState(state.step + 1, new_v), stats

    init = init_flat_state if fused_mode == "multi_tensor" else _init
    return Optimizer("lars", init, step_fn)


# ---------------------------------------------------------------------------
# LAMB — beyond-paper reference point (Adam-based layer-wise scaling)
# ---------------------------------------------------------------------------

class LambState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def lamb(schedule: Schedule,
         b1: float = 0.9, b2: float = 0.999,
         weight_decay: float = 0.0, eps: float = 1e-6) -> Optimizer:
    def init(params):
        return LambState(jnp.zeros((), jnp.int32),
                         tree_zeros_like(params), tree_zeros_like(params))

    def step_fn(grads, state, params):
        lr = schedule(state.step)
        t = state.step.astype(jnp.float32) + 1.0
        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                             state.m, grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                             state.v, grads)

        def upd(w, m, v):
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            r = mh / (jnp.sqrt(vh) + eps) + weight_decay * w
            wn = jnp.linalg.norm(w.astype(jnp.float32))
            rn = jnp.linalg.norm(r)
            ratio = jnp.where((wn > 0) & (rn > 0), wn / rn, 1.0)
            return w - lr * ratio * r

        new_p = jax.tree.map(upd, params, new_m, new_v)
        stats = {"grad_norm": global_norm(grads), "lr": lr}
        return new_p, LambState(state.step + 1, new_m, new_v), stats

    return Optimizer("lamb", init, step_fn)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def make_optimizer(name: str, schedule: Schedule, **kw) -> Optimizer:
    table = {"sngm": sngm, "sngd": sngd, "msgd": msgd, "lars": lars, "lamb": lamb}
    if name not in table:
        raise KeyError(f"unknown optimizer {name!r}; available {sorted(table)}")
    return table[name](schedule, **kw)
