"""repro.core — the paper's contribution: SNGM and its large-batch
optimizer family, schedules, distributed-norm utilities, and the
multi-tensor fused optimizer engine."""
from repro.core.optim import (
    Optimizer, OptState, sngm, sngd, msgd, lars, lamb, make_optimizer,
    global_norm, tree_squared_norm,
)
from repro.core.multi_tensor import (
    TreeLayout, build_layout, flatten, unflatten, leaf_sumsq,
    multi_tensor_step,
)
from repro.core import schedules

__all__ = ["Optimizer", "OptState", "sngm", "sngd", "msgd", "lars", "lamb",
           "make_optimizer", "global_norm", "tree_squared_norm", "schedules",
           "TreeLayout", "build_layout", "flatten", "unflatten",
           "leaf_sumsq", "multi_tensor_step"]
