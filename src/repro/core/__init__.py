"""repro.core — the paper's contribution: SNGM and its large-batch
optimizer family, schedules, distributed-norm utilities, and the
multi-tensor fused optimizer engine."""
from repro.core.optim import (
    Optimizer, OptState, sngm, sngd, msgd, lars, lamb, make_optimizer,
    global_norm, tree_squared_norm, to_pytree, from_pytree,
)
from repro.core.multi_tensor import (
    FlatOptState, TreeLayout, build_layout, count_packed_bytes, flatten,
    unflatten, init_flat_state, leaf_sumsq, multi_tensor_step,
    multi_tensor_step_flat,
)
from repro.core import schedules

__all__ = ["Optimizer", "OptState", "sngm", "sngd", "msgd", "lars", "lamb",
           "make_optimizer", "global_norm", "tree_squared_norm", "schedules",
           "to_pytree", "from_pytree",
           "FlatOptState", "TreeLayout", "build_layout", "count_packed_bytes",
           "flatten", "unflatten", "init_flat_state", "leaf_sumsq",
           "multi_tensor_step", "multi_tensor_step_flat"]
