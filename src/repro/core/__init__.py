"""repro.core — the paper's contribution: SNGM and its large-batch
optimizer family expressed as composable gradient-transform chains
(core/transform.py), compiled onto the multi-tensor fused optimizer
engine (core/multi_tensor.py), plus schedules and distributed-norm
utilities."""
from repro.core.optim import (
    Optimizer, OptState, OptimizerSpec, TrainState, sngm, sngd, msgd, lars,
    lamb, init_train_state, make_optimizer, optimizer_names,
    register_optimizer, global_norm, tree_squared_norm, to_pytree,
    from_pytree,
)
from repro.core.multi_tensor import (
    FlatGrads, FlatOptState, TreeLayout, build_layout, count_packed_bytes,
    flatten, unflatten, flat_global_norm, flat_squared_norm,
    init_flat_adam_state, init_flat_state, leaf_sumsq, mesh_shards,
    multi_tensor_lamb_step, multi_tensor_lamb_step_flat, multi_tensor_step,
    multi_tensor_step_flat, place_flat_state, resident_lamb_step,
    resident_step,
)
from repro.core import transform
from repro.core.transform import (
    ChainOptState, GradientTransform, PlanNode, SegmentPlan, chain,
    compile_chain, as_optimizer, match_chain, plan_chain,
)
from repro.core import schedules
from repro.core.schedules import make_schedule

__all__ = ["Optimizer", "OptState", "OptimizerSpec", "TrainState", "sngm",
           "sngd", "msgd", "lars", "lamb", "init_train_state",
           "make_optimizer", "optimizer_names",
           "register_optimizer", "global_norm", "tree_squared_norm",
           "schedules", "make_schedule", "to_pytree", "from_pytree",
           "FlatGrads", "FlatOptState", "TreeLayout", "build_layout",
           "count_packed_bytes", "flatten", "unflatten", "flat_global_norm",
           "flat_squared_norm", "init_flat_adam_state", "init_flat_state",
           "leaf_sumsq", "mesh_shards", "place_flat_state",
           "multi_tensor_lamb_step",
           "multi_tensor_lamb_step_flat", "multi_tensor_step",
           "multi_tensor_step_flat", "resident_lamb_step", "resident_step",
           "transform", "ChainOptState", "GradientTransform", "PlanNode",
           "SegmentPlan", "chain", "compile_chain", "as_optimizer",
           "match_chain", "plan_chain"]
