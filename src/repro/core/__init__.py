"""repro.core — the paper's contribution: SNGM and its large-batch
optimizer family, schedules, and distributed-norm utilities."""
from repro.core.optim import (
    Optimizer, OptState, sngm, sngd, msgd, lars, lamb, make_optimizer,
    global_norm, tree_squared_norm,
)
from repro.core import schedules

__all__ = ["Optimizer", "OptState", "sngm", "sngd", "msgd", "lars", "lamb",
           "make_optimizer", "global_norm", "tree_squared_norm", "schedules"]
