"""Multi-tensor fused optimizer engine.

The per-leaf Pallas path (``kernels/fused_sngm``) launches one kernel per
parameter tensor, so optimizer overhead is O(n_leaves).  This engine
flattens the parameter/gradient/momentum pytrees into dtype-bucketed
contiguous flat buffers, computes global AND per-segment squared norms in
one Pallas reduction pass per bucket, then applies momentum + update for
the whole bucket in one fused second pass — O(1) kernel launches per step
regardless of tree size.  One coefficient parameterization covers the four
momentum optimizers (see ``kernels/multi_tensor/kernel.py``): SNGM (global
norm), SNGM[per_tensor] and LARS (per-segment norms), and MSGD.  The Adam
family (LAMB) gets its own two-pass pipeline — a fused Adam-moment pass
plus the same apply pass — and ``clip_by_global_norm``-prefixed chains
add one raw-norm round (``_clip_round``) whose scalar scale is applied
inside the later kernels, keeping everything O(1) launches per step.

Numerics are bit-identical to the pure-jnp optimizer paths in
``core.optim`` because both sides share one canonical reduction order:
``leaf_sumsq`` below (CHUNK-sized row partials, then a single reduction
over partials) is used by ``tree_squared_norm``/the per-leaf jnp norms,
and every segment starts on a CHUNK boundary in the flat buffer, so the
kernel's row partials are the same numbers in the same order.

Sharding: flat buffers block 1-D over EVERY axis of a device mesh
(ZeRO-style — optimizer state has no tensor structure left, so the full
device count divides it).  ``build_layout(..., shards=S)`` pads buckets
so each local block is a whole number of kernel tiles, and the kernel
passes run shard-wise under ``shard_map`` with two-level norms:
per-shard Pallas chunk partials, then an ``all_gather`` of the partial
vectors so every shard folds the SAME canonical pairwise reduction —
sharded==unsharded stays bitwise in fp32 (see the mesh section below).
That one small collective per norm pass is exactly the
one-collective-per-step property that makes SNGM cheap to distribute
(paper §5).

Flat-buffer residency: ``multi_tensor_step`` rebuilds all three buffer
sets (params/grads/momentum) from the leaf pytrees every step.
``FlatOptState`` + ``multi_tensor_step_flat`` instead keep params and
momentum *resident* as flat buffers across steps, so steady state packs
only the gradients — 1/3 of the per-step packing traffic on an fp32 tree
(measured via ``count_packed_bytes``).  The pytree view is materialized
only where leaves are actually needed: ``loss_fn``, logging, and
checkpointing.  Both paths are bit-identical: segment padding is zero at
init and every kernel pass maps zero pads to zero pads (g-pad is always
zero because gradients are re-flattened with zero padding each step), so
a resident buffer is exactly what re-flattening its pytree view would
produce.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.multi_tensor.kernel import CHUNK, TILE
from repro.kernels.multi_tensor import ops as _ops

PyTree = Any


# ---------------------------------------------------------------------------
# packing accounting (the resident path's reason to exist)
# ---------------------------------------------------------------------------

_PACKED = {"bytes": 0, "buffers": 0}


def _record_packed(flats: Sequence[jnp.ndarray]) -> None:
    """Called by ``flatten`` once per call, at TRACE time under jit — so
    tracing one optimizer step inside ``count_packed_bytes`` reports the
    bytes that step packs into flat buffers per execution."""
    for f in flats:
        _PACKED["bytes"] += f.size * jnp.dtype(f.dtype).itemsize
        _PACKED["buffers"] += 1


@contextlib.contextmanager
def count_packed_bytes():
    """Count bytes packed into flat buffers inside the block.

        with count_packed_bytes() as c:
            jax.jit(opt.step).lower(grads, state, params)
        print(c["bytes"])   # buffer bytes packed per executed step

    The resident path (FlatOptState) packs only the gradients; the
    per-step path re-packs params+grads+momentum every step."""
    start = dict(_PACKED)
    box = {"bytes": 0, "buffers": 0}
    try:
        yield box
    finally:
        box["bytes"] = _PACKED["bytes"] - start["bytes"]
        box["buffers"] = _PACKED["buffers"] - start["buffers"]


# ---------------------------------------------------------------------------
# canonical chunked reduction (shared with the jnp optimizer paths)
# ---------------------------------------------------------------------------

def _fold_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum a 1-D f32 array by explicit pairwise halving.

    The associativity is fixed by the graph itself (log2(n) explicit adds),
    so the result is bitwise reproducible in ANY fusion context — unlike
    ``jnp.sum(jnp.sum(..., axis=1))``, which XLA's simplifier merges into a
    single differently-ordered reduction depending on what surrounds it.
    Both the jnp optimizer paths and the fused engine reduce norm partials
    with this, which is what makes them bit-identical."""
    n = x.shape[0]
    while n > 1:
        if n % 2:
            x = jnp.pad(x, (0, 1))
            n += 1
        x = x[:n // 2] + x[n // 2:]
        n //= 2
    return x[0]


def leaf_sumsq(x) -> jnp.ndarray:
    """Sum of squared entries of one array, f32 accumulate, in the engine's
    canonical order: CHUNK-sized row partials, then a pairwise fold over the
    partials.  ``tree_squared_norm`` and the per-tensor jnp norms use this
    so the fused path is bit-identical to the jnp path.  A size-0 leaf
    contributes exactly 0.0 (one all-zero pad chunk), matching its empty
    segment in the flat buffer."""
    xf = x.astype(jnp.float32).ravel()
    pad = -xf.size % CHUNK
    if pad or xf.size == 0:
        xf = jnp.pad(xf, (0, pad or CHUNK))
    return _fold_sum(jnp.sum(jnp.square(xf.reshape(-1, CHUNK)), axis=1))


def tree_squared_norm(tree: PyTree) -> jnp.ndarray:
    """Sum of squared entries over the whole pytree (fp32 accumulate), in
    the canonical chunked order — the one reduction every optimizer path
    (jnp, gradient-transform interpreter, fused engine) shares, which is
    what keeps their norms bit-identical."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(leaf_sumsq(l) for l in leaves)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(tree_squared_norm(tree))


# ---------------------------------------------------------------------------
# layout: dtype buckets of chunk-aligned segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    """One leaf's slice of its bucket buffer ([offset, offset+size) holds
    the raveled leaf; the segment is padded out to chunk_hi*CHUNK)."""
    index: int                  # position in the original leaf order
    offset: int                 # element offset, always a CHUNK multiple
    size: int
    shape: Tuple[int, ...]
    dtype: Any
    chunk_lo: int               # [chunk_lo, chunk_hi) partial-row range
    chunk_hi: int


@dataclasses.dataclass(frozen=True)
class Bucket:
    dtype: Any
    segments: Tuple[Segment, ...]
    n_elems: int                # padded buffer length, TILE multiple
    n_chunks: int


@dataclasses.dataclass(frozen=True)
class TreeLayout:
    treedef: Any
    n_leaves: int
    buckets: Tuple[Bucket, ...]
    # bucket lengths are padded to shards*TILE multiples, so every mesh
    # shard of a flat buffer is a whole number of kernel tiles; 1 = the
    # single-device layout.  Tail padding is numerically invisible (all
    # canonical folds are per-segment), so layouts built for different
    # shard counts produce bitwise-identical steps.
    shards: int = 1


def build_layout(tree: PyTree, shards: int = 1) -> TreeLayout:
    """Static (shape/dtype-only) bucketing of a pytree.  Leaves keep their
    original relative order within a bucket; buckets are ordered by dtype
    name for determinism.  ``shards`` pads every bucket to a
    ``shards*TILE`` multiple so the buffers divide evenly over a mesh of
    that many devices (each local block a whole number of kernel
    tiles)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    align = int(shards) * TILE
    by_dtype = {}
    for i, leaf in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(leaf.dtype).name, []).append(i)
    buckets = []
    for dname in sorted(by_dtype):
        segs, off = [], 0
        for i in by_dtype[dname]:
            leaf = leaves[i]
            size = leaf.size
            n_chunks = max(1, -(-size // CHUNK))
            segs.append(Segment(index=i, offset=off, size=size,
                                shape=tuple(leaf.shape),
                                dtype=jnp.dtype(leaf.dtype),
                                chunk_lo=off // CHUNK,
                                chunk_hi=off // CHUNK + n_chunks))
            off += n_chunks * CHUNK
        n_elems = -(-off // align) * align
        buckets.append(Bucket(dtype=jnp.dtype(dname), segments=tuple(segs),
                              n_elems=n_elems, n_chunks=n_elems // CHUNK))
    return TreeLayout(treedef=treedef, n_leaves=len(leaves),
                      buckets=tuple(buckets), shards=int(shards))


def flatten(tree: PyTree, layout: TreeLayout,
            cast_to: Optional[Any] = None) -> List[jnp.ndarray]:
    """Pack a pytree (mirroring the layout's tree) into one flat buffer per
    bucket.  ``cast_to`` overrides the buffer dtype (momentum is always
    f32 regardless of the parameter storage dtype)."""
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    flats = []
    for b in layout.buckets:
        dt = jnp.dtype(cast_to) if cast_to is not None else b.dtype
        pieces, off = [], 0
        for s in b.segments:
            x = leaves[s.index].astype(dt).ravel()
            seg_len = (s.chunk_hi - s.chunk_lo) * CHUNK
            pieces.append(jnp.pad(x, (0, seg_len - s.size)))
            off += seg_len
        if b.n_elems > off:
            pieces.append(jnp.zeros((b.n_elems - off,), dt))
        flats.append(jnp.concatenate(pieces) if len(pieces) > 1
                     else pieces[0])
    _record_packed(flats)
    return flats


def unflatten(flats: Sequence[jnp.ndarray], layout: TreeLayout,
              keep_dtype: bool = False) -> PyTree:
    """Inverse of ``flatten``: slice each segment back out and rebuild the
    tree.  ``keep_dtype=True`` keeps the buffer dtype (momentum buffers are
    f32 even when the layout says bf16)."""
    leaves = [None] * layout.n_leaves
    for b, flat in zip(layout.buckets, flats):
        for s in b.segments:
            x = flat[s.offset:s.offset + s.size].reshape(s.shape)
            leaves[s.index] = x if keep_dtype else x.astype(s.dtype)
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def _segment_sums(partials: jnp.ndarray, bucket: Bucket) -> List[jnp.ndarray]:
    """Reduce per-chunk partials to one scalar per segment — same fold as
    ``leaf_sumsq``'s final reduction, hence bit-identical."""
    return [_fold_sum(partials[s.chunk_lo:s.chunk_hi])
            for s in bucket.segments]


def _per_chunk(bucket: Bucket, seg_vals: Sequence[jnp.ndarray],
               fill=0.0) -> jnp.ndarray:
    """Expand per-segment scalars to the (n_chunks,) coefficient array the
    update kernel consumes (tail-padding chunks get ``fill``)."""
    pieces = [jnp.full((s.chunk_hi - s.chunk_lo,), v, jnp.float32)
              for s, v in zip(bucket.segments, seg_vals)]
    used = bucket.segments[-1].chunk_hi if bucket.segments else 0
    if bucket.n_chunks > used:
        pieces.append(jnp.full((bucket.n_chunks - used,), fill, jnp.float32))
    return jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]


# ---------------------------------------------------------------------------
# mesh sharding: flat buffers blocked over ALL mesh axes, two-level norms
# ---------------------------------------------------------------------------
#
# A flat buffer has no tensor structure left, so it shards 1-D over the
# whole device set (data AND model axes — ZeRO-style optimizer-state
# partitioning).  Each kernel pass then runs on the LOCAL block inside
# ``shard_map``, and the norm passes become two-level: per-shard Pallas
# chunk partials, then an ``all_gather`` of the (tiny) partial vectors so
# every shard folds the SAME canonical pairwise reduction over the same
# numbers in the same order.  Gathering partials instead of psum-ing
# per-shard folded scalars is what keeps sharded==unsharded bitwise in
# fp32: a psum of partial sums would re-associate the fold.  The gather
# moves n_chunks f32 scalars (4 bytes per 1024 parameter elements) — the
# one small collective per norm pass the paper's SNGM cost model prices
# in (§5).

def mesh_shards(mesh) -> int:
    """Total device count of a mesh (1 for None) — the shard count flat
    buffers divide into."""
    return 1 if mesh is None else int(mesh.size)


def flat_sharding(mesh):
    """NamedSharding blocking a 1-D flat buffer over every mesh axis."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names)))


def _engine_mesh(layout: TreeLayout, mesh):
    """The mesh the engine may actually run sharded on, or None.

    Sharded dispatch requires the layout to have been built for exactly
    this mesh's device count — only then is every local block a whole
    number of kernel tiles.  A resident state built (or restored) for a
    different shard count silently falls back to the unsharded ops,
    which compute the same values (XLA then inserts the collectives it
    needs); re-place the state via ``optim.from_pytree(..., mesh=...)``
    to get the sharded fast path."""
    if mesh is None:
        return None
    s = mesh_shards(mesh)
    return mesh if (s > 1 and layout.shards == s) else None


def _shmap(mesh, f, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map
    # check_rep=False: outputs include all_gather-ed partial vectors that
    # ARE replicated, but 0.4.x's replication inference cannot prove it.
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _chunk_sumsq(x, p=None, *, wd: float = 0.0, backend: str = "pallas",
                 mesh=None) -> jnp.ndarray:
    """Per-chunk squared-norm partials of a flat buffer; with a mesh, each
    shard reduces its local tiles and the full (n_chunks,) partial vector
    is gathered back, bitwise equal to the unsharded launch (the gather
    is pure concatenation in shard order)."""
    if mesh is None or backend == "ref":
        if p is None:
            return _ops.chunk_sumsq(x, wd=wd, backend=backend)
        return _ops.chunk_sumsq(x, p, wd=wd, backend=backend)
    from jax.sharding import PartitionSpec as P
    ax = tuple(mesh.axis_names)
    spec = P(ax)

    if p is None:
        def local(xs):
            return jax.lax.all_gather(
                _ops.chunk_sumsq(xs, wd=wd, backend=backend), ax, tiled=True)
        return _shmap(mesh, local, (spec,), P())(x)

    def local(xs, ps):
        return jax.lax.all_gather(
            _ops.chunk_sumsq(xs, ps, wd=wd, backend=backend), ax, tiled=True)
    return _shmap(mesh, local, (spec, spec), P())(x, p)


def _fused_update(pf, gf, uf, ac, c, *, beta: float, wd: float,
                  cast_g_first: bool, nesterov: bool, apply: bool,
                  backend: str = "pallas", mesh=None):
    """Momentum+apply pass; with a mesh, p/g/u and the per-chunk
    coefficient array are consumed blockwise (the replicated (n_chunks,)
    coefficients auto-slice under ``in_specs``) and the update-norm
    partials come back gathered."""
    if mesh is None or backend == "ref":
        return _ops.fused_update(pf, gf, uf, ac, c, beta=beta, wd=wd,
                                 cast_g_first=cast_g_first,
                                 nesterov=nesterov, apply=apply,
                                 backend=backend)
    from jax.sharding import PartitionSpec as P
    ax = tuple(mesh.axis_names)
    spec = P(ax)

    def local(pf, gf, uf, ac, c):
        po, uo, usq = _ops.fused_update(pf, gf, uf, ac, c, beta=beta, wd=wd,
                                        cast_g_first=cast_g_first,
                                        nesterov=nesterov, apply=apply,
                                        backend=backend)
        return po, uo, jax.lax.all_gather(usq, ax, tiled=True)
    return _shmap(mesh, local, (spec, spec, spec, spec, P()),
                  (spec, spec, P()))(pf, gf, uf, ac, c)


def _scale_apply(pf, ud, ac, c, *, backend: str = "pallas", mesh=None):
    """Coefficient-scaled apply pass, blockwise under a mesh (see
    ``_fused_update``)."""
    if mesh is None or backend == "ref":
        return _ops.scale_apply(pf, ud, ac, c, backend=backend)
    from jax.sharding import PartitionSpec as P
    ax = tuple(mesh.axis_names)
    spec = P(ax)

    def local(pf, ud, ac, c):
        po, ssq = _ops.scale_apply(pf, ud, ac, c, backend=backend)
        return po, jax.lax.all_gather(ssq, ax, tiled=True)
    return _shmap(mesh, local, (spec, spec, spec, P()), (spec, P()))(
        pf, ud, ac, c)


def _adam_update(pf, gf, mf, vf, bc1, bc2, *, b1: float, b2: float,
                 eps: float, wd: float = 0.0, backend: str = "pallas",
                 mesh=None):
    """Fused Adam-moment pass, blockwise under a mesh; the three partial
    vectors (direction/param/grad sumsq) come back gathered."""
    if mesh is None or backend == "ref":
        return _ops.adam_update(pf, gf, mf, vf, bc1, bc2, b1=b1, b2=b2,
                                eps=eps, wd=wd, backend=backend)
    from jax.sharding import PartitionSpec as P
    ax = tuple(mesh.axis_names)
    spec = P(ax)

    def local(pf, gf, mf, vf, bc1, bc2):
        mo, vo, ud, usq, psq, gsq = _ops.adam_update(
            pf, gf, mf, vf, bc1, bc2, b1=b1, b2=b2, eps=eps, wd=wd,
            backend=backend)
        gather = lambda t: jax.lax.all_gather(t, ax, tiled=True)
        return mo, vo, ud, gather(usq), gather(psq), gather(gsq)
    return _shmap(mesh, local, (spec,) * 4 + (P(), P()),
                  (spec, spec, spec, P(), P(), P()))(pf, gf, mf, vf, bc1, bc2)


# ---------------------------------------------------------------------------
# flat-buffer-resident optimizer state
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class FlatOptState:
    """Optimizer state kept resident in the engine's flat-buffer form.

    ``p_flats`` hold the parameters in their bucket (storage) dtype, one
    buffer per layout bucket.  The per-leaf slots depend on the engine
    family: momentum kinds (sngm/msgd/lars) carry the f32 momentum in
    ``u_flats``; the Adam family (lamb) instead carries the f32 first and
    second moments in ``m_flats``/``v_flats`` (``u_flats`` is empty).
    ``e_flats`` hold the resident EMA shadow parameters of
    ``ema_params`` stages compiled by the segment planner: one tuple of
    per-bucket f32 buffers PER ema stage (empty for chains without one),
    updated elementwise on the flats each step (zero launches) and
    materialized to pytrees only via ``.ema_views`` / ``to_pytree``.
    ``layout`` and ``form`` ride along as static pytree aux data, so a
    jitted step never rebuilds or re-packs them; ``form`` records which
    family — ``"momentum"``, ``("lamb", n_prefix, n_mid)``, or a
    segment-compiled chain's ``("chain", slots)`` with one per-stage
    state tag ("empty"|"trace"|"sched"|"adam"|"ema") — so ``to_pytree``
    can rebuild the matching pytree-form state.  The resident buffers
    are authoritative: materialize pytree views via ``.params`` /
    ``.momentum`` / ``.moments`` only for ``loss_fn``, logging, and
    checkpointing.
    """
    step: jnp.ndarray                    # scalar int32
    p_flats: Tuple[jnp.ndarray, ...]
    u_flats: Tuple[jnp.ndarray, ...]
    layout: TreeLayout
    m_flats: Tuple[jnp.ndarray, ...] = ()
    v_flats: Tuple[jnp.ndarray, ...] = ()
    e_flats: Tuple[Tuple[jnp.ndarray, ...], ...] = ()
    form: Any = "momentum"               # static; "momentum" | ("lamb", ...)
    #                                    #         | ("chain", slots)

    def tree_flatten_with_keys(self):
        G = jax.tree_util.GetAttrKey
        return (((G("step"), self.step),
                 (G("p_flats"), tuple(self.p_flats)),
                 (G("u_flats"), tuple(self.u_flats)),
                 (G("m_flats"), tuple(self.m_flats)),
                 (G("v_flats"), tuple(self.v_flats)),
                 (G("e_flats"), tuple(tuple(e) for e in self.e_flats))),
                (self.layout, self.form))

    @classmethod
    def tree_unflatten(cls, aux, children):
        step, p_flats, u_flats, m_flats, v_flats, e_flats = children
        layout, form = aux
        return cls(step=step, p_flats=tuple(p_flats),
                   u_flats=tuple(u_flats), layout=layout,
                   m_flats=tuple(m_flats), v_flats=tuple(v_flats),
                   e_flats=tuple(tuple(e) for e in e_flats), form=form)

    @property
    def params(self) -> PyTree:
        return unflatten(self.p_flats, self.layout)

    @property
    def momentum(self) -> PyTree:
        return unflatten(self.u_flats, self.layout, keep_dtype=True)

    @property
    def moments(self) -> Tuple[PyTree, PyTree]:
        """(m, v) pytree views of the Adam moments (f32)."""
        return (unflatten(self.m_flats, self.layout, keep_dtype=True),
                unflatten(self.v_flats, self.layout, keep_dtype=True))

    @property
    def ema_views(self) -> Tuple[PyTree, ...]:
        """One f32 pytree view per resident EMA stage."""
        return tuple(unflatten(e, self.layout, keep_dtype=True)
                     for e in self.e_flats)


def place_flat_state(state: FlatOptState, mesh) -> FlatOptState:
    """Commit every flat buffer of a resident state to the mesh's 1-D
    block sharding (all axes) and replicate the step scalar.  No-op for
    ``mesh=None``.  Pure placement — values are untouched, so a placed
    state steps bitwise-identically to the single-device one."""
    if mesh is None:
        return state
    from jax.sharding import NamedSharding, PartitionSpec
    fs = flat_sharding(mesh)
    rep = NamedSharding(mesh, PartitionSpec())

    def put(flats):
        return tuple(jax.device_put(f, fs) for f in flats)
    return dataclasses.replace(
        state, step=jax.device_put(state.step, rep),
        p_flats=put(state.p_flats), u_flats=put(state.u_flats),
        m_flats=put(state.m_flats), v_flats=put(state.v_flats),
        e_flats=tuple(put(e) for e in state.e_flats))


def init_flat_state(params: PyTree, mesh=None) -> FlatOptState:
    """Build the resident state: params packed once, momentum zeros (f32).
    With a mesh, buckets are padded so they divide over all its devices
    and every buffer is committed to the 1-D block sharding."""
    layout = build_layout(params, shards=mesh_shards(mesh))
    state = FlatOptState(
        step=jnp.zeros((), jnp.int32),
        p_flats=tuple(flatten(params, layout)),
        u_flats=tuple(jnp.zeros((b.n_elems,), jnp.float32)
                      for b in layout.buckets),
        layout=layout)
    return place_flat_state(state, mesh)


def init_flat_adam_state(params: PyTree, form: Any = ("lamb", 0, 2),
                         mesh=None) -> FlatOptState:
    """Resident state for the Adam family: params packed once, both
    moments zeros (f32), no momentum slot.  ``form`` encodes the compiled
    chain's shape — ("lamb", n stateless transforms before scale_by_adam,
    n stateless transforms between it and scale_by_schedule) — which is
    exactly what ``optim.to_pytree`` needs to rebuild the interpreter's
    ``ChainOptState`` layout."""
    layout = build_layout(params, shards=mesh_shards(mesh))

    def zeros():
        # m and v must be DISTINCT buffers: sharing one zeros array
        # between them donates the same buffer twice under the donated
        # TrainState step (XLA rejects `f(donate(a), donate(a))`)
        return tuple(jnp.zeros((b.n_elems,), jnp.float32)
                     for b in layout.buckets)

    state = FlatOptState(
        step=jnp.zeros((), jnp.int32),
        p_flats=tuple(flatten(params, layout)),
        u_flats=(), layout=layout,
        m_flats=zeros(), v_flats=zeros(), form=form)
    return place_flat_state(state, mesh)


def init_ema_flats(params: PyTree, layout: TreeLayout, mesh=None
                   ) -> Tuple[jnp.ndarray, ...]:
    """Resident shadow-parameter buffers for ONE ``ema_params`` stage:
    the params packed to f32, copied so the EMA slot never aliases
    ``p_flats`` (double donation).  Matches the interpreter's
    ``jnp.array(p, dtype=f32, copy=True)`` init leaf-for-leaf."""
    flats = tuple(jnp.array(f, copy=True)
                  for f in flatten(params, layout, cast_to=jnp.float32))
    if mesh is not None:
        fs = flat_sharding(mesh)
        flats = tuple(jax.device_put(f, fs) for f in flats)
    return flats


def ema_flats_update(e_flats: Sequence[jnp.ndarray],
                     p_flats: Sequence[jnp.ndarray],
                     decay: float) -> Tuple[jnp.ndarray, ...]:
    """One EMA advance on the resident flats, elementwise (zero launches):
    ``e <- decay*e + (1-decay)*p`` with the PRE-step params, the
    interpreter's exact ``ema_params`` expression.  Zero padding maps to
    zero, so the buffers stay bit-equal to re-flattening the leafwise
    EMA."""
    return tuple(decay * e + (1.0 - decay) * pf.astype(jnp.float32)
                 for e, pf in zip(e_flats, p_flats))


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class FlatGrads:
    """Gradients already packed into the engine's per-bucket flat buffers
    (the layout rides along as static aux data).

    ``training/step.py`` accumulates micro-batch gradients directly in
    this form when the optimizer state is resident: each micro-batch
    flattens and adds into the per-bucket buffers inside the backward
    ``lax.scan``, so the data-parallel gradient reduction happens as one
    bucketed collective per micro-batch (overlapped with the next
    backward) instead of one monolithic tree reduce at the end.  The
    resident steps consume the buffers as-is — no re-flatten — and the
    values are bitwise what flattening the accumulated tree would give
    (same per-leaf casts and adds, zero pads stay zero)."""
    flats: Tuple[jnp.ndarray, ...]
    layout: TreeLayout

    def tree_flatten_with_keys(self):
        G = jax.tree_util.GetAttrKey
        return (((G("flats"), tuple(self.flats)),), (self.layout,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        (flats,) = children
        return cls(flats=tuple(flats), layout=aux[0])

    @property
    def tree(self) -> PyTree:
        """Leaf-pytree view (sliced out of the buffers) for non-engine
        consumers."""
        return unflatten(self.flats, self.layout)


def _require_matching_layout(grads: FlatGrads, layout: TreeLayout) -> None:
    if grads.layout != layout:
        raise ValueError(
            "FlatGrads were packed with a different TreeLayout than the "
            "resident optimizer state carries (shard padding or bucketing "
            "mismatch); pack gradients with state.layout.")


def flat_squared_norm(flats: Sequence[jnp.ndarray],
                      layout: TreeLayout) -> jnp.ndarray:
    """Canonical squared norm straight off flat buffers, zero launches:
    CHUNK-row partials per bucket, per-segment pairwise folds, summed in
    ORIGINAL leaf order — bitwise equal to
    ``tree_squared_norm(unflatten(flats, layout))``.  (Folding a whole
    bucket at once would associate differently; per-segment is the
    canonical order.)"""
    parts = [jnp.sum(jnp.square(f.astype(jnp.float32).reshape(-1, CHUNK)),
                     axis=1) for f in flats]
    return sum(_leaf_values(parts, layout))


def flat_global_norm(flats: Sequence[jnp.ndarray],
                     layout: TreeLayout) -> jnp.ndarray:
    return jnp.sqrt(flat_squared_norm(flats, layout))


def _clip_flats_round(g_flats, layout: TreeLayout, clip: float,
                      backend: str, mesh=None):
    """``_clip_tree_round`` for gradients already in flat-buffer form:
    same raw-norm launch per bucket, same leafwise clip expression applied
    elementwise on the buffers (bitwise: the scale is one broadcast
    scalar, and zero pads map to zero).  Returns (clipped_flats,
    raw_gnorm)."""
    parts = [_chunk_sumsq(gf, backend=backend, mesh=mesh) for gf in g_flats]
    gnorm = jnp.sqrt(sum(_leaf_values(parts, layout)))
    scale = clip / jnp.maximum(gnorm, clip)
    clipped = [(gf.astype(jnp.float32) * scale).astype(gf.dtype)
               for gf in g_flats]
    return clipped, gnorm


def resident_step(kind: str, grads: PyTree, state: FlatOptState, *, lr,
                  beta: float, weight_decay: float = 0.0, eps: float = 1e-12,
                  trust: float = 0.001, clip: Optional[float] = None,
                  nesterov: bool = False,
                  materialize_view: bool = True, mesh=None
                  ) -> Tuple[Optional[PyTree], FlatOptState, dict]:
    """The resident fast path: flatten ONLY the gradients; params and
    momentum stay in the buffers carried by ``state``.  Returns
    ``(params_view, new_state, stats)`` where the pytree view is bit-equal
    to what the per-step path returns (buffer padding is invariantly
    zero, see module docstring).  ``materialize_view=False`` returns
    ``None`` instead of the view — the donation-safe ``TrainState`` path
    uses this so the step's OUTPUTS hold the parameters exactly once
    (in ``new_state.p_flats``), letting jit donation alias the update
    fully in place.  ``mesh``: run the kernel passes shard-wise over the
    mesh the state was placed on (see ``_engine_mesh`` for the
    fallback)."""
    layout = state.layout
    mesh = _engine_mesh(layout, mesh)
    stat_gnorm = None
    if isinstance(grads, FlatGrads):
        _require_matching_layout(grads, layout)
        g_flats = list(grads.flats)
        if clip is not None:
            g_flats, stat_gnorm = _clip_flats_round(
                g_flats, layout, float(clip), "pallas", mesh=mesh)
    else:
        check_grad_dtypes(grads, layout)
        if clip is not None:
            grads, stat_gnorm = _clip_tree_round(grads, layout, float(clip),
                                                 "pallas", mesh=mesh)
        g_flats = flatten(grads, layout)
    po, uo, stats = multi_tensor_step_flat(
        kind, layout, state.p_flats, g_flats, state.u_flats, lr=lr,
        beta=beta, weight_decay=weight_decay, eps=eps, trust=trust,
        nesterov=nesterov, stat_gnorm=stat_gnorm, mesh=mesh)
    new_state = FlatOptState(step=state.step + 1, p_flats=tuple(po),
                             u_flats=tuple(uo), layout=layout,
                             form=state.form)
    view = unflatten(po, layout) if materialize_view else None
    return view, new_state, stats


def resident_lamb_step(grads: PyTree, state: FlatOptState, *, lr, b1: float,
                       b2: float, eps: float, weight_decay: float = 0.0,
                       trust_eps: float = 0.0, clip: Optional[float] = None,
                       materialize_view: bool = True, mesh=None
                       ) -> Tuple[Optional[PyTree], FlatOptState, dict]:
    """Resident fast path for the Adam family: flatten ONLY the gradients;
    params and both moments stay in the buffers carried by ``state``.
    ``materialize_view=False`` skips the pytree params view (see
    ``resident_step``) for the donation-safe ``TrainState`` path."""
    layout = state.layout
    mesh = _engine_mesh(layout, mesh)
    stat_gnorm = None
    if isinstance(grads, FlatGrads):
        _require_matching_layout(grads, layout)
        g_flats = list(grads.flats)
        if clip is not None:
            g_flats, stat_gnorm = _clip_flats_round(
                g_flats, layout, float(clip), "pallas", mesh=mesh)
    else:
        check_grad_dtypes(grads, layout)
        if clip is not None:
            grads, stat_gnorm = _clip_tree_round(grads, layout, float(clip),
                                                 "pallas", mesh=mesh)
        g_flats = flatten(grads, layout)
    po, mo, vo, stats = multi_tensor_lamb_step_flat(
        layout, state.p_flats, g_flats, state.m_flats, state.v_flats,
        count=state.step, lr=lr, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, trust_eps=trust_eps,
        stat_gnorm=stat_gnorm, mesh=mesh)
    new_state = FlatOptState(step=state.step + 1, p_flats=tuple(po),
                             u_flats=(), layout=layout, m_flats=tuple(mo),
                             v_flats=tuple(vo), form=state.form)
    view = unflatten(po, layout) if materialize_view else None
    return view, new_state, stats


def check_grad_dtypes(grads: PyTree, layout: TreeLayout) -> None:
    """The engine buckets by PARAM dtype, so gradients must match their
    parameter's dtype leaf-for-leaf (what training/step.py's accumulator
    produces).  A silent cast here (e.g. fp32 grads over bf16 params)
    would quietly diverge from the jnp path's promote-to-f32 semantics."""
    leaves = jax.tree_util.tree_leaves(grads)
    assert len(leaves) == layout.n_leaves, (len(leaves), layout.n_leaves)
    for b in layout.buckets:
        for s in b.segments:
            if leaves[s.index].dtype != s.dtype:
                raise ValueError(
                    f"multi_tensor fused path requires grads to match the "
                    f"parameter dtype per leaf; got grad "
                    f"{leaves[s.index].dtype} for param {s.dtype}. Cast the "
                    f"gradients (or use the jnp path, fused=None, which "
                    f"promotes to f32).")


# ---------------------------------------------------------------------------
# the engine step
# ---------------------------------------------------------------------------

KINDS = ("sngm_global", "sngm_per_tensor", "msgd", "lars")


def _leaf_values(parts_per_bucket, layout: TreeLayout) -> List[jnp.ndarray]:
    """Fold per-chunk partials to one scalar per LEAF, indexed in the
    original leaf order (the order every canonical reduction sums in)."""
    out = [None] * layout.n_leaves
    for b, parts in zip(layout.buckets, parts_per_bucket):
        for s, v in zip(b.segments, _segment_sums(parts, b)):
            out[s.index] = v
    return out


def _clip_tree_round(grads: PyTree, layout: TreeLayout, clip: float,
                     backend: str, cast_to: Optional[Any] = None, mesh=None):
    """Round 0 of a clip-prefixed chain: pack the raw gradients and reduce
    their global norm in one ``chunk_sumsq`` launch per bucket, then apply
    the interpreter's exact ``clip_by_global_norm`` expression LEAF-WISE on
    the gradient tree.  Clipping at the tree level (rather than on the
    flat buffer) keeps the downstream kernels' input producers — a
    pad/concat of per-leaf casts — the same graph shape as the un-clipped
    chains', which is what keeps their last-ulp contraction behaviour
    under XLA fusion (and hence bit-identity against the per-leaf jnp
    reference) stable.  Costs one extra gradient packing per step.
    ``cast_to`` overrides the packing dtype for the norm round — the
    segment planner passes f32 when the clip sits MID-chain on updates an
    earlier stage already promoted (packing them at the bucket dtype
    would silently round).  Returns (clipped_grads, raw_gnorm)."""
    parts = [_chunk_sumsq(gf, backend=backend, mesh=mesh)
             for gf in flatten(grads, layout, cast_to=cast_to)]
    gnorm = jnp.sqrt(sum(_leaf_values(parts, layout)))
    scale = clip / jnp.maximum(gnorm, clip)
    clipped = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
    return clipped, gnorm


def multi_tensor_step(kind: str, params: PyTree, grads: PyTree,
                      momentum: PyTree, *, lr, beta: float,
                      weight_decay: float = 0.0, eps: float = 1e-12,
                      trust: float = 0.001, clip: Optional[float] = None,
                      nesterov: bool = False,
                      backend: str = "pallas") -> Tuple[PyTree, PyTree, dict]:
    """One fused optimizer step over the whole tree (pytree in/out).

    Packs params+grads+momentum into flat buffers, runs the flat engine
    core, and unpacks the results.  Returns (new_params, new_momentum,
    stats) with the same stats keys as the jnp paths in ``core.optim``
    ({grad_norm, lr, update_norm}), all bit-identical to them.
    ``backend``: "pallas" (interpret mode off-TPU) or "ref" (pure-jnp
    oracle, zero kernel launches).  Steady-state training should prefer
    the resident form (``FlatOptState`` + ``multi_tensor_step_flat``),
    which packs only the gradients.
    """
    layout = build_layout(params)
    check_grad_dtypes(grads, layout)
    stat_gnorm = None
    if clip is not None:
        grads, stat_gnorm = _clip_tree_round(grads, layout, float(clip),
                                             backend)
    p_flats = flatten(params, layout)
    g_flats = flatten(grads, layout)
    u_flats = flatten(momentum, layout, cast_to=jnp.float32)
    po_flats, uo_flats, stats = multi_tensor_step_flat(
        kind, layout, p_flats, g_flats, u_flats, lr=lr, beta=beta,
        weight_decay=weight_decay, eps=eps, trust=trust, nesterov=nesterov,
        stat_gnorm=stat_gnorm, backend=backend)
    return (unflatten(po_flats, layout),
            unflatten(uo_flats, layout, keep_dtype=True), stats)


def multi_tensor_step_flat(kind: str, layout: TreeLayout,
                           p_flats: Sequence[jnp.ndarray],
                           g_flats: Sequence[jnp.ndarray],
                           u_flats: Sequence[jnp.ndarray], *, lr, beta: float,
                           weight_decay: float = 0.0, eps: float = 1e-12,
                           trust: float = 0.001, nesterov: bool = False,
                           suffix_clip: Optional[float] = None,
                           stat_gnorm: Optional[jnp.ndarray] = None,
                           backend: str = "pallas", mesh=None
                           ) -> Tuple[List[jnp.ndarray], List[jnp.ndarray],
                                      dict]:
    """The engine core: flat-in/flat-out, one (p, g, u) buffer triple per
    layout bucket.  Returns (new_p_flats, new_u_flats, stats) without ever
    materializing a pytree — the resident path calls this with the buffers
    held in ``FlatOptState`` and only the gradients freshly packed.

    Clip-prefixed chains are compiled by the TREE-level wrappers
    (``multi_tensor_step`` / ``resident_step``): they run the raw-norm
    round (``_clip_tree_round``), pass the CLIPPED gradients in here, and
    supply ``stat_gnorm`` — the raw norm the interpreter's clip stage
    reported.  For msgd a supplied ``stat_gnorm`` also skips pass 1
    entirely (its coefficients are constant and its chain has no
    norm-emitting stage after the clip, so the decayed norm is never
    needed); sngm/lars ignore ``stat_gnorm`` for stats because their
    chains re-report the norm downstream of the clip.

    ``nesterov=True`` runs the look-ahead momentum variant of the update
    kernel (``trace(nesterov=True)`` fused).  ``suffix_clip`` compiles a
    TRAILING ``clip_by_global_norm`` (the segment planner's
    clip-at-suffix position): the update pass defers the parameter write
    and emits the effective f32 direction, whose lr-scaled norm feeds
    the interpreter's clip expression, and a third ``scale_apply``
    launch applies the clipped step — one extra launch, agreement with
    the interpreter at the documented "close" tolerance (the clip norm
    associates ``lr * ||u||`` where the interpreter folds
    ``||lr * u||``, the same lr-product association LARS already has).
    """
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    wd = float(weight_decay)

    # ---- pass 1: squared-norm partials per bucket -------------------------
    # sngm/msgd norm the coupled-decayed gradient (g + wd*w, computed inside
    # the kernel); lars needs raw ||g|| and ||w|| per tensor instead.
    # msgd's constant coefficients need no norm at all — pass 1 runs there
    # only for the grad_norm stat, so it is skipped whenever a later (or
    # earlier) clip stage supplies that stat instead.
    g_parts = []
    w_parts = []
    if not (kind == "msgd" and (stat_gnorm is not None
                                or suffix_clip is not None)):
        for b, pf, gf in zip(layout.buckets, p_flats, g_flats):
            if kind == "lars":
                g_parts.append(_chunk_sumsq(gf, backend=backend, mesh=mesh))
                w_parts.append(_chunk_sumsq(pf, backend=backend, mesh=mesh))
            else:
                g_parts.append(_chunk_sumsq(gf, pf, wd=wd, backend=backend,
                                            mesh=mesh))

    # per-segment and global sums, in ORIGINAL leaf order so the sequential
    # accumulation matches tree_squared_norm exactly
    if g_parts:
        gsq_by_leaf = _leaf_values(g_parts, layout)
        gnorm = jnp.sqrt(sum(gsq_by_leaf))
    else:
        gsq_by_leaf, gnorm = None, stat_gnorm
    wsq_by_leaf = _leaf_values(w_parts, layout) if kind == "lars" else None

    # ---- coefficients ----------------------------------------------------
    lr = jnp.asarray(lr, jnp.float32)
    cast_g_first = False
    if kind == "sngm_global":
        inv = 1.0 / (gnorm + eps)
        a_chunks = [jnp.full((b.n_chunks,), inv, jnp.float32)
                    for b in layout.buckets]
        c = lr
    elif kind == "sngm_per_tensor":
        a_chunks = [
            _per_chunk(b, [1.0 / (jnp.sqrt(gsq_by_leaf[s.index]) + eps)
                           for s in b.segments])
            for b in layout.buckets]
        c = lr
    elif kind == "msgd":
        a_chunks = [jnp.ones((b.n_chunks,), jnp.float32)
                    for b in layout.buckets]
        c = lr
    else:  # lars
        def local_lr(s):
            wn = jnp.sqrt(wsq_by_leaf[s.index])
            gn = jnp.sqrt(gsq_by_leaf[s.index])
            local = trust * wn / (gn + wd * wn + eps)
            return lr * jnp.where(wn > 0, local, 1.0)
        a_chunks = [_per_chunk(b, [local_lr(s) for s in b.segments])
                    for b in layout.buckets]
        c = jnp.float32(1.0)
        cast_g_first = True

    # ---- pass 2: fused momentum + apply per bucket -----------------------
    po_flats, uo_flats, usq_parts = [], [], []
    apply_now = suffix_clip is None
    for b, pf, gf, uf, ac in zip(layout.buckets, p_flats, g_flats, u_flats,
                                 a_chunks):
        po, uo, usq = _fused_update(pf, gf, uf, ac, c, beta=beta, wd=wd,
                                    cast_g_first=cast_g_first,
                                    nesterov=nesterov, apply=apply_now,
                                    backend=backend, mesh=mesh)
        po_flats.append(po)
        uo_flats.append(uo)
        usq_parts.append(usq)

    unorm = jnp.sqrt(sum(_leaf_values(usq_parts, layout)))
    if suffix_clip is None:
        stats = {"grad_norm": gnorm, "lr": lr, "update_norm": unorm}
        return po_flats, uo_flats, stats

    # ---- pass 3 (suffix clip): rescale the deferred direction + apply ----
    # With apply=False pass 2 returned the effective f32 direction in
    # ``po_flats``; the interpreter's trailing clip sees the lr-scaled
    # step, so its norm is lr * ||direction|| (up to the documented
    # lr-product association) and its scale feeds one scale_apply launch:
    # ``p <- p - c*(cscale * direction)`` with c carrying the schedule lr.
    snorm = lr * unorm
    cscale = suffix_clip / jnp.maximum(snorm, suffix_clip)
    out_flats, ssq_parts = [], []
    for b, pf, eff in zip(layout.buckets, p_flats, po_flats):
        ac = jnp.full((b.n_chunks,), cscale, jnp.float32)
        po, ssq = _scale_apply(pf, eff, ac, lr, backend=backend, mesh=mesh)
        out_flats.append(po)
        ssq_parts.append(ssq)
    del ssq_parts   # the chain's update_norm stat is sched's (pre-clip)
    # stats mirror the interpreter's left-to-right merge: the trailing
    # clip re-reports grad_norm as the norm of ITS input (the lr-scaled
    # update), overriding any earlier reporter; update_norm stays the
    # schedule stage's pre-scaling report.
    stats = {"grad_norm": snorm, "lr": lr, "update_norm": unorm}
    return out_flats, uo_flats, stats


# ---------------------------------------------------------------------------
# the LAMB/Adam engine step
# ---------------------------------------------------------------------------

def multi_tensor_lamb_step(params: PyTree, grads: PyTree, count, m: PyTree,
                           v: PyTree, *, lr, b1: float, b2: float,
                           eps: float, weight_decay: float = 0.0,
                           trust_eps: float = 0.0,
                           clip: Optional[float] = None,
                           backend: str = "pallas"
                           ) -> Tuple[PyTree, PyTree, PyTree, dict]:
    """One fused LAMB step, pytree in/out (the per-step packing path).
    ``count`` is the Adam step counter BEFORE this step (bias correction
    uses t = count + 1).  Returns (new_params, new_m, new_v, stats)."""
    layout = build_layout(params)
    check_grad_dtypes(grads, layout)
    stat_gnorm = None
    if clip is not None:
        grads, stat_gnorm = _clip_tree_round(grads, layout, float(clip),
                                             backend)
    p_flats = flatten(params, layout)
    g_flats = flatten(grads, layout)
    m_flats = flatten(m, layout, cast_to=jnp.float32)
    v_flats = flatten(v, layout, cast_to=jnp.float32)
    po, mo, vo, stats = multi_tensor_lamb_step_flat(
        layout, p_flats, g_flats, m_flats, v_flats, count=count, lr=lr,
        b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
        trust_eps=trust_eps, stat_gnorm=stat_gnorm, backend=backend)
    return (unflatten(po, layout), unflatten(mo, layout, keep_dtype=True),
            unflatten(vo, layout, keep_dtype=True), stats)


def multi_tensor_lamb_step_flat(layout: TreeLayout,
                                p_flats: Sequence[jnp.ndarray],
                                g_flats: Sequence[jnp.ndarray],
                                m_flats: Sequence[jnp.ndarray],
                                v_flats: Sequence[jnp.ndarray], *, count,
                                lr, b1: float, b2: float, eps: float,
                                weight_decay: float = 0.0,
                                trust_eps: float = 0.0,
                                stat_gnorm: Optional[jnp.ndarray] = None,
                                backend: str = "pallas", mesh=None
                                ) -> Tuple[List[jnp.ndarray],
                                           List[jnp.ndarray],
                                           List[jnp.ndarray], dict]:
    """The LAMB engine core: two launches per bucket (Adam-moment pass +
    apply pass); the tree-level wrappers add the round-0 raw-norm launch
    and pass clipped gradients + ``stat_gnorm`` for clip-prefixed chains.

    The Adam pass advances both f32 moments and forms the bias-corrected,
    decoupled-decayed direction in one kernel, emitting the per-chunk
    sumsq partials of direction / params / grads; the host folds them
    per segment (canonical order) into the LAMB trust ratios, and the
    ``scale_apply`` pass applies the per-segment ratio and the lr — so
    ``p <- p - lr*(ratio*u)`` and the ``update_norm`` partials come out
    of the same launch, with no momentum operand read.  ``eps`` must
    be > 0 (zero-pad invariance; the chain compiler enforces this).
    Numerics mirror the chain interpreter's
    ``scale_by_adam -> add_decayed_weights -> scale_by_trust_ratio ->
    scale_by_schedule`` stages expression-for-expression.
    """
    assert eps > 0.0, "fused lamb requires adam eps > 0 (pad invariance)"
    wd = float(weight_decay)
    t = jnp.asarray(count).astype(jnp.float32) + 1.0
    bc1 = 1 - b1 ** t          # the interpreter's exact bias-correction
    bc2 = 1 - b2 ** t

    # ---- pass 1: fused Adam moments + direction + norm partials ----------
    mo_flats, vo_flats, u_flats = [], [], []
    usq_parts, psq_parts, gsq_parts = [], [], []
    for pf, gf, mf, vf in zip(p_flats, g_flats, m_flats, v_flats):
        mo, vo, ud, usq, psq, gsq = _adam_update(
            pf, gf, mf, vf, bc1, bc2, b1=b1, b2=b2, eps=eps,
            wd=wd, backend=backend, mesh=mesh)
        mo_flats.append(mo)
        vo_flats.append(vo)
        u_flats.append(ud)
        usq_parts.append(usq)
        psq_parts.append(psq)
        gsq_parts.append(gsq)

    # grad_norm stat: the interpreter chain reports the raw-gradient norm
    # (the clip stage's report, or the fallback default) — never the
    # decayed one.  For clip chains the raw norm arrives as stat_gnorm.
    if stat_gnorm is not None:
        gnorm = stat_gnorm
    else:
        gnorm = jnp.sqrt(sum(_leaf_values(gsq_parts, layout)))

    # ---- per-segment trust ratios ----------------------------------------
    usq_by_leaf = _leaf_values(usq_parts, layout)
    wsq_by_leaf = _leaf_values(psq_parts, layout)

    def ratio(s):
        wn = jnp.sqrt(wsq_by_leaf[s.index])
        un = jnp.sqrt(usq_by_leaf[s.index])
        return jnp.where((wn > 0) & (un > 0), wn / (un + trust_eps), 1.0)

    a_chunks = [_per_chunk(b, [ratio(s) for s in b.segments])
                for b in layout.buckets]

    # ---- pass 2: trust-scale + apply -------------------------------------
    lr = jnp.asarray(lr, jnp.float32)
    po_flats, ssq_parts = [], []
    for pf, ud, ac in zip(p_flats, u_flats, a_chunks):
        po, ssq = _scale_apply(pf, ud, ac, lr, backend=backend, mesh=mesh)
        po_flats.append(po)
        ssq_parts.append(ssq)

    stats = {"grad_norm": gnorm, "lr": lr,
             "update_norm": jnp.sqrt(sum(_leaf_values(ssq_parts, layout)))}
    return po_flats, mo_flats, vo_flats, stats
