"""Learning-rate schedules used in the paper's experiments.

The paper (§5) uses:
  * poly-power decay for SNGM and LARS:  lr_t = lr0 * (1 - t/T)^power
  * step decay (divide at milestones) for the MSGD baseline
  * gradual warm-up only for the LARS-with-warm-up row of Table 2
    (SNGM explicitly does NOT use warm-up).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]   # step -> lr


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def poly_power(lr0: float, total_steps: int, power: float = 1.1) -> Schedule:
    """lr0 * (1 - t/T)^power  — the paper's poly strategy (You et al. 2017)."""
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return lr0 * (1.0 - frac) ** power
    return sched


def step_decay(lr0: float, milestones: Sequence[int], factor: float = 0.1) -> Schedule:
    """Divide lr by 1/factor at each milestone (He et al. 2016 recipe)."""
    ms = jnp.asarray(sorted(milestones), jnp.int32)
    def sched(step):
        n = jnp.sum(step >= ms).astype(jnp.float32)
        return lr0 * factor ** n
    return sched


def warmup(base: Schedule, warmup_steps: int, init_lr: float = 0.0) -> Schedule:
    """Gradual linear warm-up from init_lr to base(warmup_steps), then base.

    Used only for the LARS-with-warm-up baseline (Table 2); SNGM needs none.
    """
    def sched(step):
        t = step.astype(jnp.float32)
        target = base(jnp.asarray(warmup_steps))
        frac = jnp.clip(t / jnp.maximum(warmup_steps, 1), 0.0, 1.0)
        warm = init_lr + frac * (target - init_lr)
        return jnp.where(step < warmup_steps, warm, base(step))
    return sched


def cosine(lr0: float, total_steps: int, final_frac: float = 0.0) -> Schedule:
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr0 * (final_frac + (1 - final_frac) * c)
    return sched


# ---------------------------------------------------------------------------
# registry + declarative specs (so OptimizerSpec can serialize a schedule)
# ---------------------------------------------------------------------------

SCHEDULES = {
    "constant": constant,
    "poly_power": poly_power,
    "step_decay": step_decay,
    "warmup": warmup,
    "cosine": cosine,
}


def schedule_names():
    return tuple(sorted(SCHEDULES))


def make_schedule(spec) -> Schedule:
    """Build a schedule from a JSON-safe ``{"name": ..., "kwargs": {...}}``
    spec (the form ``OptimizerSpec`` persists in ``train_meta.json``).
    ``warmup`` nests its base schedule as another spec under
    ``kwargs["base"]``."""
    name = spec["name"]
    if name not in SCHEDULES:
        raise KeyError(f"unknown schedule {name!r}; "
                       f"available {schedule_names()}")
    kwargs = dict(spec.get("kwargs", {}))
    if name == "warmup":
        kwargs["base"] = make_schedule(kwargs["base"])
    return SCHEDULES[name](**kwargs)
