"""Next-token cross-entropy, computed in sequence chunks so the
(B, S, vocab) logits tensor never materializes (vocab is up to 256k).
Each chunk is wrapped in ``jax.checkpoint``: the backward pass recomputes
chunk logits instead of storing them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

CHUNK = 512


def lm_loss(h, unembed, tokens, mask, cfg: ModelConfig):
    """h: (B,S,d) final hidden; tokens: (B,S) int32; mask: (B,S).

    Predicts tokens[:, t+1] from h[:, t]; the last position is masked out.
    Returns (mean loss over masked tokens, token count).
    """
    B, S, d = h.shape
    targets = jnp.roll(tokens, -1, axis=1)
    m = mask * jnp.concatenate(
        [jnp.ones((B, S - 1), mask.dtype), jnp.zeros((B, 1), mask.dtype)], axis=1)

    chunk = min(CHUNK, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def chunk_loss(h_c, t_c, m_c):
        if cfg.logits_bf16:
            # §Perf: vocab projection bf16-in/f32-accumulate (MXU native);
            # softmax/CE math stays f32
            logits = jnp.einsum("bsd,dv->bsv", h_c.astype(jnp.bfloat16),
                                unembed.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", h_c.astype(jnp.float32),
                                unembed.astype(jnp.float32))
        logits = layers.softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        ce = (lse - picked) * m_c
        return jnp.sum(ce), jnp.sum(m_c)

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(carry, xs):
        tot, cnt = carry
        h_c, t_c, m_c = xs
        s, n = chunk_loss(h_c, t_c, m_c)
        return (tot + s, cnt + n), None

    hs = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(m.reshape(B, nc, chunk), 1, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (hs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0), cnt
