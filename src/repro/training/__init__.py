from repro.core.optim import TrainState, init_train_state
from repro.training.step import make_train_step, loss_fn, run_steps
from repro.training.loss import lm_loss

__all__ = ["make_train_step", "loss_fn", "lm_loss", "TrainState",
           "init_train_state", "run_steps"]
