"""Train-step builder: gradient accumulation (lax.scan over micro-batches,
paper §5 trains B=4096/8192 by accumulating 128-sized micro-batches) +
any ``repro.core`` optimizer.  The optimizer sees the *accumulated
global-batch* gradient, so SNGM normalizes once per global batch —
exactly Algorithm 1.

Fused optimizers (``fused="multi_tensor"``/``"per_leaf"``) slot in here
unchanged: the accumulator below keeps gradients in the parameter storage
dtype, which is exactly the per-leaf dtype contract the multi-tensor
engine buckets by (core/multi_tensor.py), so ``make_train_step`` works
identically for jnp and fused optimizers — including under pjit, where
the flat-buffer build is plain jnp and SPMD inserts the one scalar
all-reduce for the norm.

The step consumes/produces the unified ``TrainState`` and is
donation-safe: on the resident path (``TrainState.params is None``) the
``FlatOptState.p_flats`` buffers are the SINGLE owner of the parameters
— ``loss_fn`` reads a temporary unflattened view that XLA frees inside
the step, and the optimizer update writes the buffers without ever
materializing a second pytree output.  Jit it with
``donate_argnums=(0,)`` (what ``launch/train.py`` does) and the whole
params+momentum update aliases in place across steps.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.multi_tensor import (FlatGrads, FlatOptState, flatten,
                                     mesh_shards)
from repro.core.multi_tensor import flat_sharding as _flat_sharding
from repro.core.optim import Optimizer, TrainState
from repro.core.transform import as_optimizer
from repro.models.runtime import Runtime
from repro.models.transformer import forward, unembed_matrix
from repro.training.loss import lm_loss


def loss_fn(params, batch: Dict[str, Any], cfg: ModelConfig, rt: Runtime):
    if rt.gather_dtype != "float32":
        # §Perf: cast matrices to the compute dtype BEFORE use so the FSDP
        # all-gather (inserted by SPMD at first use) moves bf16, not fp32;
        # the cast itself is shard-local.  1D params (norm scales, biases)
        # keep fp32.
        gd = jnp.dtype(rt.gather_dtype)
        params = jax.tree.map(
            lambda p: p.astype(gd)
            if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)
    h, _, aux = forward(params, cfg, rt, batch["tokens"], mode="train",
                        encoder_embeds=batch.get("encoder_embeds"))
    loss, ntok = lm_loss(h, unembed_matrix(params), batch["tokens"],
                         batch["loss_mask"], cfg)
    return loss + aux, {"ce_loss": loss, "aux_loss": aux, "ntok": ntok}


# How loss_fn's aux metrics combine across micro-batches, so logged stats
# keep their global-batch semantics at any n_micro.  COUNT_METRICS sum to
# the global total; TOKEN_WEIGHTED_METRICS are per-token means and combine
# weighted by ntok (an unweighted mean of per-micro means diverges when
# loss_mask density is ragged across micro-batches); everything else is a
# plain mean.  Extend these when adding a metric to loss_fn, or it will
# be silently averaged under gradient accumulation.
COUNT_METRICS = ("ntok",)
TOKEN_WEIGHTED_METRICS = ("ce_loss",)


def make_train_step(cfg: ModelConfig, rt: Runtime, opt: Optimizer,
                    n_micro: int = 1, grad_specs=None):
    """Returns train_step(state, batch) -> (state', stats) over the
    unified ``TrainState`` (build one with ``opt.init_state(params)``).

    ``opt`` is an ``Optimizer`` — or a raw ``GradientTransform`` chain,
    which is compiled on the spot (``core.transform.as_optimizer``): a
    recognized shape lands on the fused-kind implementation, a novel
    composition trains through the jnp chain interpreter.

    batch["tokens"]: (B, S) global batch; accumulated over ``n_micro``
    micro-batches of size B/n_micro inside one jit step.

    grad_specs (PartitionSpec tree mirroring params): pins the gradient /
    accumulator sharding to the parameter sharding so the per-micro
    gradient reduction lowers as reduce-scatter instead of a full
    all-reduce (§Perf: 16x collective-bytes difference at n_micro=16).

    Donation contract: the returned step is safe to jit with
    ``donate_argnums=(0,)`` — the state's buffers (params or resident
    flats, momentum, Adam moments) appear exactly once in the outputs,
    so XLA aliases them in place instead of double-buffering.
    """
    opt = as_optimizer(opt)
    grad_fn = jax.value_and_grad(partial(loss_fn, cfg=cfg, rt=rt), has_aux=True)

    def constrain_g(g):
        if grad_specs is None or rt.mesh is None:
            return g
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(rt.mesh, s)), g, grad_specs)

    def train_step(state: TrainState, batch):
        # resident path: a read-only pytree view of the flat buffers,
        # materialized for loss_fn only (never threaded back as a live
        # second copy — the update below reads state.opt_state.p_flats)
        params = state.params_view
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0, (B, n_micro)

        # flat accumulation: with a resident FlatOptState whose layout
        # matches the runtime mesh's shard count, accumulate straight into
        # the dtype-bucketed flat buffers.  Each micro-batch packs its
        # gradient and adds per bucket under the flat sharding constraint,
        # so SPMD overlaps the bucketed gradient reduce with the NEXT
        # micro-batch's backward inside the scan — and the optimizer gets
        # pre-packed ``FlatGrads``, skipping the re-flatten.  Packing is a
        # pure reshape/pad/concat at the bucket (= parameter storage)
        # dtype, so the summed buckets are bitwise the packed tree sum.
        flat_layout = None
        if n_micro > 1 and isinstance(state.opt_state, FlatOptState):
            lo = state.opt_state.layout
            if rt.mesh is None or lo.shards in (1, mesh_shards(rt.mesh)):
                flat_layout = lo

        def constrain_flats(flats):
            if rt.mesh is None or flat_layout.shards == 1:
                return flats
            fs = _flat_sharding(rt.mesh)
            return tuple(jax.lax.with_sharding_constraint(f, fs)
                         for f in flats)

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain_g(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, B // n_micro, *x.shape[1:]),
                batch)

            if flat_layout is not None:
                def body(acc, mb):
                    g_acc, l_acc = acc
                    (l, m), g = grad_fn(params, mb)
                    gf = flatten(constrain_g(g), flat_layout)
                    g_acc = constrain_flats(tuple(
                        a + b for a, b in zip(g_acc, gf)))
                    return (g_acc, l_acc + l), m

                g0 = tuple(jnp.zeros((b.n_elems,), b.dtype)
                           for b in flat_layout.buckets)
                g0 = constrain_flats(g0)
            else:
                def body(acc, mb):
                    g_acc, l_acc = acc
                    (l, m), g = grad_fn(params, mb)
                    g = constrain_g(g)
                    g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                         g_acc, g)
                    return (constrain_g(g_acc), l_acc + l), m

                # accumulator in the parameter storage dtype: fp32 models
                # get exact accumulation; bf16-param models (jamba-398B)
                # trade ~0.5% gradient noise for fitting the accumulator
                # in HBM
                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                  params)
            (g_sum, l_sum), m_stack = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            if flat_layout is not None:
                grads = FlatGrads(tuple(f / n_micro for f in g_sum),
                                  flat_layout)
            else:
                grads = jax.tree.map(lambda g: g / n_micro, g_sum)
            loss = l_sum / n_micro
            # every aux metric (scalar or not) keeps its global-batch
            # semantics regardless of n_micro — so `metrics` has the same
            # keys and shapes as the n_micro=1 branch
            def combine(k, v):
                if k in COUNT_METRICS:
                    return jnp.sum(v, axis=0)
                if k in TOKEN_WEIGHTED_METRICS and "ntok" in m_stack:
                    w = m_stack["ntok"].astype(jnp.float32)
                    w = w.reshape(w.shape[:1] + (1,) * (v.ndim - 1))
                    return jnp.sum(v * w, axis=0) / jnp.sum(m_stack["ntok"])
                return jnp.mean(v, axis=0)

            metrics = {k: combine(k, v) for k, v in m_stack.items()}

        new_state, stats = opt.step_state(grads, state)
        stats = dict(stats)
        stats["loss"] = loss
        stats.update({k: v for k, v in metrics.items() if jnp.ndim(v) == 0})
        return new_state, stats

    return train_step


def run_steps(step_fn, state: TrainState, batches, n_steps: int, *,
              start: int = 0, tracker=None, callbacks=(), log_every: int = 1,
              summary: Optional[Dict[str, Any]] = None,
              step_hook=None) -> TrainState:
    """Host-side training loop around a (possibly jitted, possibly
    donated) ``train_step(state, batch) -> (state', stats)``: threads the
    state, buffers the per-step device stats, and drains them into the
    tracker every ``log_every`` steps (stats stay device scalars between
    drains, so logging never serializes dispatch — the same pending-drain
    discipline the launcher documents).

    ``batches`` is either the historical ``batch_at(t)`` callable (the
    batch for step ``t``) or any ITERATOR/ITERABLE of batches — e.g. a
    ``repro.data.StreamingLoader`` or the ``PrefetchIterator`` wrapping
    one.  An iterator that exhausts (``StopIteration``) ends the run
    early and cleanly — with ``max_epochs`` set on the loader that is
    the epoch bound; ``n_steps`` stays the step bound.

    ``callbacks`` (``repro.tracker.callbacks.Callback``) run in
    registration order at each drain and may add derived metrics
    (wall-clock, tokens/sec); their ``on_end`` summaries merge with
    ``summary`` into one ``tracker.log_summary`` record before the
    tracker is finished.

    ``step_hook(t, state)`` — when given — runs after every step with
    the NEW state, outside the metrics pump: the launcher uses it for
    periodic (async) checkpointing, which must see the post-step state
    and the data iterator's post-step cursor together.

    This is the ONE loop the launcher, the benchmark harness, and the
    sweep share — so every run emits the same record stream regardless
    of entry point.
    """
    from repro.tracker.callbacks import CallbackRunner
    runner = CallbackRunner(tracker, callbacks, flush_every=log_every)
    if callable(batches) and not hasattr(batches, "__next__"):
        next_batch = batches                      # batch_at(t) form
    else:
        it = iter(batches)
        next_batch = lambda t: next(it)           # noqa: E731
    for t in range(start, n_steps):
        try:
            batch = next_batch(t)
        except StopIteration:
            break
        state, stats = step_fn(state, batch)
        runner.push(t, stats)
        if step_hook is not None:
            step_hook(t, state)
    runner.close(summary)
    return state
