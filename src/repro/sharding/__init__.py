from repro.sharding.rules import (
    DEFAULT_RULES, spec_for, param_specs, param_shardings, batch_spec,
    cache_specs, flat_axes, flat_spec, flat_sharding,
)

__all__ = ["DEFAULT_RULES", "spec_for", "param_specs", "param_shardings",
           "batch_spec", "cache_specs", "flat_axes", "flat_spec",
           "flat_sharding"]
