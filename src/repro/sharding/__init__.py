from repro.sharding.rules import (
    DEFAULT_RULES, spec_for, param_specs, param_shardings, batch_spec,
    cache_specs,
)

__all__ = ["DEFAULT_RULES", "spec_for", "param_specs", "param_shardings",
           "batch_spec", "cache_specs"]
