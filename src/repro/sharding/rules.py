"""Logical-axis -> mesh-axis resolution.

Models annotate parameters with *logical* axes ("embed", "heads", "ffn",
"experts", ...).  ``spec_for`` maps them to mesh axes with two guards:
  * divisibility: an axis maps only if the mesh axis size divides the dim
    (e.g. gemma-2b's 8 heads stay replicated on a model=16 mesh while its
    d_ff=16384 still shards);
  * exclusivity: a mesh axis is used at most once per tensor.

Default layout (DESIGN.md §3): tensor-parallel dims on "model",
d_model/embed dims FSDP-style on "data", MoE experts expert-parallel on
"data"; the "pod" axis is pure data parallelism (params replicated across
pods).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import ParamDef, is_def, logical_axes

# logical axis -> candidate mesh axes, in preference order
DEFAULT_RULES: Dict[Optional[str], Tuple[str, ...]] = {
    "vocab":      ("model",),
    "vocab_table": (),            # embedding table: gather-friendly (see models)
    "embed":      ("data",),      # FSDP: all-gather on use
    "ffn":        ("model",),
    "heads":      ("model",),
    "kv_heads":   ("model",),
    "head_dim":   (),             # never shard (rope mixes halves)
    "experts":    ("data",),      # expert parallelism
    "kv_lora":    ("data",),
    "kv_lora_in": ("model",),
    "q_lora":     ("data",),
    "inner":      ("model",),     # mamba expanded channels / heads
    "norm":       (),
    "layers":     (),             # scan axis
    None:         (),
}


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             mesh: Mesh, rules: Dict = None) -> P:
    rules = rules or DEFAULT_RULES
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        choice = None
        for cand in rules.get(ax, ()):
            if cand in mesh.shape and cand not in used \
                    and dim % mesh.shape[cand] == 0:
                choice = cand
                used.add(cand)
                break
        out.append(choice)
    return P(*out)


def param_specs(defs_tree, mesh: Mesh, rules: Dict = None):
    """Tree of PartitionSpec mirroring a ParamDef tree."""
    return jax.tree_util.tree_map(
        lambda d: spec_for(d.shape, d.axes, mesh, rules), defs_tree,
        is_leaf=is_def)


def param_shardings(defs_tree, mesh: Mesh, rules: Dict = None):
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, mesh, rules)),
        defs_tree, is_leaf=is_def)


def batch_spec(mesh: Mesh, ndim: int) -> P:
    """Batch dim over every data-parallel axis present in the mesh."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes, *([None] * (ndim - 1)))


def flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Every mesh axis, in mesh order: the 1-D layout the optimizer
    engine shards its flat buffers over.  Optimizer state has no tensor
    structure left after flattening, so data AND model axes both divide
    the buffers (ZeRO-style) and the shard count is the full device
    count."""
    return tuple(mesh.axis_names)


def flat_spec(mesh: Mesh) -> P:
    """PartitionSpec for a 1-D flat buffer blocked over the whole mesh."""
    return P(flat_axes(mesh))


def flat_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, flat_spec(mesh))


# decode-cache leaf layouts, dims indexed FROM THE END (leaves may carry a
# leading stacked layer-period dim): name -> (batch_from_end, seq_from_end)
_CACHE_DIMS = {
    "k": (4, 3), "v": (4, 3), "ck": (4, 3), "cv": (4, 3),
    "ckv": (3, 2), "krope": (3, 2), "slot_pos": (2, 1),
    "conv": (3, None), "ssm": (4, None),
}


def cache_specs(cache_abstract_tree, mesh: Mesh, batch_shardable: bool):
    """Shardings for a decode cache.

    * batch shards over (pod, data) when it divides them;
    * the attention-cache *sequence* dim shards over "model" (and over
      "data" too when the batch cannot shard, e.g. long_500k batch=1) —
      flash-decoding: partial softmax + all-reduce, done by the SPMD
      partitioner;
    * SSM state heads / conv channels shard over "model".
    """
    daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_model = mesh.shape.get("model", 1)

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name not in _CACHE_DIMS:
            return NamedSharding(mesh, P())
        b_end, s_end = _CACHE_DIMS[name]
        spec = [None] * leaf.ndim
        if batch_shardable:
            spec[leaf.ndim - b_end] = daxes
        if name == "conv" and leaf.shape[-1] % n_model == 0:
            spec[leaf.ndim - 1] = "model"
        elif name == "ssm" and leaf.shape[leaf.ndim - 3] % n_model == 0:
            spec[leaf.ndim - 3] = "model"
        elif s_end is not None:
            seq_axes = [] if batch_shardable else list(daxes)
            seq_axes.append("model")
            shard = 1
            chosen = []
            for a in seq_axes:
                if leaf.shape[leaf.ndim - s_end] % (shard * mesh.shape[a]) == 0:
                    shard *= mesh.shape[a]
                    chosen.append(a)
            if chosen and leaf.shape[leaf.ndim - s_end] > 1:
                spec[leaf.ndim - s_end] = tuple(chosen)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_abstract_tree)
