"""Deterministic synthetic data pipelines (no datasets ship offline).

* ``SyntheticLM`` — token sequences from a fixed random bigram chain with
  controllable branching: a real learnable distribution, so training loss
  measurably decreases (used by the e2e example and the paper-claims
  benchmarks).
* ``synthetic_images`` — class-conditional Gaussian-blob images, the
  CIFAR10 stand-in for the paper's Table 2 reproduction.

Both are stateless: batch ``i`` is a pure function of (seed, i), so any
data-parallel worker can produce its own shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Bigram-chain language: next token ~ uniform over ``branching``
    successors of the current token (successor table fixed by seed)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, branching: int = 4):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.branching = branching
        rng = np.random.RandomState(seed)
        self.table = jnp.asarray(
            rng.randint(0, vocab_size, size=(vocab_size, branching)), jnp.int32)
        self.seed = seed

    def batch_at(self, i: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), i)
        k0, k1 = jax.random.split(key)
        tok0 = jax.random.randint(k0, (self.batch,), 0, self.vocab, jnp.int32)
        choices = jax.random.randint(k1, (self.batch, self.seq), 0,
                                     self.branching, jnp.int32)

        def step(tok, ch):
            nxt = self.table[tok, ch]
            return nxt, tok
        _, toks = jax.lax.scan(step, tok0, choices.T)
        tokens = jnp.moveaxis(toks, 0, 1)                    # (B,S)
        return {"tokens": tokens,
                "loss_mask": jnp.ones((self.batch, self.seq), jnp.float32)}

    def optimal_loss(self) -> float:
        """Entropy of the chain = log(branching) nats (distinct successors
        assumed; collisions make this an upper bound)."""
        return float(np.log(self.branching))


MU_SEED = 12345     # class means are a fixed property of the task, shared
                    # by every split — `seed` only draws samples


def synthetic_images(n: int, seed: int = 0, n_classes: int = 10,
                     image_size: int = 32, noise: float = 12.0):
    """CIFAR proxy: class-conditional images with SMOOTH (low-frequency)
    class means — x = mu_y + noise * N(0, 1), normalized to unit variance.
    The 4x4->32x32 upsampled means give local spatial structure (so
    convolution + pooling are the right inductive bias, and pooling
    averages pixel noise down), while noise=12 keeps enough confusion for
    train/test generalization gaps to appear."""
    rng_mu = np.random.RandomState(MU_SEED)
    coarse = rng_mu.randn(n_classes, image_size // 8, image_size // 8, 3)
    mus = np.kron(coarse, np.ones((1, 8, 8, 1))).astype(np.float32)
    rng = np.random.RandomState(seed)
    y = rng.randint(0, n_classes, size=(n,))
    x = mus[y] + noise * rng.randn(n, image_size, image_size, 3).astype(np.float32)
    x = x / np.sqrt(1.0 + noise ** 2)          # unit-ish variance
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)
