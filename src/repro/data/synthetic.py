"""Deterministic synthetic data pipelines (no datasets ship offline).

* ``SyntheticLM`` — token sequences from a fixed random bigram chain with
  controllable branching: a real learnable distribution, so training loss
  measurably decreases (used by the e2e example and the paper-claims
  benchmarks).
* ``synthetic_images`` — class-conditional Gaussian-blob images, the
  CIFAR10 stand-in for the paper's Table 2 reproduction.

Both are ``DataSource`` implementations (``data.source``): example ``j``
is a pure function of (seed, j), so the ``StreamingLoader`` can shard,
shuffle, and seek them exactly like an on-disk dataset — and any
data-parallel worker can produce its own shard.  ``SyntheticLM`` also
keeps its historical ``batch_at(i)`` batch-level stream (batch ``i`` is
a pure function of (seed, i)); the two streams draw from independent
fold-in domains, so loader-driven runs and ``batch_at`` runs are both
deterministic but not example-for-example identical.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.source import MemorySource


class SyntheticLM:
    """Bigram-chain language: next token ~ uniform over ``branching``
    successors of the current token (successor table fixed by seed).

    As a ``DataSource`` the nominal epoch is ``epoch_examples`` examples
    in ``n_shards`` equal virtual shards (the chain itself is infinite;
    the epoch size just gives the loader a shuffle/epoch structure)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, branching: int = 4,
                 epoch_examples: int = 65536, n_shards: int = 16):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.branching = branching
        rng = np.random.RandomState(seed)
        self.table = jnp.asarray(
            rng.randint(0, vocab_size, size=(vocab_size, branching)), jnp.int32)
        self.seed = seed
        if epoch_examples % n_shards:
            raise ValueError(f"epoch_examples {epoch_examples} must divide "
                             f"into {n_shards} shards")
        self.epoch_examples = epoch_examples
        self.n_shards = n_shards

    def _walk(self, tok0, choices):
        """(n,) start tokens + (n, S) branch choices -> (n, S) tokens."""
        def step(tok, ch):
            nxt = self.table[tok, ch]
            return nxt, tok
        _, toks = jax.lax.scan(step, tok0, jnp.moveaxis(choices, 0, 1))
        return jnp.moveaxis(toks, 0, 1)                  # (n, S)

    def batch_at(self, i: int):
        """Batch-level stream: batch ``i`` of ``batch_size`` examples."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), i)
        k0, k1 = jax.random.split(key)
        tok0 = jax.random.randint(k0, (self.batch,), 0, self.vocab, jnp.int32)
        choices = jax.random.randint(k1, (self.batch, self.seq), 0,
                                     self.branching, jnp.int32)
        tokens = self._walk(tok0, choices)
        return {"tokens": tokens,
                "loss_mask": jnp.ones((self.batch, self.seq), jnp.float32)}

    # -- DataSource protocol (example-level, host numpy) ----------------
    def shard_lengths(self) -> Tuple[int, ...]:
        per = self.epoch_examples // self.n_shards
        return (per,) * self.n_shards

    def read(self, shard: int, start: int, count: int) -> Dict[str, np.ndarray]:
        from repro.data.source import check_read_range
        check_read_range(self.shard_lengths(), shard, start, count)
        per = self.epoch_examples // self.n_shards
        first = shard * per + start
        # per-EXAMPLE keys in a fold-in domain disjoint from batch_at's
        # (batch_at folds batch indices into PRNGKey(seed); examples fold
        # global example indices into PRNGKey(seed) ^ fold_in(..., -1))
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed), 2**31 - 1)
        keys = jax.vmap(lambda j: jax.random.fold_in(base, j))(
            jnp.arange(first, first + count))
        k0 = jax.vmap(lambda k: jax.random.split(k)[0])(keys)
        k1 = jax.vmap(lambda k: jax.random.split(k)[1])(keys)
        tok0 = jax.vmap(
            lambda k: jax.random.randint(k, (), 0, self.vocab, jnp.int32))(k0)
        choices = jax.vmap(
            lambda k: jax.random.randint(k, (self.seq,), 0,
                                         self.branching, jnp.int32))(k1)
        tokens = self._walk(tok0, choices)
        return {"tokens": np.asarray(jax.device_get(tokens)),
                "loss_mask": np.ones((count, self.seq), np.float32)}

    def optimal_loss(self) -> float:
        """Entropy of the chain = log(branching) nats (distinct successors
        assumed; collisions make this an upper bound)."""
        return float(np.log(self.branching))


MU_SEED = 12345     # class means are a fixed property of the task, shared
                    # by every split — `seed` only draws samples


def synthetic_images(n: int, seed: int = 0, n_classes: int = 10,
                     image_size: int = 32, noise: float = 12.0):
    """CIFAR proxy: class-conditional images with SMOOTH (low-frequency)
    class means — x = mu_y + noise * N(0, 1), normalized to unit variance.
    The 4x4->32x32 upsampled means give local spatial structure (so
    convolution + pooling are the right inductive bias, and pooling
    averages pixel noise down), while noise=12 keeps enough confusion for
    train/test generalization gaps to appear."""
    rng_mu = np.random.RandomState(MU_SEED)
    coarse = rng_mu.randn(n_classes, image_size // 8, image_size // 8, 3)
    mus = np.kron(coarse, np.ones((1, 8, 8, 1))).astype(np.float32)
    rng = np.random.RandomState(seed)
    y = rng.randint(0, n_classes, size=(n,))
    x = mus[y] + noise * rng.randn(n, image_size, image_size, 3).astype(np.float32)
    x = x / np.sqrt(1.0 + noise ** 2)          # unit-ish variance
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def synthetic_images_source(n: int, seed: int = 0,
                            shard_size: Optional[int] = None,
                            **kw) -> MemorySource:
    """The Table-2 image proxy as a sharded ``DataSource`` (fields
    ``x``/``y``), ready for the ``StreamingLoader`` or the data packer."""
    x, y = synthetic_images(n, seed=seed, **kw)
    return MemorySource({"x": np.asarray(x), "y": np.asarray(y)},
                        shard_size=shard_size)
