"""``repro-data-pack`` CLI — write a sharded on-disk dataset.

    # pack an existing .npz/.npy of arrays (fields keep their names)
    python -m repro.data.pack OUT --from-npz corpus.npz --shard-size 1024

    # materialize the synthetic bigram LM as a real on-disk dataset
    # (exercises the full disk pipeline in CI and demos)
    python -m repro.data.pack OUT --synthetic-lm --vocab 512 --seq 128 \
        --n 8192 --shard-size 1024

    # materialize the Table-2 image proxy
    python -m repro.data.pack OUT --synthetic-images --n 4096

The output directory is a ``data.format`` pack: ``shard_*.npz`` files
plus a ``dataset.json`` index written last (the commit marker).  For
the synthetic LM the index ``meta`` records vocab/seq/branching/seed so
consumers (``benchmarks/bench_sweep.py --data-dir``) can validate
compatibility instead of guessing.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

import numpy as np


def _pack_lm(out: str, vocab: int, seq: int, n: int, shard_size: int,
             seed: int, branching: int) -> str:
    from repro.data.format import DataPackWriter
    from repro.data.synthetic import SyntheticLM
    src = SyntheticLM(vocab, seq, batch_size=1, seed=seed,
                      branching=branching, epoch_examples=n,
                      n_shards=max(1, n // shard_size) or 1)
    meta = {"kind": "synthetic_lm", "vocab_size": vocab, "seq_len": seq,
            "branching": branching, "seed": seed,
            "optimal_loss": src.optimal_loss()}
    with DataPackWriter(out, shard_size=shard_size, meta=meta) as w:
        step = min(shard_size, 2048)
        done = 0
        for s, length in enumerate(src.shard_lengths()):
            off = 0
            while off < length:
                take = min(step, length - off)
                w.add(src.read(s, off, take))
                off += take
                done += take
    print(f"[pack] {done} synthetic-LM examples -> {out}")
    return out


def _pack_images(out: str, n: int, shard_size: int, seed: int) -> str:
    from repro.data.format import pack_dataset
    from repro.data.synthetic import synthetic_images
    x, y = synthetic_images(n, seed=seed)
    pack_dataset(out, {"x": np.asarray(x), "y": np.asarray(y)},
                 shard_size=shard_size,
                 meta={"kind": "synthetic_images", "seed": seed})
    print(f"[pack] {n} synthetic images -> {out}")
    return out


def _pack_npz(out: str, path: str, shard_size: int) -> str:
    from repro.data.format import pack_dataset
    data = np.load(path)
    arrays = ({k: data[k] for k in data.files} if hasattr(data, "files")
              else {"data": data})
    pack_dataset(out, arrays, shard_size=shard_size,
                 meta={"kind": "npz", "source": path})
    n = next(iter(arrays.values())).shape[0]
    print(f"[pack] {n} examples from {path} -> {out}")
    return out


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro-data-pack")
    ap.add_argument("out", help="output dataset directory")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--from-npz", metavar="FILE",
                     help="pack the arrays of an .npz/.npy file")
    src.add_argument("--synthetic-lm", action="store_true",
                     help="materialize the synthetic bigram LM on disk")
    src.add_argument("--synthetic-images", action="store_true",
                     help="materialize the Table-2 image proxy on disk")
    ap.add_argument("--n", type=int, default=8192,
                    help="examples to generate (synthetic sources)")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--branching", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shard-size", type=int, default=1024,
                    help="examples per shard — also the shuffle "
                         "granularity of the streaming loader")
    args = ap.parse_args(argv)

    if args.from_npz:
        _pack_npz(args.out, args.from_npz, args.shard_size)
    elif args.synthetic_lm:
        n = (args.n // args.shard_size) * args.shard_size or args.shard_size
        if n != args.n:
            print(f"[pack] rounding --n {args.n} -> {n} "
                  f"(whole shards of {args.shard_size})")
        _pack_lm(args.out, args.vocab, args.seq, n, args.shard_size,
                 args.seed, args.branching)
    else:
        _pack_images(args.out, args.n, args.shard_size, args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
