"""Background host→device prefetch.

``PrefetchIterator`` wraps any host batch iterator (typically a
``StreamingLoader``) with a worker thread that stays ``depth`` batches
ahead: it pulls the next host batch, moves it to device
(``jax.device_put`` — optionally through a caller-supplied ``place``
function that applies mesh shardings), and parks it in a bounded queue.
The consumer's ``next()`` then returns an ALREADY-RESIDENT batch, so a
donated train step never waits on host I/O — the only time the step
blocks is when the queue is empty, and that blocked time is measured
and exported as the **input stall** counters the tracker/bench layer
gates on (``benchmarks/bench_data_pipeline.py``: stall ≈ 0 with
prefetch on).

Checkpoint coupling: the worker snapshots ``loader.state`` immediately
after pulling each batch, and the snapshot travels WITH the batch
through the queue — so ``prefetch.state`` after training consumed batch
``t`` is the cursor of batch ``t+1`` even though the loader itself has
already run ahead.  Saving ``prefetch.state`` (not ``loader.state``!)
is what keeps resume exact under prefetch; the launcher and
``checkpoint/io.py`` do exactly that.

Default ``depth=2`` is classic double buffering: one batch in flight to
the device while the step consumes the previous one.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, Optional

__all__ = ["PrefetchIterator", "device_put_batch"]


def device_put_batch(batch, sharding=None):
    """Default placement: ``jax.device_put`` every leaf (with a sharding
    tree or single sharding when given).  On multi-process runs with a
    sharding, the local rows are assembled into the global array via
    ``make_array_from_process_local_data`` — the loader yields each
    process's slice of the global batch."""
    import jax
    if sharding is None:
        return jax.device_put(batch)
    if jax.process_count() > 1:
        return jax.tree.map(
            lambda x: jax.make_array_from_process_local_data(sharding, x),
            batch)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


class _Stop:
    """Queue sentinel: clean exhaustion of the upstream iterator."""


class _Failure:
    def __init__(self, exc: BaseException):
        self.exc = exc


class PrefetchIterator:
    """See module docstring.

    Counters (host wall-clock, cumulative — use ``counters()`` or the
    per-batch ``stall_log``):

      * ``input_stall_s`` — total time ``next()`` spent blocked waiting
        for the queue (the time a train step waited on input);
      * ``prefetch_depth_sum`` — queue occupancy observed at each
        ``next()``, for the average depth readout (a healthy pipeline
        sits near ``depth``; ~0 means the source can't keep up).

    ``place=None`` skips device placement (pure host-side prefetch);
    ``place=device_put_batch`` (default) moves batches to device in the
    worker thread.
    """

    def __init__(self, it: Iterator[Dict[str, Any]], depth: int = 2,
                 place: Optional[Callable[[Any], Any]] = device_put_batch):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._it = it
        self.depth = depth
        self._place = place
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        # state snapshot accompanying the last batch next() yielded: the
        # cursor of the next UNCONSUMED batch (see module docstring)
        self._state = getattr(it, "state", None)
        self.input_stall_s = 0.0
        self.prefetch_depth_sum = 0
        self.n_batches = 0
        self.stall_log: deque = deque()   # (stall_s, depth) per batch
        self._exhausted = False
        self._closed = False
        # a worker _Failure that close() drained before next() saw it:
        # held so the error surfaces exactly once instead of vanishing
        self._pending_error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-prefetch")
        self._thread.start()

    # -- worker ---------------------------------------------------------
    def _worker(self) -> None:
        try:
            while not self._stop.is_set():
                try:
                    batch = next(self._it)
                except StopIteration:
                    self._put(_Stop())
                    return
                state = getattr(self._it, "state", None)
                if self._place is not None:
                    batch = self._place(batch)
                self._put((batch, state))
        except BaseException as e:  # propagate to the consumer
            self._put(_Failure(e))

    def _put(self, item) -> None:
        """Bounded put that aborts promptly when the consumer closes."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    # -- consumer -------------------------------------------------------
    def __iter__(self) -> "PrefetchIterator":
        return self

    def __next__(self):
        if self._exhausted:
            raise StopIteration
        depth_now = self._q.qsize()
        t0 = time.perf_counter()
        # poll rather than block indefinitely: a worker that died WITHOUT
        # parking a sentinel (crashed hard, or aborted its bounded put
        # when close() raced this next()) would otherwise hang the
        # consumer forever on an empty queue
        while True:
            try:
                item = self._q.get(timeout=0.05)
                break
            except queue.Empty:
                if self._stop.is_set():
                    self._exhausted = True
                    raise StopIteration from None
                if not self._thread.is_alive():
                    self._exhausted = True
                    if self._pending_error is not None:
                        err, self._pending_error = self._pending_error, None
                        raise err
                    raise StopIteration from None
        stall = time.perf_counter() - t0
        if isinstance(item, _Stop):
            self._exhausted = True
            raise StopIteration
        if isinstance(item, _Failure):
            self._exhausted = True
            raise item.exc
        batch, state = item
        self._state = state
        self.input_stall_s += stall
        self.prefetch_depth_sum += depth_now
        self.n_batches += 1
        self.stall_log.append((stall, depth_now))
        return batch

    @property
    def state(self):
        """``LoaderState`` of the next unconsumed batch (exact under
        prefetch run-ahead); None when the upstream iterator carries no
        state."""
        return self._state

    def counters(self) -> Dict[str, float]:
        n = max(self.n_batches, 1)
        return {"input_stall_s": self.input_stall_s,
                "input_stall_s_per_step": self.input_stall_s / n,
                "prefetch_depth_avg": self.prefetch_depth_sum / n,
                "prefetch_depth": self.depth,
                "prefetch_batches": self.n_batches}

    def close(self) -> None:
        """Stop the worker, release the upstream iterator, and surface an
        undelivered worker failure exactly once.  Idempotent — a second
        ``close()`` (or one after a failed worker) is a no-op; also runs
        on ``with`` exit."""
        if self._closed:
            return
        self._closed = True
        self._exhausted = True
        self._stop.set()

        def drain():
            # discard buffered batches but KEEP an undelivered _Failure —
            # draining used to throw the worker's error away with them
            try:
                while True:
                    item = self._q.get_nowait()
                    if isinstance(item, _Failure) \
                            and self._pending_error is None:
                        self._pending_error = item.exc
            except queue.Empty:
                pass

        drain()                      # unblock a worker parked on a full queue
        self._thread.join(timeout=5.0)
        drain()                      # the worker may have parked one more
        close = getattr(self._it, "close", None)
        if close is not None:
            close()
        if self._pending_error is not None:
            err, self._pending_error = self._pending_error, None
            raise err

    def __enter__(self) -> "PrefetchIterator":
        return self

    def __exit__(self, *_) -> None:
        self.close()
