"""``DataSource`` — the example-level contract every input pipeline
component speaks.

A source is a HOST-SIDE, sharded, random-access view of a dataset:

  * ``shard_lengths()`` — examples per shard (the unit of shuffling and
    of per-process partitioning in ``loader.StreamingLoader``);
  * ``read(shard, start, count)`` — a dict of numpy arrays, each with a
    leading example dimension, for ``count`` consecutive examples of one
    shard.  Reads are pure: the same (shard, start, count) always
    returns the same bytes, which is what makes the loader's
    ``LoaderState`` sufficient for exact-batch deterministic resume.

Sources never touch devices — host→device movement is the prefetcher's
job (``data.prefetch``) — and never hold iterator state; cursors live in
``LoaderState`` so they can ride the checkpoint.

Implementations in-tree: ``MemorySource`` (in-RAM arrays, below),
``SyntheticLM`` / ``SyntheticImages`` (``data.synthetic``), and
``DiskShardedSource`` over the ``repro-data-pack`` on-disk format
(``data.format``).
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class DataSource(Protocol):
    """Structural protocol — any object with these two methods is a
    source (``isinstance`` works via ``runtime_checkable``)."""

    def shard_lengths(self) -> Tuple[int, ...]:
        """Number of examples in each shard, in shard order."""
        ...

    def read(self, shard: int, start: int, count: int) -> Dict[str, np.ndarray]:
        """``count`` consecutive examples of ``shard`` beginning at
        ``start``: a dict of numpy arrays, each shaped ``(count, ...)``.
        Must raise ``IndexError``/``ValueError`` on out-of-range reads
        rather than silently truncating."""
        ...


def n_examples(source: DataSource) -> int:
    """Total examples per epoch across all shards."""
    return int(sum(source.shard_lengths()))


def check_read_range(lengths: Tuple[int, ...], shard: int, start: int,
                     count: int) -> None:
    """Shared bounds check for ``read`` implementations (loud, never
    truncating — a silent short read would corrupt loader determinism)."""
    if not 0 <= shard < len(lengths):
        raise IndexError(f"shard {shard} out of range (have {len(lengths)})")
    if count < 0 or start < 0 or start + count > lengths[shard]:
        raise ValueError(
            f"read [{start}:{start + count}) out of range for shard "
            f"{shard} of length {lengths[shard]}")


class MemorySource:
    """In-RAM arrays as a (virtually) sharded source.

    ``arrays`` is a dict of equal-leading-length numpy arrays (the
    fields of one example batch); ``shard_size`` slices them into
    virtual shards so shuffling/partitioning behave exactly as they
    would over the on-disk format.  The default is one shard.
    """

    def __init__(self, arrays: Dict[str, np.ndarray],
                 shard_size: Optional[int] = None):
        if not arrays:
            raise ValueError("MemorySource needs at least one field")
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        n = {k: v.shape[0] for k, v in self.arrays.items()}
        if len(set(n.values())) != 1:
            raise ValueError(f"fields disagree on example count: {n}")
        self.n = next(iter(n.values()))
        if self.n == 0:
            raise ValueError("MemorySource needs at least one example")
        step = shard_size or self.n
        if step <= 0:
            raise ValueError(f"shard_size must be positive, got {step}")
        self._bounds = [(s, min(s + step, self.n))
                        for s in range(0, self.n, step)]

    def shard_lengths(self) -> Tuple[int, ...]:
        return tuple(e - s for s, e in self._bounds)

    def read(self, shard: int, start: int, count: int) -> Dict[str, np.ndarray]:
        check_read_range(self.shard_lengths(), shard, start, count)
        s0 = self._bounds[shard][0] + start
        return {k: v[s0:s0 + count] for k, v in self.arrays.items()}
