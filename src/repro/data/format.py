"""``repro-data-pack`` — the on-disk sharded array/token format.

A packed dataset is a directory:

    dataset/
      shard_00000.npz     # one array per field, shape (n_0, *field_shape)
      shard_00001.npz
      ...
      dataset.json        # written LAST = the commit marker

``dataset.json``::

    {"format": 1,
     "fields": {"tokens": {"dtype": "int32", "shape": [128]}, ...},
     "shard_lengths": [1024, 1024, ...],
     "meta": {...}}        # free-form provenance (vocab size, seq len, ...)

Design points:

  * the index file is written last, so a crash mid-pack can never leave
    a directory that LOOKS like a dataset (readers require it);
  * shards are uncompressed ``.npz`` — zip-member reads are cheap and
    sequential, and the loader reads shards mostly front-to-back;
  * extension dtypes (bfloat16, ...) are stored as same-width unsigned
    views with the true dtype recorded per field — the same sidecar
    trick ``checkpoint/io.py`` uses — so any array dtype round-trips
    bit-exactly;
  * shard size is the SHUFFLE GRANULARITY: ``StreamingLoader`` permutes
    shard order per epoch but reads within a shard sequentially, so
    pack with small shards (hundreds–thousands of examples) for good
    mixing.

``pack_dataset`` packs in-memory arrays; ``DataPackWriter`` streams
example batches of unknown total length; ``python -m repro.data.pack``
is the CLI around both.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.data.source import check_read_range

PACK_FORMAT = 1
INDEX_NAME = "dataset.json"


def _np_savable(dt: np.dtype) -> bool:
    """True iff the .npy descr string round-trips this dtype (extension
    dtypes like bfloat16 silently degrade to void records otherwise)."""
    import warnings
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            descr = np.lib.format.dtype_to_descr(dt)
            return np.lib.format.descr_to_dtype(descr) == dt
    except Exception:
        return False


def _dtype_by_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; owns bfloat16/float8_* dtypes
        return np.dtype(getattr(ml_dtypes, name))


def shard_name(i: int) -> str:
    return f"shard_{i:05d}.npz"


class DataPackWriter:
    """Streaming pack writer: feed example batches with ``add``; shards
    of ``shard_size`` examples are flushed as they fill and the index is
    committed by ``close()`` (or the ``with`` exit).  A directory with
    no ``dataset.json`` is an aborted pack and is refused by readers."""

    def __init__(self, out_dir: str, shard_size: int = 1024,
                 meta: Optional[Dict[str, Any]] = None):
        if shard_size <= 0:
            raise ValueError(f"shard_size must be positive, got {shard_size}")
        if os.path.exists(os.path.join(out_dir, INDEX_NAME)):
            raise ValueError(f"{out_dir!r} already holds a packed dataset; "
                             f"refusing to overwrite")
        os.makedirs(out_dir, exist_ok=True)
        self.out_dir = out_dir
        self.shard_size = shard_size
        self.meta = dict(meta or {})
        self._fields: Optional[Dict[str, Dict[str, Any]]] = None
        self._buf: Dict[str, list] = {}
        self._buffered = 0
        self._shard_lengths: list = []
        self._closed = False

    def add(self, batch: Dict[str, np.ndarray]) -> None:
        batch = {k: np.asarray(v) for k, v in batch.items()}
        ns = {k: v.shape[0] for k, v in batch.items()}
        if len(set(ns.values())) != 1:
            raise ValueError(f"fields disagree on example count: {ns}")
        fields = {k: {"dtype": v.dtype.name, "shape": list(v.shape[1:])}
                  for k, v in batch.items()}
        if self._fields is None:
            self._fields = fields
            self._buf = {k: [] for k in fields}
        elif fields != self._fields:
            raise ValueError(f"batch schema {fields} != first batch's "
                             f"{self._fields}")
        for k, v in batch.items():
            self._buf[k].append(v)
        self._buffered += next(iter(ns.values()))
        while self._buffered >= self.shard_size:
            self._flush(self.shard_size)

    def _flush(self, n: int) -> None:
        if n == 0:
            return
        cat = {k: np.concatenate(v) if len(v) > 1 else v[0]
               for k, v in self._buf.items()}
        out, keep = {}, {}
        for k, v in cat.items():
            out[k], keep[k] = v[:n], [v[n:]]
        arrays = {}
        for k, a in out.items():
            if not _np_savable(a.dtype):
                a = a.view(f"uint{8 * a.dtype.itemsize}")
            arrays[k] = a
        np.savez(os.path.join(self.out_dir,
                              shard_name(len(self._shard_lengths))), **arrays)
        self._shard_lengths.append(n)
        self._buf = keep
        self._buffered -= n

    def close(self) -> str:
        """Flush the tail shard and commit the index; returns the index
        path.  Idempotent."""
        if self._closed:
            return os.path.join(self.out_dir, INDEX_NAME)
        if self._fields is None or (not self._shard_lengths
                                    and self._buffered == 0):
            raise ValueError("nothing packed: add at least one example")
        self._flush(self._buffered)
        index = {"format": PACK_FORMAT, "fields": self._fields,
                 "shard_lengths": self._shard_lengths, "meta": self.meta}
        with open(os.path.join(self.out_dir, INDEX_NAME), "w") as f:
            json.dump(index, f, indent=1, sort_keys=True)
        self._closed = True
        return os.path.join(self.out_dir, INDEX_NAME)

    def __enter__(self) -> "DataPackWriter":
        return self

    def __exit__(self, exc_type, *_) -> None:
        if exc_type is None:
            self.close()


def pack_dataset(out_dir: str, arrays: Dict[str, np.ndarray],
                 shard_size: int = 1024,
                 meta: Optional[Dict[str, Any]] = None) -> str:
    """Pack in-memory arrays (dict of equal-leading-length fields) into
    ``out_dir``; returns the committed index path."""
    with DataPackWriter(out_dir, shard_size=shard_size, meta=meta) as w:
        w.add(arrays)
    return os.path.join(out_dir, INDEX_NAME)


def pack_iterable(out_dir: str, batches: Iterable[Dict[str, np.ndarray]],
                  shard_size: int = 1024,
                  meta: Optional[Dict[str, Any]] = None) -> str:
    """Pack a stream of example batches of unknown total length."""
    with DataPackWriter(out_dir, shard_size=shard_size, meta=meta) as w:
        for b in batches:
            w.add(b)
    return os.path.join(out_dir, INDEX_NAME)


class DiskShardedSource:
    """``DataSource`` over a ``repro-data-pack`` directory.

    Reads are served from per-shard ``NpzFile`` handles with a tiny
    (2-entry) cache — the loader's access pattern is sequential within a
    shard, so at most the current and next shard stay open.  Extension
    dtypes are viewed back through the per-field dtype record, so reads
    return bit-exact arrays.
    """

    _CACHE = 2

    def __init__(self, path: str):
        index_p = os.path.join(path, INDEX_NAME)
        if not os.path.exists(index_p):
            raise FileNotFoundError(
                f"{path!r} is not a packed dataset (no {INDEX_NAME}; an "
                f"aborted pack leaves no index — re-run the packer)")
        with open(index_p) as f:
            index = json.load(f)
        if index.get("format") != PACK_FORMAT:
            raise ValueError(f"{index_p}: unknown pack format "
                             f"{index.get('format')!r} (this reader "
                             f"understands {PACK_FORMAT})")
        self.path = path
        self.fields: Dict[str, Dict[str, Any]] = index["fields"]
        self._lengths = tuple(int(n) for n in index["shard_lengths"])
        self.meta: Dict[str, Any] = index.get("meta", {})
        self._open: Dict[int, Any] = {}

    def shard_lengths(self) -> Tuple[int, ...]:
        return self._lengths

    def _shard(self, i: int):
        if i not in self._open:
            if len(self._open) >= self._CACHE:
                self._open.pop(next(iter(self._open))).close()
            self._open[i] = np.load(os.path.join(self.path, shard_name(i)))
        return self._open[i]

    def read(self, shard: int, start: int, count: int) -> Dict[str, np.ndarray]:
        check_read_range(self._lengths, shard, start, count)
        data = self._shard(shard)
        out = {}
        for k, spec in self.fields.items():
            a = data[k][start:start + count]
            want = _dtype_by_name(spec["dtype"])
            if a.dtype != want:
                a = a.view(want)
            out[k] = a
        return out

    def close(self) -> None:
        for f in self._open.values():
            f.close()
        self._open.clear()
