"""repro.data — the input pipeline subsystem.

Layers, bottom to top:

  * ``source``   — the ``DataSource`` protocol (sharded, host-side,
                   random-access examples) + ``MemorySource``;
  * ``synthetic``— deterministic synthetic sources (``SyntheticLM``
                   bigram language, ``synthetic_images`` CIFAR proxy);
  * ``format``   — the ``repro-data-pack`` on-disk sharded format
                   (``pack_dataset``/``DataPackWriter`` writers,
                   ``DiskShardedSource`` reader; CLI:
                   ``python -m repro.data.pack``);
  * ``loader``   — ``StreamingLoader``: per-process sharded batches,
                   seekable via the serializable ``LoaderState`` that
                   rides the checkpoint (exact-batch resume);
  * ``prefetch`` — ``PrefetchIterator``: background host→device
                   prefetch (double-buffered) with input-stall and
                   queue-depth counters.

README "Data pipeline & resumable input" documents the contracts.
"""
from repro.data.format import (DataPackWriter, DiskShardedSource,
                               pack_dataset, pack_iterable)
from repro.data.loader import LoaderState, StreamingLoader
from repro.data.prefetch import PrefetchIterator, device_put_batch
from repro.data.source import DataSource, MemorySource, n_examples
from repro.data.synthetic import (SyntheticLM, synthetic_images,
                                  synthetic_images_source)

__all__ = [
    "DataSource", "MemorySource", "n_examples",
    "SyntheticLM", "synthetic_images", "synthetic_images_source",
    "DataPackWriter", "DiskShardedSource", "pack_dataset", "pack_iterable",
    "LoaderState", "StreamingLoader",
    "PrefetchIterator", "device_put_batch",
]
