from repro.data.synthetic import SyntheticLM, synthetic_images

__all__ = ["SyntheticLM", "synthetic_images"]
