"""``StreamingLoader`` — a per-process, sharded, seekable batch stream.

The loader turns a ``DataSource`` into an infinite (or epoch-bounded)
stream of host-side numpy batches, with three properties the training
stack depends on:

  * **per-process sharding** — with ``process_count`` processes, process
    ``p`` owns source shards ``p, p+P, p+2P, ...`` (round-robin) and
    yields the LOCAL ``batch_size / process_count`` rows of every global
    batch; the global batch is the concatenation across processes, in
    process order, which is exactly the batch-axis layout
    ``sharding/rules.batch_spec`` shards over the data mesh axes.
  * **determinism** — shard order is permuted per epoch from a fixed rng
    key (``jax.random.fold_in(key, epoch)``); within a shard reads are
    sequential, so the shard is the shuffle granularity (pack with small
    shards for mixing).  Batch ``t`` is a pure function of
    (source, batch size, key, process layout).
  * **seekability** — the full iterator position is a four-field
    ``LoaderState`` (epoch, shard cursor, within-shard offset, rng key).
    ``loader.state`` after consuming batch ``t`` describes batch
    ``t+1``; constructing a loader with ``state=`` (or calling
    ``seek``) resumes so that the next batch is BITWISE the batch an
    uninterrupted run would have produced.  The state is JSON-trivial
    and rides the checkpoint (``checkpoint/io.py`` ``loader_state``).

Epoch tails smaller than one local batch are dropped (classic
``drop_last``) and batches never mix epochs, so every yielded batch has
a fixed shape — a jit-stability requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.source import DataSource


@dataclasses.dataclass
class LoaderState:
    """Serializable cursor of a ``StreamingLoader``: everything needed
    to reproduce the rest of the stream bit-for-bit.  ``key`` is the
    base rng key's raw uint32 pair (the per-epoch permutation derives
    from it; storing the base key keeps every future epoch exact)."""
    epoch: int = 0
    shard_cursor: int = 0
    offset: int = 0
    key: Tuple[int, int] = (0, 0)

    def to_dict(self) -> Dict[str, Any]:
        return {"epoch": int(self.epoch),
                "shard_cursor": int(self.shard_cursor),
                "offset": int(self.offset),
                "key": [int(self.key[0]), int(self.key[1])]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LoaderState":
        missing = {"epoch", "shard_cursor", "offset", "key"} - set(d)
        if missing:
            raise ValueError(f"loader state missing fields {sorted(missing)}")
        return cls(epoch=int(d["epoch"]), shard_cursor=int(d["shard_cursor"]),
                   offset=int(d["offset"]),
                   key=(int(d["key"][0]), int(d["key"][1])))


def _key_data(seed: int) -> Tuple[int, int]:
    import jax
    k = jax.random.key_data(jax.random.PRNGKey(seed))
    return int(k[0]), int(k[1])


def _epoch_perm(key: Tuple[int, int], epoch: int, n: int) -> np.ndarray:
    """Permutation of ``n`` local shards for ``epoch``, derived from the
    base key — host-side numpy; stable across jax versions by using a
    plain SeedSequence over (key, epoch)."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([key[0], key[1], epoch])))
    return rng.permutation(n)


class StreamingLoader:
    """See module docstring.  ``batch_size`` is the GLOBAL batch; the
    loader yields this process's ``batch_size // process_count`` rows.

    ``max_epochs=None`` streams forever (training bounds the run by
    steps); an int raises ``StopIteration`` once that many epochs are
    exhausted.  ``shuffle=False`` keeps shard order fixed — useful for
    evaluation sweeps.
    """

    def __init__(self, source: DataSource, batch_size: int, *,
                 seed: int = 0, shuffle: bool = True,
                 max_epochs: Optional[int] = None,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None,
                 state: Optional[LoaderState] = None):
        import jax
        P = process_count if process_count is not None else jax.process_count()
        p = process_index if process_index is not None else jax.process_index()
        if not 0 <= p < P:
            raise ValueError(f"process_index {p} out of range for {P}")
        if batch_size % P:
            raise ValueError(f"global batch {batch_size} must divide across "
                             f"{P} processes")
        self.source = source
        self.batch_size = batch_size
        self.local_batch = batch_size // P
        self.shuffle = shuffle
        self.max_epochs = max_epochs
        lengths = tuple(source.shard_lengths())
        self._my_shards = tuple(range(p, len(lengths), P))
        self._my_lengths = tuple(lengths[s] for s in self._my_shards)
        if not self._my_shards:
            raise ValueError(f"process {p}/{P} owns no shards "
                             f"({len(lengths)} total); pack more shards")
        if sum(self._my_lengths) < self.local_batch:
            raise ValueError(
                f"process {p} owns {sum(self._my_lengths)} examples < local "
                f"batch {self.local_batch}; every epoch would be empty")
        self._st = state if state is not None \
            else LoaderState(key=_key_data(seed))
        self._st = dataclasses.replace(self._st)   # private copy
        self._perm_epoch: Optional[int] = None
        self._perm: Optional[np.ndarray] = None

    # -- state ----------------------------------------------------------
    @property
    def state(self) -> LoaderState:
        """The cursor of the NEXT batch (snapshot — safe to serialize)."""
        return dataclasses.replace(self._st)

    def seek(self, state: LoaderState) -> None:
        self._st = dataclasses.replace(state)
        self._perm_epoch = None

    # -- iteration ------------------------------------------------------
    def _order(self, epoch: int) -> np.ndarray:
        """This epoch's local-shard visit order (cached per epoch)."""
        if self._perm_epoch != epoch:
            n = len(self._my_shards)
            self._perm = (_epoch_perm(self._st.key, epoch, n)
                          if self.shuffle else np.arange(n))
            self._perm_epoch = epoch
        return self._perm

    def _advance_epoch(self) -> None:
        self._st.epoch += 1
        self._st.shard_cursor = 0
        self._st.offset = 0
        if self.max_epochs is not None and self._st.epoch >= self.max_epochs:
            raise StopIteration

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        st = self._st
        if self.max_epochs is not None and st.epoch >= self.max_epochs:
            raise StopIteration
        parts = []
        need = self.local_batch
        while need > 0:
            order = self._order(st.epoch)
            if st.shard_cursor >= len(order):
                # epoch exhausted mid-batch: drop the tail (drop_last)
                # and start the batch over in the next epoch — batches
                # never mix epochs, so shapes stay jit-stable
                parts, need = [], self.local_batch
                self._advance_epoch()
                continue
            local = int(order[st.shard_cursor])
            length = self._my_lengths[local]
            take = min(need, length - st.offset)
            if take > 0:
                part = self.source.read(self._my_shards[local],
                                        st.offset, take)
                parts.append(part)
                st.offset += take
                need -= take
            if st.offset >= length:
                st.shard_cursor += 1
                st.offset = 0
        if len(parts) == 1:
            batch = {k: np.asarray(v) for k, v in parts[0].items()}
        else:
            batch = {k: np.concatenate([p[k] for p in parts])
                     for k in parts[0]}
        for k, v in batch.items():
            if v.shape[0] != self.local_batch:
                raise ValueError(f"source returned short read for {k!r}: "
                                 f"{v.shape[0]} != {self.local_batch}")
        return batch

    # -- bookkeeping ----------------------------------------------------
    def batches_per_epoch(self) -> int:
        """Batches this process yields per epoch (drop_last floor).  In
        a multi-process run every process must agree — i.e. shards
        should balance across processes — or the collective would hang;
        the launcher asserts this via ``min``/``max`` over processes at
        startup on real multi-host runs."""
        return sum(self._my_lengths) // self.local_batch

    def close(self) -> None:
        close = getattr(self.source, "close", None)
        if close is not None:
            close()
