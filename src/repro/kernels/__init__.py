"""repro.kernels — Pallas kernels for the optimizer/attention hot spots.

Each kernel package is <name>/{kernel,ops,ref}.py: the Pallas kernel, the
backend-dispatching wrapper, and the jnp oracle used by tests.

Also home to the kernel-launch counter: every ops-layer wrapper calls
``record_launches(n)`` at TRACE time, so tracing one optimizer step inside
``count_pallas_launches()`` reports exactly how many ``pallas_call``s that
step will issue per execution (the number bench_optimizer_overhead.py uses
to show O(1) multi-tensor launches vs O(n_leaves) per-leaf launches).
"""
from __future__ import annotations

import contextlib

_LAUNCHES = {"n": 0}


def record_launches(n: int = 1) -> None:
    """Called by ops wrappers once per pallas_call they trace."""
    _LAUNCHES["n"] += n


@contextlib.contextmanager
def count_pallas_launches():
    """Count pallas_call sites traced inside the block.

        with count_pallas_launches() as c:
            jax.jit(opt.step).lower(g, state, p)
        print(c["launches"])   # kernel launches per executed step
    """
    start = _LAUNCHES["n"]
    box = {"launches": 0}
    try:
        yield box
    finally:
        box["launches"] = _LAUNCHES["n"] - start
