"""jit'd wrapper: Pallas flash attention on TPU, interpret mode elsewhere."""
import jax

from repro.kernels.flash_attention.kernel import flash_attention


def attention(q, k, v, **kw):
    return flash_attention(q, k, v, interpret=jax.default_backend() != "tpu",
                           **kw)
