"""Flash attention — Pallas TPU kernel (causal / sliding-window / softcap).

Canonical TPU blocking: grid = (batch*q_heads, n_q_blocks, n_kv_blocks)
with the KV dimension innermost.  Running max / denominator / accumulator
live in VMEM scratch across the KV loop; the output block is finalized
when the last KV block for a given Q block retires.  Block sizes are
MXU-aligned (q 256 x kv 512 x head_dim padded to a 128 multiple); fully
masked KV blocks (beyond the causal frontier or the sliding window) are
skipped with ``pl.when``.

GQA folds the q->kv head mapping into the k/v BlockSpec index maps, so
K/V are never materialized per-q-head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLK = 256
DEFAULT_KV_BLK = 512
NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, q_blk, kv_blk, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_blk
    k_start = ki * kv_blk

    # block-level skip: entirely above the diagonal, or entirely out-of-window
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + q_blk - 1
    if window > 0:
        run &= k_start + kv_blk - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (q_blk, hd)
        k = k_ref[0].astype(jnp.float32)          # (kv_blk, hd)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        ids_q = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ids_k = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= ids_k <= ids_q
        if window > 0:
            mask &= ids_k > ids_q - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (q_blk, kv_blk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_blk", "kv_blk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_blk: int = DEFAULT_Q_BLK,
                    kv_blk: int = DEFAULT_KV_BLK, interpret: bool = False):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0.
    Returns (B, S, H, hd) in q.dtype."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    group = H // K
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    assert S % q_blk == 0 and S % kv_blk == 0, (S, q_blk, kv_blk)
    hd_pad = -hd % 128
    scale = hd ** -0.5

    qt = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * K, S, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * K, S, hd)
    if hd_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, hd_pad)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, hd_pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, hd_pad)))
    hdp = hd + hd_pad
    n_q = S // q_blk
    n_kv = S // kv_blk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, q_blk=q_blk, kv_blk=kv_blk,
                          n_kv=n_kv),
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_blk, hdp), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_blk, hdp),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, kv_blk, hdp),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hdp), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hdp), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[..., :hd].reshape(B, H, S, hd)
    return jnp.moveaxis(out, 1, 2)
