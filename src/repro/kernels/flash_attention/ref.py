"""Pure-jnp oracle: dense softmax attention with causal/window/softcap."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qf * hd ** -0.5,
                   k.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window > 0:
        ok &= j > i - window
    s = jnp.where(ok, s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
