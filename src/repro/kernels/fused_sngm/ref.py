"""Pure-jnp oracle for the fused SNGM update kernel."""
import jax.numpy as jnp


def sngm_update_ref(p, g, u, inv_norm, lr, *, beta: float):
    u_new = beta * u + g.astype(jnp.float32) * inv_norm
    p_new = p - lr * u_new
    return p_new, u_new
