"""Fused SNGM update — Pallas TPU kernel.

The SNGM update (Algorithm 1) is a pure HBM-bandwidth operation over the
parameter/momentum trees:

    u <- beta * u + g * (1/||g||)
    p <- p - lr * u

A naive XLA lowering reads/writes each tensor in 3-4 passes (scale, add,
axpy); the fused kernel does ONE read of (p, g, u) and ONE write of
(p, u) per VMEM tile — the optimizer's HBM traffic drops from ~7x to the
5x minimum.  Scalars (inv_norm, lr) arrive via SMEM so one compiled kernel
serves every step.

Tiling: leaves are raveled, padded to ROWS*128 and viewed as (n, 128);
the grid walks row-blocks of ROWS (8 sublanes x 128 lanes aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS = 256          # rows per block -> 256*128*4B = 128 KiB/operand in VMEM
LANES = 128


def _kernel(scal_ref, p_ref, g_ref, u_ref, po_ref, uo_ref, *, beta):
    inv = scal_ref[0]
    lr = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)
    u = beta * u_ref[...] + g * inv
    uo_ref[...] = u
    po_ref[...] = p_ref[...] - lr * u


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def fused_sngm_update(p, g, u, inv_norm, lr, *, beta: float,
                      interpret: bool = False):
    """One leaf: returns (p_new, u_new); p,u float32; g any float dtype."""
    shape = p.shape
    n = p.size
    block = ROWS * LANES
    n_pad = -n % block
    pf = jnp.pad(p.ravel(), (0, n_pad)).reshape(-1, LANES)
    gf = jnp.pad(g.ravel(), (0, n_pad)).reshape(-1, LANES)
    uf = jnp.pad(u.ravel(), (0, n_pad)).reshape(-1, LANES)
    scal = jnp.stack([inv_norm.astype(jnp.float32), lr.astype(jnp.float32)])
    grid = pf.shape[0] // ROWS

    tile = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    po, uo = pl.pallas_call(
        functools.partial(_kernel, beta=beta),
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct(pf.shape, jnp.float32),
                   jax.ShapeDtypeStruct(pf.shape, jnp.float32)],
        interpret=interpret,
    )(scal, pf, gf, uf)
    return (po.ravel()[:n].reshape(shape), uo.ravel()[:n].reshape(shape))
