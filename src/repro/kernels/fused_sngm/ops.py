"""jit'd tree-level wrapper used by ``repro.core.optim.sngm(use_pallas=True)``.

On non-TPU backends the kernel runs in interpret mode (correctness path);
numerics match ref.py / the jnp optimizer exactly (float32 math).
"""
from __future__ import annotations

import jax

from repro.kernels import record_launches
from repro.kernels.fused_sngm.kernel import fused_sngm_update


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_sngm_tree(params, grads, momentum, inv_norm, beta: float, lr):
    interp = _interpret()
    new_p, new_u = {}, {}
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_u = jax.tree_util.tree_leaves(momentum)
    ps, us = [], []
    for (path, p), g, u in zip(flat_p, flat_g, flat_u):
        record_launches(1)
        pn, un = fused_sngm_update(p, g, u, inv_norm, lr, beta=beta,
                                   interpret=interp)
        ps.append(pn)
        us.append(un)
    return (jax.tree_util.tree_unflatten(treedef, ps),
            jax.tree_util.tree_unflatten(treedef, us))
