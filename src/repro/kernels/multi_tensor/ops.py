"""Backend-dispatching wrappers for the multi-tensor kernels.

On non-TPU backends the kernels run in interpret mode (correctness path);
``backend="ref"`` bypasses Pallas entirely with the bit-identical jnp
oracle.  Launch counts are recorded at trace time for the overhead
benchmark — note the ref backend records zero.

``lane_pad`` (default: the ``REPRO_MT_LANE_PAD`` env switch) pads the
coefficient/partial blocks to the TPU lane width for Mosaic builds that
reject the (rows, 1) layout; results are bitwise-identical either way
(see kernel.py).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import record_launches
from repro.kernels.multi_tensor import kernel, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lane_pad(lane_pad: Optional[bool]) -> bool:
    return kernel._lane_pad_default() if lane_pad is None else lane_pad


def chunk_sumsq(x, p=None, *, wd: float = 0.0, backend: str = "pallas",
                lane_pad: Optional[bool] = None):
    if backend == "ref":
        return ref.chunk_sumsq_ref(x, p, wd=wd)
    record_launches(1)
    return kernel.chunk_sumsq(x, p, wd=wd, interpret=_interpret(),
                              lane_pad=_lane_pad(lane_pad))


def fused_update(p, g, u, a_chunk, c, *, beta: float, wd: float,
                 cast_g_first: bool = False, nesterov: bool = False,
                 apply: bool = True, backend: str = "pallas",
                 lane_pad: Optional[bool] = None):
    if backend == "ref":
        return ref.fused_update_ref(p, g, u, a_chunk, c, beta=beta, wd=wd,
                                    cast_g_first=cast_g_first,
                                    nesterov=nesterov, apply=apply)
    record_launches(1)
    return kernel.fused_update(p, g, u, a_chunk, c, beta=beta, wd=wd,
                               cast_g_first=cast_g_first, nesterov=nesterov,
                               apply=apply, interpret=_interpret(),
                               lane_pad=_lane_pad(lane_pad))


def scale_apply(p, g, a_chunk, c, *, backend: str = "pallas",
                lane_pad: Optional[bool] = None):
    if backend == "ref":
        return ref.scale_apply_ref(p, g, a_chunk, c)
    record_launches(1)
    return kernel.scale_apply(p, g, a_chunk, c, interpret=_interpret(),
                              lane_pad=_lane_pad(lane_pad))


def adam_update(p, g, m, v, bc1, bc2, *, b1: float, b2: float, eps: float,
                wd: float = 0.0, backend: str = "pallas",
                lane_pad: Optional[bool] = None):
    if backend == "ref":
        return ref.adam_update_ref(p, g, m, v, bc1, bc2, b1=b1, b2=b2,
                                   eps=eps, wd=wd)
    record_launches(1)
    return kernel.adam_update(p, g, m, v, bc1, bc2, b1=b1, b2=b2,
                              eps=eps, wd=wd, interpret=_interpret(),
                              lane_pad=_lane_pad(lane_pad))
