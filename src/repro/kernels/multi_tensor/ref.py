"""Pure-jnp oracles for the multi-tensor kernels.

Expression-for-expression mirrors of ``kernel.py`` on the same
(n_chunks, CHUNK) view, so kernel-vs-ref comparisons are bitwise (every
op is per-row; tiling rows into grid steps cannot change the result).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.multi_tensor.kernel import CHUNK, _decay


def chunk_sumsq_ref(x, p=None, *, wd: float = 0.0):
    x2 = x.reshape(-1, CHUNK)
    if p is None or wd == 0.0:
        ge = x2.astype(jnp.float32)
    else:
        ge = _decay(x2, p.reshape(-1, CHUNK), wd=wd, cast_g_first=False)
    return jnp.sum(jnp.square(ge), axis=1)


def fused_update_ref(p, g, u, a_chunk, c, *, beta: float, wd: float,
                     cast_g_first: bool = False, nesterov: bool = False,
                     apply: bool = True):
    p2 = p.reshape(-1, CHUNK)
    ge = _decay(g.reshape(-1, CHUNK), p2, wd=wd, cast_g_first=cast_g_first)
    a = a_chunk.reshape(-1, 1)
    u_new = beta * u.reshape(-1, CHUNK) + a * ge
    out = beta * u_new + a * ge if nesterov else u_new
    if apply:
        first = (p2 - jnp.asarray(c, jnp.float32) * out).astype(p.dtype)
    else:
        first = out
    usq = jnp.sum(jnp.square(out), axis=1)
    return first.ravel(), u_new.ravel(), usq


def scale_apply_ref(p, g, a_chunk, c):
    p2 = p.reshape(-1, CHUNK)
    s = a_chunk.reshape(-1, 1) * g.reshape(-1, CHUNK)
    p_new = (p2 - jnp.asarray(c, jnp.float32) * s).astype(p.dtype)
    return p_new.ravel(), jnp.sum(jnp.square(s), axis=1)


def adam_update_ref(p, g, m, v, bc1, bc2, *, b1: float, b2: float,
                    eps: float, wd: float = 0.0):
    p2 = p.reshape(-1, CHUNK)
    g32 = g.reshape(-1, CHUNK).astype(jnp.float32)
    gsq = jnp.sum(jnp.square(g32), axis=1)
    m_new = b1 * m.reshape(-1, CHUNK) + (1 - b1) * g32
    v_new = b2 * v.reshape(-1, CHUNK) + (1 - b2) * jnp.square(g32)
    u = (m_new / jnp.asarray(bc1, jnp.float32)) / \
        (jnp.sqrt(v_new / jnp.asarray(bc2, jnp.float32)) + eps)
    if wd != 0.0:
        u = u + wd * p2
    usq = jnp.sum(jnp.square(u), axis=1)
    psq = jnp.sum(jnp.square(p2.astype(jnp.float32)), axis=1)
    return (m_new.ravel(), v_new.ravel(), u.ravel(), usq, psq, gsq)
