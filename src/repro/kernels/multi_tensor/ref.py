"""Pure-jnp oracles for the multi-tensor kernels.

Expression-for-expression mirrors of ``kernel.py`` on the same
(n_chunks, CHUNK) view, so kernel-vs-ref comparisons are bitwise (every
op is per-row; tiling rows into grid steps cannot change the result).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.multi_tensor.kernel import CHUNK, _decay


def chunk_sumsq_ref(x, p=None, *, wd: float = 0.0):
    x2 = x.reshape(-1, CHUNK)
    if p is None or wd == 0.0:
        ge = x2.astype(jnp.float32)
    else:
        ge = _decay(x2, p.reshape(-1, CHUNK), wd=wd, cast_g_first=False)
    return jnp.sum(jnp.square(ge), axis=1)


def fused_update_ref(p, g, u, a_chunk, c, *, beta: float, wd: float,
                     cast_g_first: bool = False):
    p2 = p.reshape(-1, CHUNK)
    ge = _decay(g.reshape(-1, CHUNK), p2, wd=wd, cast_g_first=cast_g_first)
    a = a_chunk.reshape(-1, 1)
    u_new = beta * u.reshape(-1, CHUNK) + a * ge
    p_new = (p2 - jnp.asarray(c, jnp.float32) * u_new).astype(p.dtype)
    usq = jnp.sum(jnp.square(u_new), axis=1)
    return p_new.ravel(), u_new.ravel(), usq
