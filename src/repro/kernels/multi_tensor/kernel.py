"""Multi-tensor fused optimizer kernels — Pallas TPU.

The per-leaf kernels (``fused_sngm``, ``fused_lars``) launch one kernel
per parameter tensor, so optimizer overhead grows with tree size.  These
kernels instead operate on ONE dtype-bucketed flat buffer holding every
leaf (built by ``repro.core.multi_tensor``), giving O(1) launches per
optimizer step:

  pass 1  ``chunk_sumsq``   — squared-norm partials, one f32 per CHUNK-sized
                              row of the buffer.  Segment (= per-tensor) and
                              global norms are tiny reductions over these
                              partials; because every segment starts on a
                              CHUNK boundary the per-segment results are
                              bit-identical to a per-leaf chunked reduction.
  pass 2  ``fused_update``  — momentum + apply for the whole buffer, with a
                              per-chunk normalization coefficient ``a`` (a
                              broadcast scalar for SNGM's global norm, a
                              per-segment scalar for SNGM[per_tensor]/LARS,
                              1 for MSGD).  Also emits sumsq partials of the
                              new momentum so ``update_norm`` stats need no
                              third pass.

One (a, c, wd, beta, cast_g_first) parameterization covers the four
momentum optimizers:

    u_new = beta * u + a * decay(g, p)        decay = g + wd*p (coupled wd)
    p_new = (p - c * u_new).astype(p.dtype)

    sngm             a = 1/(||g_dec||+eps)  broadcast        c = lr
    sngm[per_tensor] a = 1/(||g_dec||_seg+eps) per segment   c = lr
    lars             a = lr * local_lr_seg  per segment      c = 1
    msgd             a = 1                                   c = lr

LAMB/Adam adds two kernels.  ``adam_update`` (one launch per bucket)
advances both fp32 Adam moments, materializes the bias-corrected (and
decoupled-weight-decayed) direction ``u``, and emits per-chunk sumsq
partials of ``u``, ``p`` and ``g`` — so the host can form the
per-segment trust ratios and the stats norms without extra passes.
``scale_apply`` (the second launch) scales by the per-segment ratio and
applies, emitting the scaled direction's sumsq partials (the
``update_norm`` stat) — no momentum operand, no dead outputs:

    u     = m_hat / (sqrt(v_hat) + eps) + wd * p     (adam_update)
    p_new = (p - lr * (ratio_seg * u)).astype(p.dtype)  (scale_apply)

Clip-prefixed chains add a raw-norm ``chunk_sumsq`` round BEFORE these
kernels; the host then rescales the flat gradient buffers with the
interpreter's exact clip expression (a fused jnp elementwise op, zero
extra launches) and runs the unchanged passes on the clipped buffers —
see ``core.multi_tensor``.  The kernels themselves are clip-agnostic,
which keeps their op graphs (and therefore their last-ulp contraction
behaviour under XLA fusion) byte-stable across all chain variants.

Layout: buffers are viewed as (n_chunks, CHUNK) rows; the grid walks
tiles of TILE_ROWS rows.  Coefficients/partials ride in (TILE_ROWS, 1)
blocks — fine in interpret mode and on recent Mosaic (last-dim-1 gets a
masked relayout).  For a target TPU whose Mosaic build rejects the
last-dim-1 layout, set ``lane_pad=True`` (or export
``REPRO_MT_LANE_PAD=1``): coefficient/partial blocks are padded to the
full lane width (``LANE=128``) — the coefficient is replicated across
lanes on the host, partials are broadcast-stored across lanes in the
kernel and lane 0 is sliced back out — with bitwise-identical results
(each lane carries the same f32 value; asserted in
tests/test_multi_tensor.py).

In-place residency: the update passes declare ``input_output_aliases``
(p->p_new, u->u_new, m->m_new, v->v_new), so when the caller's buffers
are donated (the ``TrainState`` train step jitted with
``donate_argnums``) XLA updates the resident flat buffers in place
instead of double-buffering them; when an input is still live elsewhere
XLA inserts the copy itself, so numerics and non-donated callers are
unaffected.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 1024        # elements per row == per-coefficient granularity
TILE_ROWS = 64      # rows per grid step: 64*1024*4B = 256 KiB f32 per operand
TILE = TILE_ROWS * CHUNK
LANE = 128          # TPU lane width: coefficient-block width under lane_pad


def _lane_pad_default() -> bool:
    """Env-switchable default for the lane-width padding of coefficient /
    partial blocks (real-TPU Mosaic builds that reject (rows, 1))."""
    return os.environ.get("REPRO_MT_LANE_PAD", "0").lower() not in (
        "0", "", "false")


def _coeff_width(lane_pad: bool) -> int:
    return LANE if lane_pad else 1


def _expand_coeff(a: jnp.ndarray, lane_pad: bool) -> jnp.ndarray:
    """Host-side: (n_chunks,) f32 -> the (n_chunks, width) block the kernel
    reads.  Lane replication keeps every lane bit-identical to lane 0."""
    col = a.reshape(-1, 1)
    if not lane_pad:
        return col
    return jnp.broadcast_to(col, (col.shape[0], LANE))


def _store_partial(ref, s: jnp.ndarray) -> None:
    """Kernel-side: store a (rows, 1) partial into a (rows, width) block,
    broadcasting the value across lanes when lane-padded."""
    ref[...] = jnp.broadcast_to(s, ref.shape)


def _partials_out(out: jnp.ndarray) -> jnp.ndarray:
    """Host-side: (n_chunks, width) partial block -> (n_chunks,) lane 0."""
    return out[:, 0]


def _tile_rows(n_chunks: int, interpret: bool) -> int:
    """Grid tiling: TILE_ROWS rows per step on TPU (VMEM-bounded); the whole
    buffer in ONE grid step under interpret mode, where each extra grid step
    costs a full-buffer dynamic-update-slice instead of a VMEM tile swap.
    Per-row math is identical either way, so numerics don't change."""
    return n_chunks if interpret else TILE_ROWS


def _decay(g, p, *, wd: float, cast_g_first: bool):
    """g + wd*p in f32, replicating the reference paths' cast order exactly:
    SNGM/MSGD decay in the gradient dtype then cast (``_decayed``); LARS
    casts the gradient first.  wd == 0 must be a true no-op (not ``+0*p``,
    which flips the sign of -0.0)."""
    if wd == 0.0:
        return g.astype(jnp.float32)
    if cast_g_first:
        return g.astype(jnp.float32) + wd * p
    return (g + wd * p).astype(jnp.float32)


# ---------------------------------------------------------------------------
# pass 1: squared-norm partials
# ---------------------------------------------------------------------------

def _sumsq_raw_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    _store_partial(o_ref, jnp.sum(jnp.square(x), axis=1, keepdims=True))


def _sumsq_decayed_kernel(g_ref, p_ref, o_ref, *, wd):
    ge = _decay(g_ref[...], p_ref[...], wd=wd, cast_g_first=False)
    _store_partial(o_ref, jnp.sum(jnp.square(ge), axis=1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("wd", "interpret", "lane_pad"))
def chunk_sumsq(x, p=None, *, wd: float = 0.0, interpret: bool = False,
                lane_pad: bool = False):
    """Per-chunk sum of squares of ``x`` (or of ``x + wd*p`` when ``p`` is
    given).  ``x``: flat (n,) with n % TILE == 0.  Returns f32 (n/CHUNK,)."""
    assert x.ndim == 1 and x.size % TILE == 0, x.shape
    x2 = x.reshape(-1, CHUNK)
    n_chunks = x2.shape[0]
    rows = _tile_rows(n_chunks, interpret)
    grid = n_chunks // rows
    width = _coeff_width(lane_pad)
    tile = pl.BlockSpec((rows, CHUNK), lambda i: (i, 0))
    otile = pl.BlockSpec((rows, width), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((n_chunks, width), jnp.float32)
    if p is None or wd == 0.0:
        out = pl.pallas_call(
            _sumsq_raw_kernel, grid=(grid,),
            in_specs=[tile], out_specs=otile, out_shape=out_shape,
            interpret=interpret,
        )(x2)
    else:
        out = pl.pallas_call(
            functools.partial(_sumsq_decayed_kernel, wd=wd), grid=(grid,),
            in_specs=[tile, tile], out_specs=otile, out_shape=out_shape,
            interpret=interpret,
        )(x2, p.reshape(-1, CHUNK))
    return _partials_out(out)


# ---------------------------------------------------------------------------
# pass 2: fused momentum + apply
# ---------------------------------------------------------------------------

def _update_kernel(c_ref, a_ref, p_ref, g_ref, u_ref,
                   po_ref, uo_ref, usq_ref, *, beta, wd, cast_g_first,
                   nesterov, apply):
    ge = _decay(g_ref[...], p_ref[...], wd=wd, cast_g_first=cast_g_first)
    a = a_ref[:, 0:1]                    # (TILE_ROWS, 1), broadcasts per row
    u_new = beta * u_ref[...] + a * ge
    # nesterov look-ahead: the applied direction re-adds the scaled
    # gradient on top of the NEW momentum (the interpreter's second
    # tree.map in ``trace(nesterov=True)``); the stored slot stays u_new
    out = beta * u_new + a * ge if nesterov else u_new
    uo_ref[...] = u_new
    if apply:
        po_ref[...] = (p_ref[...] - c_ref[0] * out).astype(po_ref.dtype)
    else:
        # deferred apply (a suffix stage — e.g. a trailing clip — still
        # reads the effective direction): first output carries ``out``
        po_ref[...] = out
    _store_partial(usq_ref, jnp.sum(jnp.square(out), axis=1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("beta", "wd", "cast_g_first",
                                             "nesterov", "apply",
                                             "interpret", "lane_pad"))
def fused_update(p, g, u, a_chunk, c, *, beta: float, wd: float,
                 cast_g_first: bool = False, nesterov: bool = False,
                 apply: bool = True, interpret: bool = False,
                 lane_pad: bool = False):
    """Whole-bucket fused optimizer update.

    p: flat (n,) in the bucket dtype; g: flat (n,) gradient buffer (bucket
    dtype, or f32 for the LAMB apply where ``g`` carries the pre-formed
    Adam direction); u: flat (n,) f32; a_chunk: (n/CHUNK,) f32 per-chunk
    coefficient; c: scalar.
    Returns (p_new [p.dtype], u_new [f32], u_sumsq_partials [(n/CHUNK,) f32]).
    ``p -> p_new`` and ``u -> u_new`` are declared input/output aliases,
    so donated resident buffers update in place.

    ``nesterov=True`` applies (and reports in the sumsq partials) the
    look-ahead direction ``beta*u_new + a*ge`` while still storing
    ``u_new`` in the momentum slot — the fused form of
    ``trace(nesterov=True)``.  ``apply=False`` skips the parameter write:
    the first output instead carries the f32 effective direction (for a
    suffix stage such as a trailing clip, which rescales it and applies
    via ``scale_apply``); ``p`` is NOT aliased in that mode since a later
    pass still reads it.
    """
    assert p.ndim == 1 and p.size % TILE == 0, p.shape
    n_chunks = p.size // CHUNK
    assert a_chunk.shape == (n_chunks,), a_chunk.shape
    rows = _tile_rows(n_chunks, interpret)
    grid = n_chunks // rows
    width = _coeff_width(lane_pad)
    tile = pl.BlockSpec((rows, CHUNK), lambda i: (i, 0))
    ctile = pl.BlockSpec((rows, width), lambda i: (i, 0))
    cs = jnp.reshape(c, (1,)).astype(jnp.float32)
    po_dtype = p.dtype if apply else jnp.float32
    aliases = {2: 0, 4: 1} if apply else {4: 1}
    po, uo, usq = pl.pallas_call(
        functools.partial(_update_kernel, beta=beta, wd=wd,
                          cast_g_first=cast_g_first, nesterov=nesterov,
                          apply=apply),
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  ctile, tile, tile, tile],
        out_specs=[tile, tile, ctile],
        out_shape=[jax.ShapeDtypeStruct((n_chunks, CHUNK), po_dtype),
                   jax.ShapeDtypeStruct((n_chunks, CHUNK), jnp.float32),
                   jax.ShapeDtypeStruct((n_chunks, width), jnp.float32)],
        input_output_aliases=aliases,          # p -> p_new, u -> u_new
        interpret=interpret,
    )(cs, _expand_coeff(a_chunk, lane_pad), p.reshape(-1, CHUNK),
      g.reshape(-1, CHUNK), u.reshape(-1, CHUNK))
    return po.ravel(), uo.ravel(), _partials_out(usq)


def _scale_apply_kernel(c_ref, a_ref, p_ref, g_ref, po_ref, ssq_ref):
    """Per-chunk-scaled apply (LAMB's second launch): the expression
    mirrors the interpreter's scale_by_trust_ratio (ratio * u) ->
    scale_by_schedule (lr * .) -> apply (w - .) stages exactly."""
    s = a_ref[:, 0:1] * g_ref[...]       # (TILE_ROWS, 1) a broadcasts
    po_ref[...] = (p_ref[...] - c_ref[0] * s).astype(po_ref.dtype)
    _store_partial(ssq_ref, jnp.sum(jnp.square(s), axis=1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("interpret", "lane_pad"))
def scale_apply(p, g, a_chunk, c, *, interpret: bool = False,
                lane_pad: bool = False):
    """Whole-bucket scale-and-apply: ``p <- (p - c * (a * g)).astype``.

    p: flat (n,) in the bucket dtype; g: flat (n,) f32 direction;
    a_chunk: (n/CHUNK,) f32 per-chunk coefficient; c: scalar.
    Returns (p_new [p.dtype], s_sumsq_partials [(n/CHUNK,) f32]) where
    s = a * g is the scaled direction (its folded norm is LAMB's
    pre-lr ``update_norm`` stat).  ``p -> p_new`` is an input/output
    alias, so a donated resident buffer updates in place.
    """
    assert p.ndim == 1 and p.size % TILE == 0, p.shape
    n_chunks = p.size // CHUNK
    assert a_chunk.shape == (n_chunks,), a_chunk.shape
    rows = _tile_rows(n_chunks, interpret)
    grid = n_chunks // rows
    width = _coeff_width(lane_pad)
    tile = pl.BlockSpec((rows, CHUNK), lambda i: (i, 0))
    ctile = pl.BlockSpec((rows, width), lambda i: (i, 0))
    cs = jnp.reshape(c, (1,)).astype(jnp.float32)
    po, ssq = pl.pallas_call(
        _scale_apply_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  ctile, tile, tile],
        out_specs=[tile, ctile],
        out_shape=[jax.ShapeDtypeStruct((n_chunks, CHUNK), p.dtype),
                   jax.ShapeDtypeStruct((n_chunks, width), jnp.float32)],
        input_output_aliases={2: 0},           # p -> p_new
        interpret=interpret,
    )(cs, _expand_coeff(a_chunk, lane_pad), p.reshape(-1, CHUNK),
      g.reshape(-1, CHUNK))
    return po.ravel(), _partials_out(ssq)


# ---------------------------------------------------------------------------
# LAMB/Adam pass: moments + bias-corrected direction + norm partials
# ---------------------------------------------------------------------------

def _adam_kernel(b_ref, p_ref, g_ref, m_ref, v_ref,
                 mo_ref, vo_ref, uo_ref, usq_ref, psq_ref, gsq_ref,
                 *, b1, b2, eps, wd):
    """One fused pass: advance both Adam moments, form the bias-corrected
    (decoupled-decayed) direction, and emit the three per-chunk sumsq
    partial sets (direction, params, grads) the host needs for the
    trust ratios and the stats norms.  Every expression mirrors the chain
    interpreter's ``scale_by_adam`` / ``add_decayed_weights`` stages,
    including the cast orders (wd*p in the param dtype, then f32 add)."""
    g = g_ref[...]
    g32 = g.astype(jnp.float32)
    _store_partial(gsq_ref, jnp.sum(jnp.square(g32), axis=1, keepdims=True))
    m_new = b1 * m_ref[...] + (1 - b1) * g32
    v_new = b2 * v_ref[...] + (1 - b2) * jnp.square(g32)
    u = (m_new / b_ref[0]) / (jnp.sqrt(v_new / b_ref[1]) + eps)
    if wd != 0.0:
        u = u + wd * p_ref[...]
    mo_ref[...] = m_new
    vo_ref[...] = v_new
    uo_ref[...] = u
    _store_partial(usq_ref, jnp.sum(jnp.square(u), axis=1, keepdims=True))
    _store_partial(psq_ref,
                   jnp.sum(jnp.square(p_ref[...].astype(jnp.float32)),
                           axis=1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd",
                                             "interpret", "lane_pad"))
def adam_update(p, g, m, v, bc1, bc2, *, b1: float, b2: float,
                eps: float, wd: float = 0.0, interpret: bool = False,
                lane_pad: bool = False):
    """Whole-bucket fused Adam-moment pass (LAMB's first launch).

    p, g: flat (n,) in the bucket dtype; m, v: flat (n,) f32 moments;
    bc1, bc2: scalar bias corrections ``1 - b^t`` (computed host-side so
    they match the interpreter's expression exactly).  ``eps`` must be
    > 0 so zero padding maps to zero direction (0 / (0 + eps)); the
    chain compiler refuses eps <= 0.
    Returns (m_new, v_new, u [all f32 flat], and f32 (n/CHUNK,) sumsq
    partials of u, p, g).  ``m -> m_new`` and ``v -> v_new`` are
    input/output aliases, so donated resident moment buffers update in
    place (``p`` cannot alias — the apply pass still reads it).
    """
    assert p.ndim == 1 and p.size % TILE == 0, p.shape
    n_chunks = p.size // CHUNK
    rows = _tile_rows(n_chunks, interpret)
    grid = n_chunks // rows
    width = _coeff_width(lane_pad)
    tile = pl.BlockSpec((rows, CHUNK), lambda i: (i, 0))
    ctile = pl.BlockSpec((rows, width), lambda i: (i, 0))
    bs = jnp.stack([jnp.asarray(bc1, jnp.float32),
                    jnp.asarray(bc2, jnp.float32)])
    flat = jax.ShapeDtypeStruct((n_chunks, CHUNK), jnp.float32)
    part = jax.ShapeDtypeStruct((n_chunks, width), jnp.float32)
    mo, vo, uo, usq, psq, gsq = pl.pallas_call(
        functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  tile, tile, tile, tile],
        out_specs=[tile, tile, tile, ctile, ctile, ctile],
        out_shape=[flat, flat, flat, part, part, part],
        input_output_aliases={3: 0, 4: 1},     # m -> m_new, v -> v_new
        interpret=interpret,
    )(bs, p.reshape(-1, CHUNK), g.reshape(-1, CHUNK),
      m.reshape(-1, CHUNK), v.reshape(-1, CHUNK))
    return (mo.ravel(), vo.ravel(), uo.ravel(),
            _partials_out(usq), _partials_out(psq), _partials_out(gsq))
