"""Pure-jnp oracle for the RMSNorm kernel (same math as models/layers.py)."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
