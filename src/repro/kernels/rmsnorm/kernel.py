"""RMSNorm — Pallas TPU kernel.

Row-tiled: grid walks blocks of ROWS rows; each block loads (ROWS, d) into
VMEM, reduces the squared mean over the feature dim in fp32, scales, and
writes back in the input dtype.  d is the lane dim (all assigned archs
have d a multiple of 128; ops.py pads otherwise, which changes the mean
denominator — so the wrapper passes the true d as a static).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _kernel(x_ref, s_ref, o_ref, *, eps, true_d):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.sum(x * x, axis=-1, keepdims=True) / true_d
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_pallas(x, scale, eps: float = 1e-6, interpret: bool = False):
    """x: (..., d) -> same shape/dtype."""
    shape, dtype = x.shape, x.dtype
    d = shape[-1]
    d_pad = -d % 128
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r_pad = -rows % ROWS
    x2 = jnp.pad(x2, ((0, r_pad), (0, d_pad)))
    s2 = jnp.pad(scale, (0, d_pad))
    grid = x2.shape[0] // ROWS

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, true_d=d),
        grid=(grid,),
        in_specs=[pl.BlockSpec((ROWS, d + d_pad), lambda i: (i, 0)),
                  pl.BlockSpec((d + d_pad,), lambda i: (0,))],
        out_specs=pl.BlockSpec((ROWS, d + d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, dtype),
        interpret=interpret,
    )(x2, s2)
    return out[:rows, :d].reshape(shape)
