"""jit'd wrapper: Pallas on TPU, interpret mode elsewhere."""
import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


def rmsnorm(x, scale, eps: float = 1e-6):
    return rmsnorm_pallas(x, scale, eps,
                          interpret=jax.default_backend() != "tpu")
