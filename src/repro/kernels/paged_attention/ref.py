"""Pure-jnp oracle: paged decode attention via dense gather.

Gathers each sequence's K/V blocks through its block table into a
dense (B, T, K, hd) view, masks everything past the sequence frontier
(t > pos) or outside the sliding window, and runs two-pass softmax in
fp32 — numerically the same computation as the model's jnp paged
decode path (layers._sdpa over the gathered view), which is itself
bitwise against the dense decode engine.
"""
import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def paged_attention_ref(q, kp, vp, bt, pos, *, window: int = 0,
                        softcap: float = 0.0):
    """q (B, H, hd); kp/vp (n_blocks, bs, K, hd); bt (B, nbmax) int32;
    pos (B,) int32 absolute position of the entry just written.
    Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, bs, K, _ = kp.shape
    G = H // K
    T = bt.shape[1] * bs
    kd = kp[bt].reshape(B, T, K, hd).astype(jnp.float32)
    vd = vp[bt].reshape(B, T, K, hd).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qf * hd ** -0.5, kd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    t_ids = jnp.arange(T, dtype=jnp.int32)[None, :]
    valid = t_ids <= pos[:, None]
    if window > 0:
        valid &= t_ids > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, vd)
    return o.reshape(B, H, hd).astype(q.dtype)
