"""Paged decode attention — Pallas TPU kernel.

Decode-time attention for one new token per sequence against a paged
KV cache: K/V live in a global pool of fixed-size blocks and each
sequence names its blocks through an int32 block-table row
(serving/paged_cache.py).  The kernel never touches a dense
(B, ctx, ...) cache — the block table and per-sequence positions are
scalar-prefetched (``PrefetchScalarGridSpec``), so the K/V BlockSpec
index maps chase the table and fetch exactly the blocks each sequence
owns.

Blocking: grid = (batch * kv_heads, n_table_cols) with the block
column innermost.  The q-head group of one kv head (GQA folded like
flash_attention) rides in a single (G, hd) block padded to the fp32
min tile; running max / denominator / accumulator live in VMEM scratch
across the column loop; blocks entirely beyond the sequence frontier
(t0 > pos) or entirely outside the sliding window are skipped with
``pl.when``; the output is finalized when the last column retires.

Tolerance policy (same as flash_attention): the kernel's online
softmax reassociates the reduction, so it is NOT bitwise against the
two-pass ref — fp32 agrees to ~1e-6 atol (few-ulp), bf16 inputs to
~3e-2.  The model's jnp gather path (layers.py) is the bitwise-parity
reference against the dense engine; this kernel is the TPU fast path,
gated differentially in tests/test_kernels.py and BENCH_serving.json.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            scale, window, softcap, bs, n_bt, n_kv_heads):
    g = pl.program_id(0)                     # fused (batch, kv-head)
    j = pl.program_id(1)                     # block-table column
    pos = pos_ref[g // n_kv_heads]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t0 = j * bs
    # column-level skip: block fully beyond the frontier or out-of-window
    run = t0 <= pos
    if window > 0:
        run &= t0 + bs - 1 > pos - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (Gp, hdp)
        k = k_ref[0, 0].astype(jnp.float32)       # (bs, hdp)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        t = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= pos
        if window > 0:
            mask &= t > pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                    # (Gp, bs)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(j == n_bt - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def paged_decode_attention(q, kp, vp, bt, pos, *, window: int = 0,
                           softcap: float = 0.0, interpret: bool = False):
    """q: (B, H, hd) — one query token per sequence.
    kp/vp: (n_blocks, bs, K, hd) block pools, H % K == 0.
    bt: (B, nbmax) int32 block table; pos: (B,) int32 position of the
    entry just written (reads are masked to t <= pos).
    Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, bs, K, _ = kp.shape
    G = H // K
    n_bt = bt.shape[1]
    g_pad = -G % 8                 # fp32 min sublane tile
    hd_pad = -hd % 128
    Gp, hdp = G + g_pad, hd + hd_pad

    qt = q.reshape(B * K, G, hd)
    if g_pad or hd_pad:
        qt = jnp.pad(qt, ((0, 0), (0, g_pad), (0, hd_pad)))
    # pool laid out (nb, K, bs, hd) kernel-side so one (bs, hdp) block
    # per kv head is a contiguous min-tile-aligned window
    kt = jnp.moveaxis(kp, 2, 1)
    vt = jnp.moveaxis(vp, 2, 1)
    if hd_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * K, n_bt),
        in_specs=[
            pl.BlockSpec((1, Gp, hdp), lambda g, j, bt_, pos_: (g, 0, 0)),
            pl.BlockSpec((1, 1, bs, hdp),
                         lambda g, j, bt_, pos_, K=K: (bt_[g // K, j],
                                                       g % K, 0, 0)),
            pl.BlockSpec((1, 1, bs, hdp),
                         lambda g, j, bt_, pos_, K=K: (bt_[g // K, j],
                                                       g % K, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Gp, hdp),
                               lambda g, j, bt_, pos_: (g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, hdp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=hd ** -0.5, window=window,
                          softcap=softcap, bs=bs, n_bt=n_bt, n_kv_heads=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, Gp, hdp), q.dtype),
        interpret=interpret,
    )(bt.astype(jnp.int32), pos.astype(jnp.int32), qt, kt, vt)
    return out[:, :G, :hd].reshape(B, H, hd)
