"""jit'd wrapper: Pallas paged decode attention on TPU, interpret mode
elsewhere (the kernel body runs in Python on CPU)."""
import jax

from repro.kernels.paged_attention.kernel import paged_decode_attention


def paged_attention(q, kp, vp, bt, pos, **kw):
    return paged_decode_attention(q, kp, vp, bt, pos,
                                  interpret=jax.default_backend() != "tpu",
                                  **kw)
