import jax

from repro.kernels.fused_lars.kernel import fused_lars_update


def lars_update(w, g, v, lr, **kw):
    return fused_lars_update(w, g, v, lr,
                             interpret=jax.default_backend() != "tpu", **kw)
