import jax

from repro.kernels import record_launches
from repro.kernels.fused_lars.kernel import fused_lars_update


def lars_update(w, g, v, lr, **kw):
    record_launches(3)   # two _sqnorm passes + one fused update per tensor
    return fused_lars_update(w, g, v, lr,
                             interpret=jax.default_backend() != "tpu", **kw)
