"""Pure-jnp oracle for the fused LARS update (matches core.optim.lars)."""
import jax.numpy as jnp


def lars_update_ref(w, g, v, lr, *, beta: float, wd: float,
                    trust: float = 0.001, eps: float = 1e-12):
    g = g.astype(jnp.float32)
    wn = jnp.linalg.norm(w.astype(jnp.float32))
    gn = jnp.linalg.norm(g)
    local = trust * wn / (gn + wd * wn + eps)
    local = jnp.where(wn > 0, local, 1.0)
    v_new = beta * v + lr * local * (g + wd * w)
    return w - v_new, v_new
