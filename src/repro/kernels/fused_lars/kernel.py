"""Fused LARS update — Pallas TPU kernel.

LARS (the paper's large-batch baseline) needs a per-tensor trust ratio
before the momentum/apply pass:

    local_lr = trust * ||w|| / (||g|| + wd * ||w|| + eps)
    v <- beta * v + lr * local_lr * (g + wd * w)
    w <- w - v

Two kernels: a tiled squared-norm reduction (pass 1) and the fused
momentum+apply (pass 2) consuming the two scalars via SMEM — one read
and one write per tensor beyond the unavoidable norm pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS = 256
LANES = 128


def _sq_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[0, 0] = 0.0
    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x)


def _sqnorm(x, interpret):
    n = x.size
    block = ROWS * LANES
    xf = jnp.pad(x.ravel(), (0, -n % block)).reshape(-1, LANES)
    grid = xf.shape[0] // ROWS
    out = pl.pallas_call(
        _sq_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(xf)
    return out[0, 0]


def _upd_kernel(scal_ref, w_ref, g_ref, v_ref, wo_ref, vo_ref, *, beta, wd):
    lr_local = scal_ref[0]
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...]
    v = beta * v_ref[...] + lr_local * (g + wd * w)
    vo_ref[...] = v
    wo_ref[...] = w - v


@functools.partial(jax.jit, static_argnames=("beta", "wd", "trust", "eps",
                                             "interpret"))
def fused_lars_update(w, g, v, lr, *, beta: float, wd: float,
                      trust: float = 0.001, eps: float = 1e-12,
                      interpret: bool = False):
    wn = jnp.sqrt(_sqnorm(w, interpret))
    gn = jnp.sqrt(_sqnorm(g, interpret))
    local = trust * wn / (gn + wd * wn + eps)
    local = jnp.where(wn > 0, local, 1.0)
    scal = (lr.astype(jnp.float32) * local)[None]

    shape = w.shape
    n = w.size
    block = ROWS * LANES
    pad = -n % block
    wf = jnp.pad(w.ravel(), (0, pad)).reshape(-1, LANES)
    gf = jnp.pad(g.ravel(), (0, pad)).reshape(-1, LANES)
    vf = jnp.pad(v.ravel(), (0, pad)).reshape(-1, LANES)
    tile = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    wo, vo = pl.pallas_call(
        functools.partial(_upd_kernel, beta=beta, wd=wd),
        grid=(wf.shape[0] // ROWS,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct(wf.shape, jnp.float32),
                   jax.ShapeDtypeStruct(wf.shape, jnp.float32)],
        interpret=interpret,
    )(scal, wf, gf, vf)
    return wo.ravel()[:n].reshape(shape), vo.ravel()[:n].reshape(shape)
