"""Mixture-of-Experts FFN with capacity-based dispatch.

Two distribution modes, one math:

* ``a2a``       — expert parallelism: experts shard over the ``ep`` axis
                  (data axis); tokens are scattered into per-(expert)
                  capacity buffers and exchanged with ``lax.all_to_all``
                  inside ``shard_map`` (DeepSeek-style EP).  Used when the
                  local token count is large (train / prefill).
* ``allreduce`` — for tiny token counts (decode, batch <= mesh): tokens
                  are replicated, every shard computes its local experts
                  and the contributions are psum'd over the ep axis.  No
                  all_to_all, no divisibility constraint on batch.

The reference oracle (``moe_ref``) computes every expert densely on every
token — exact, drop-free; tests compare against it with a high capacity
factor.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef
from repro.models.runtime import Runtime
from repro.models import layers

MIN_CAPACITY = 4


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_defs(cfg: ModelConfig):
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_expert
    defs = {
        "router": ParamDef((d, E), (None, None), scale=0.02),  # tiny: replicate
        "wg": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "wu": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "wd": ParamDef((E, f, d), ("experts", "ffn", "embed")),
    }
    if m.n_shared:
        defs["shared"] = layers.mlp_defs(cfg, m.n_shared * f, gated=True)
    return defs


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route(logits: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits (T,E) -> weights (T,k), ids (T,k), aux_loss (scalar)."""
    m = cfg.moe
    lf = logits.astype(jnp.float32)
    if m.router_mode == "softmax_topk":      # DeepSeek-V2
        probs = jax.nn.softmax(lf, axis=-1)
        weights, ids = jax.lax.top_k(probs, m.top_k)
    else:                                     # Mixtral / Jamba: topk then softmax
        top_logits, ids = jax.lax.top_k(lf, m.top_k)
        weights = jax.nn.softmax(top_logits, axis=-1)
        probs = jax.nn.softmax(lf, axis=-1)
    # switch-style load-balance loss: E * sum_e (frac dispatched_e * mean prob_e)
    T = logits.shape[0]
    dispatch = jnp.zeros_like(probs).at[jnp.arange(T)[:, None], ids].add(1.0)
    frac = dispatch.mean(axis=0) / m.top_k
    aux = m.n_experts * jnp.sum(frac * probs.mean(axis=0))
    return weights, ids, aux


# ---------------------------------------------------------------------------
# per-shard body
# ---------------------------------------------------------------------------

def _positions(flat_ids: jnp.ndarray, E: int, cap: int):
    """Position of each assignment within its expert's capacity buffer."""
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)          # (A,E)
    pos = (jnp.cumsum(oh, axis=0) - 1)                         # running count
    pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < cap
    return pos, keep


def _expert_ffn(cfg: ModelConfig, wg, wu, wd, xs, n_model: int, model_axis):
    """xs: (E_loc, C, d); weights sharded on ffn over the model axis."""
    cdt = jnp.dtype(cfg.compute_dtype)
    xs = xs.astype(cdt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg.astype(cdt)))
    h = h * jnp.einsum("ecd,edf->ecf", xs, wu.astype(cdt))
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(cdt))
    if n_model > 1:  # partial sum over the sharded ffn dim
        y = jax.lax.psum(y, model_axis)
    return y


def _moe_body(router, wg, wu, wd, x, *, cfg: ModelConfig, n_ep: int,
              ep_axis, model_axis, n_model: int, mode: str):
    """Runs per device (or directly when unsharded). x: (T_loc, d)."""
    m = cfg.moe
    T, d = x.shape
    E = m.n_experts
    E_loc = E // n_ep
    k = m.top_k

    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)
    weights, ids, aux = route(logits, cfg)
    flat_ids = ids.reshape(-1)                                  # (T*k,)
    x_rep = jnp.repeat(x, k, axis=0)                            # (T*k, d)

    cap = max(MIN_CAPACITY, math.ceil(T * k / E * m.capacity_factor))
    pos, keep = _positions(flat_ids, E, cap)

    if mode == "allreduce":
        # tiny T: tokens replicated; each shard computes its local experts
        # for every token and the results are psum'd over the ep axis.
        idx = jax.lax.axis_index(ep_axis) if n_ep > 1 else 0
        local = (flat_ids // E_loc) == idx
        buf = jnp.zeros((E, cap, d), x.dtype)
        buf = buf.at[flat_ids, pos].add(jnp.where((keep & local)[:, None], x_rep, 0))
        buf_loc = jax.lax.dynamic_slice(buf, (idx * E_loc, 0, 0), (E_loc, cap, d))
        y_loc = _expert_ffn(cfg, wg, wu, wd, buf_loc, n_model, model_axis)
        y_full = jnp.zeros((E, cap, d), y_loc.dtype)
        y_full = jax.lax.dynamic_update_slice(y_full, y_loc, (idx * E_loc, 0, 0))
        if n_ep > 1:
            y_full = jax.lax.psum(y_full, ep_axis)
        rows = y_full[flat_ids, pos] * keep[:, None]
    else:  # mode == "a2a": expert parallelism with all_to_all
        buf = jnp.zeros((E, cap, d), x.dtype)
        buf = buf.at[flat_ids, pos].add(jnp.where(keep[:, None], x_rep, 0))
        if n_ep > 1:
            buf = buf.reshape(n_ep, E_loc, cap, d)
            buf = jax.lax.all_to_all(buf, ep_axis, 0, 0)        # (n_ep src, E_loc, cap, d)
            xs = jnp.moveaxis(buf, 0, 1).reshape(E_loc, n_ep * cap, d)
        else:
            xs = buf
        y = _expert_ffn(cfg, wg, wu, wd, xs, n_model, model_axis)
        if n_ep > 1:
            y = jnp.moveaxis(y.reshape(E_loc, n_ep, cap, d), 1, 0)
            y = jax.lax.all_to_all(y, ep_axis, 0, 0)            # back to source
            y = y.reshape(E, cap, d)
        rows = y[flat_ids, pos] * keep[:, None]

    rows = rows.reshape(T, k, d)
    out = jnp.sum(weights[..., None].astype(rows.dtype) * rows, axis=1)
    return out.astype(x.dtype), aux.reshape(1)


# ---------------------------------------------------------------------------
# public apply
# ---------------------------------------------------------------------------

def moe_apply(p, x, cfg: ModelConfig, rt: Runtime):
    """x: (B, S, d) -> (out (B,S,d), aux loss scalar)."""
    B, S, d = x.shape
    m = cfg.moe
    T_global = B * S

    if rt.mesh is None:
        body = partial(_moe_body, cfg=cfg, n_ep=1, ep_axis=None,
                       model_axis=None, n_model=1, mode="a2a")
        y, aux = body(p["router"], p["wg"], p["wu"], p["wd"], x.reshape(T_global, d))
        y = y.reshape(B, S, d)
        aux = aux[0]
    else:
        n_ep = rt.mesh.shape[rt.ep_axis]
        n_model = rt.mesh.shape[rt.model_axis]
        n_batch_shards = 1
        for a in rt.data_axes:
            n_batch_shards *= rt.mesh.shape[a]
        # token-sharded a2a when the flattened token dim divides evenly and
        # is large; replicated allreduce mode otherwise (tiny decode batches)
        a2a_ok = (B % n_batch_shards == 0)
        mode = "a2a" if a2a_ok else "allreduce"
        tok_spec = P(rt.data_axes, None) if a2a_ok else P(None, None)
        body = partial(_moe_body, cfg=cfg, n_ep=n_ep, ep_axis=rt.ep_axis,
                       model_axis=rt.model_axis, n_model=n_model, mode=mode)
        wspec = P(rt.ep_axis, None, rt.model_axis)
        y, aux = shard_map(
            body, mesh=rt.mesh,
            in_specs=(P(None, None), wspec, wspec,
                      P(rt.ep_axis, rt.model_axis, None), tok_spec),
            out_specs=(tok_spec, P(rt.data_axes if a2a_ok else None)),
            check_rep=False,
        )(p["router"], p["wg"], p["wu"], p["wd"], x.reshape(T_global, d))
        y = y.reshape(B, S, d)
        aux = jnp.mean(aux)

    if m.n_shared:
        y = y + layers.mlp(p["shared"], x, cfg)
    return y, aux * m.router_aux_weight


# ---------------------------------------------------------------------------
# dense oracle (tests): every expert on every token, no capacity drops
# ---------------------------------------------------------------------------

def moe_ref(p, x, cfg: ModelConfig):
    B, S, d = x.shape
    m = cfg.moe
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    weights, ids, aux = route(logits, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    xs = xf.astype(cdt)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xs, p["wg"].astype(cdt)))
    h = h * jnp.einsum("td,edf->tef", xs, p["wu"].astype(cdt))
    y_all = jnp.einsum("tef,efd->ted", h, p["wd"].astype(cdt))   # (T,E,d)
    sel = jnp.take_along_axis(y_all, ids[:, :, None], axis=1)    # (T,k,d)
    y = jnp.sum(weights[..., None].astype(sel.dtype) * sel, axis=1)
    y = y.reshape(B, S, d).astype(x.dtype)
    if m.n_shared:
        y = y + layers.mlp(p["shared"], x, cfg)
    return y, aux * m.router_aux_weight
