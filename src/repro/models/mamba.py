"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk "attention-
like" quadratic term + inter-chunk linear recurrence over chunk states
(sequential ``lax.scan`` over chunks; n_chunks = S / chunk).
Decode uses the O(1) recurrent update on the (B, H, P, N) SSM state.

Projections are kept as separate tensors (wz/wx/wB/wC/wdt) instead of one
fused in_proj so each shards cleanly on its own logical axes
(DESIGN.md §7): heads/channels on "model", d_model on "embed".
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.headdim
    return s, d_in, H, s.headdim, s.d_state, s.ngroups


def mamba_defs(cfg: ModelConfig):
    s, d_in, H, P_, N, G = _dims(cfg)
    conv_dim = d_in + 2 * G * N
    d = cfg.d_model
    return {
        "wz": ParamDef((d, d_in), ("embed", "inner")),
        "wx": ParamDef((d, d_in), ("embed", "inner")),
        "wB": ParamDef((d, G * N), ("embed", None)),
        "wC": ParamDef((d, G * N), ("embed", None)),
        "wdt": ParamDef((d, H), ("embed", "heads")),
        "conv_w": ParamDef((s.conv_width, conv_dim), (None, "inner")),
        "conv_b": ParamDef((conv_dim,), ("inner",), "zeros"),
        "A_log": ParamDef((H,), ("heads",), "arange_log"),
        "D": ParamDef((H,), ("heads",), "ones"),
        "dt_bias": ParamDef((H,), ("heads",), "zeros"),
        "norm": ParamDef((d_in,), ("inner",), "ones"),
        "out_proj": ParamDef((d_in, d), ("inner", "embed")),
    }


def _gated_rmsnorm(scale, y, z, eps):
    """Mamba2 output norm: RMSNorm(y * silu(z))."""
    yf = (y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _conv_full(xBC, w, b):
    """Causal depthwise conv over (B,S,C) with kernel (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


# ---------------------------------------------------------------------------
# chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B_, C_, chunk: int, h0=None):
    """SSD over a full sequence.

    x:  (B, S, H, P)   dt: (B, S, H)   A: (H,) (negative)
    B_: (B, S, G, N)   C_: (B, S, G, N)
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    Bb, S, H, P_ = x.shape
    G = B_.shape[2]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    # reshape into chunks; broadcast groups to heads
    xc = x.reshape(Bb, nc, chunk, H, P_)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = jnp.repeat(B_.reshape(Bb, nc, chunk, G, N := B_.shape[-1]), rep, axis=3)
    Cc = jnp.repeat(C_.reshape(Bb, nc, chunk, G, N), rep, axis=3)

    dA = dtc * A  # (B,nc,Q,H), negative
    dA_cs = jnp.cumsum(dA, axis=2)                              # within-chunk cumsum

    # 1) intra-chunk (quadratic in chunk length)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))              # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)           # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                                   # dt-weighted input
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                        scores.astype(jnp.float32), L, xdt.astype(jnp.float32))

    # 2) chunk states: state_c = sum_q decay_out[q] * B[q] x~[q]
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)            # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bc.astype(jnp.float32), decay_out, xdt.astype(jnp.float32))

    # 3) inter-chunk recurrence over chunk states (sequential scan)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                   # (B,nc,H)
    def scan_fn(h, inp):
        st, dec = inp                                           # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h
    h_init = jnp.zeros((Bb, H, P_, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_last, h_prev = jax.lax.scan(
        scan_fn, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                         # (B,nc,H,P,N)

    # 4) inter-chunk output: y_off[q] = C[q] . (decay_in[q] * h_prev)
    decay_in = jnp.exp(dA_cs)                                   # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Cc.astype(jnp.float32), h_prev, decay_in)

    y = (y_diag + y_off).reshape(Bb, S, H, P_)
    return y, h_last


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------

def mamba_block(p, x, cfg: ModelConfig, *, cache: Optional[dict] = None, pos=None):
    """x: (B,S,d). cache (decode): {"conv": (B,W-1,conv_dim), "ssm": (B,H,P,N)}."""
    s, d_in, H, P_, N, G = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    Bb, S, _ = x.shape
    xc = x.astype(cdt)

    z = xc @ p["wz"].astype(cdt)                                # (B,S,d_in)
    xin = xc @ p["wx"].astype(cdt)
    Bv = xc @ p["wB"].astype(cdt)
    Cv = xc @ p["wC"].astype(cdt)
    dt = xc @ p["wdt"].astype(cdt)                              # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)

    xBC = jnp.concatenate([xin, Bv, Cv], axis=-1)               # (B,S,conv_dim)

    if cache is None:
        xBC = _conv_full(xBC, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
        conv_tail = None
        if S >= s.conv_width - 1:
            # store raw (pre-conv) tail for decode continuation
            conv_tail = jnp.concatenate([xin, Bv, Cv], axis=-1)[:, S - (s.conv_width - 1):, :]
        xin2 = xBC[..., :d_in].reshape(Bb, S, H, P_)
        Bm = xBC[..., d_in:d_in + G * N].reshape(Bb, S, G, N)
        Cm = xBC[..., d_in + G * N:].reshape(Bb, S, G, N)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        # pad S to a chunk multiple with dt=0 positions: exp(0*A)=1 and
        # x*dt=0, so the padded tail is an identity recurrence (state and
        # real outputs unaffected)
        chunk = min(s.chunk, S)
        pad = -S % chunk
        if pad:
            pz = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
            y, h_last = ssd_chunked(pz(xin2), pz(dtv), A, pz(Bm), pz(Cm), chunk)
            y = y[:, :S]
        else:
            y, h_last = ssd_chunked(xin2, dtv, A, Bm, Cm, chunk)
        y = y + p["D"].astype(jnp.float32)[:, None] * xin2.astype(jnp.float32)
        y = y.reshape(Bb, S, d_in).astype(cdt)
        y = _gated_rmsnorm(p["norm"], y, z, cfg.norm_eps)
        out = y.astype(cdt) @ p["out_proj"].astype(cdt)
        new_cache = None
        if conv_tail is not None:
            new_cache = {"conv": conv_tail.astype(cdt), "ssm": h_last.astype(jnp.float32)}
        return out, new_cache

    # ---- decode: O(1) recurrent update, S == 1 ----
    raw = xBC[:, 0, :]                                          # (B,conv_dim)
    conv_buf = jnp.concatenate([cache["conv"], raw[:, None, :]], axis=1)  # (B,W,conv)
    w = p["conv_w"].astype(cdt)
    conv_out = jnp.einsum("bwc,wc->bc", conv_buf, w) + p["conv_b"].astype(cdt)
    conv_out = jax.nn.silu(conv_out)
    new_conv = conv_buf[:, 1:, :]

    xin2 = conv_out[:, :d_in].reshape(Bb, H, P_)
    Bm = conv_out[:, d_in:d_in + G * N].reshape(Bb, G, N)
    Cm = conv_out[:, d_in + G * N:].reshape(Bb, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=1)                            # (B,H,N)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dAe = jnp.exp(dtv * A)                                      # (B,H)
    h = cache["ssm"]                                            # (B,H,P,N) fp32
    xdt = xin2.astype(jnp.float32) * dtv[..., None]
    h_new = h * dAe[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt,
                                                  Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[:, None] * xin2.astype(jnp.float32)
    y = y.reshape(Bb, 1, d_in).astype(cdt)
    y = _gated_rmsnorm(p["norm"], y, z, cfg.norm_eps)
    out = y.astype(cdt) @ p["out_proj"].astype(cdt)
    return out, {"conv": new_conv, "ssm": h_new}
