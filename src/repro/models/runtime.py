"""Runtime: distribution context threaded through model apply functions.

``mesh=None`` means single-device reference execution (smoke tests, CPU
examples); the expert-parallel MoE path and any explicit collective only
activate when a mesh with a >1-sized axis is present.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)   # axes the batch/tokens shard over
    model_axis: str = "model"
    ep_axis: str = "data"                    # expert-parallel axis
    use_pallas: bool = False
    remat: bool = True                       # checkpoint each scanned period
    # §Perf: cast >=2D fp32 params to compute dtype BEFORE the FSDP
    # all-gather — halves weight-gather collective bytes and weight HBM
    # reads (norm scales / biases stay fp32).  Default: faithful baseline.
    gather_dtype: str = "float32"
    # §Perf: "full" recomputes the whole block in backward; "save_tp"
    # additionally saves the post-all-reduce activations (checkpoint_name
    # "tp_out"), so remat recompute skips the TP collectives and the
    # matmuls feeding them (+2 x (B,S,d) bf16 per layer of stash).
    remat_policy: str = "full"

    def __hash__(self):  # mesh is unhashable; identity is fine for tracing
        return hash((id(self.mesh), self.data_axes, self.model_axis,
                     self.ep_axis, self.use_pallas, self.remat,
                     self.gather_dtype, self.remat_policy))

    @property
    def n_ep(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.ep_axis]

    def constrain(self, x, *spec):
        """with_sharding_constraint when a mesh is present; no-op otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh,
                                          jax.sharding.PartitionSpec(*spec)))


CPU_RUNTIME = Runtime(mesh=None, remat=False)
