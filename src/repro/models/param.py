"""Parameter abstraction: models declare *abstract* trees of ``ParamDef``
(shape + logical axis names + init); the same tree materializes to arrays
(``materialize``), to ShapeDtypeStructs for the dry-run (``abstract``), and
to PartitionSpecs via the sharding rules (``sharding/rules.py``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = never sharded)
    init: str = "normal"              # normal | zeros | ones | embed | const
    scale: float = -1.0               # -1 -> 1/sqrt(fan_in) for "normal"
    dtype: Any = jnp.float32


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_defs(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_def)


def stack(tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layer dim of size n to every ParamDef."""
    def add(d: ParamDef) -> ParamDef:
        return d._replace(shape=(n,) + d.shape, axes=(axis_name,) + d.axes)
    return _tree_map_defs(add, tree)


def materialize(tree, rng: jax.Array):
    """Deterministically initialize every leaf (path-hashed rng folds)."""
    paths = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_def)[0]

    def init_one(path, d: ParamDef):
        key = jax.random.fold_in(rng, _path_hash(path))
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "const":
            return jnp.full(d.shape, d.scale, d.dtype)
        if d.init == "arange_log":  # mamba A_log init: log(uniform[1, 16])
            u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(d.dtype)
        scale = d.scale
        if scale < 0:
            scale = 1.0 / np.sqrt(_fan_in(d))
        return (scale * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)

    leaves = [init_one(p, d) for p, d in paths]
    treedef = jax.tree_util.tree_structure(tree, is_leaf=is_def)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract(tree):
    """ShapeDtypeStruct tree — zero-allocation stand-in for the dry-run."""
    return _tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def logical_axes(tree):
    """Tree of logical-axis tuples, mirroring the param tree."""
    return _tree_map_defs(lambda d: d.axes, tree)


def _fan_in(d: ParamDef) -> float:
    """Fan-in from the logical-axis layout: 2D mats are (in, out); 3D
    projections back to the residual stream (last axis "embed", e.g.
    wo (H, hd, d)) contract everything before it; other 3D projections
    (wq (d, H, hd), wk_b (lora, H, hd)) contract their first dim."""
    if len(d.shape) < 2:
        return float(d.shape[-1])
    if len(d.shape) == 2:
        return float(d.shape[0])
    if d.axes and d.axes[-1] == "embed":
        return float(np.prod(d.shape[:-1]))
    return float(d.shape[0])


def _path_hash(path) -> int:
    # zlib.crc32, NOT hash(): python str hashing is salted per process,
    # which would make "seeded" init non-reproducible across runs
    import zlib
    s = "/".join(str(p) for p in path)
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def param_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(abstract(tree))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves)


def count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(abstract(tree))
    return sum(int(np.prod(l.shape)) for l in leaves)
