from repro.models.runtime import Runtime, CPU_RUNTIME
from repro.models.transformer import model_defs, forward, unembed_matrix
from repro.models import param, layers, moe, mamba

__all__ = ["Runtime", "CPU_RUNTIME", "model_defs", "forward",
           "unembed_matrix", "param", "layers", "moe", "mamba"]
