"""Small convolutional classifier — the paper's Figure-1 network ("a
network with two convolutional layers") used for the CIFAR10-proxy
experiments (Table 2 reproduction at reduced scale)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef, materialize


def convnet_defs(n_classes: int = 10, width: int = 32):
    return {
        "conv1": ParamDef((3, 3, 3, width), (None, None, None, None), scale=0.1),
        "b1": ParamDef((width,), (None,), "zeros"),
        "conv2": ParamDef((3, 3, width, 2 * width), (None, None, None, None), scale=0.1),
        "b2": ParamDef((2 * width,), (None,), "zeros"),
        "fc1": ParamDef((2 * width * 8 * 8, 128), (None, None)),
        "bf": ParamDef((128,), (None,), "zeros"),
        "fc2": ParamDef((128, n_classes), (None, None)),
        "bo": ParamDef((n_classes,), (None,), "zeros"),
    }


def ghost_norm(h: jnp.ndarray, ghost_batch: int,
               eps: float = 1e-5) -> jnp.ndarray:
    """Parameter-free ghost batch normalization (Hoffer et al. 2017,
    1705.08741): standardize each channel over VIRTUAL batches of
    ``ghost_batch`` examples instead of the full batch, so large-batch
    training keeps the small-batch normalization noise the paper's
    comparisons control for.  No learned scale/shift and no running
    statistics — eval uses the same batch statistics."""
    b = h.shape[0]
    g = min(ghost_batch, b)
    if b % g:
        raise ValueError(f"ghost_batch {g} must divide the batch {b}")
    hg = h.reshape(b // g, g, *h.shape[1:])
    axes = tuple(range(1, hg.ndim - 1))     # ghost batch + spatial, not C
    mu = hg.mean(axes, keepdims=True)
    var = hg.var(axes, keepdims=True)
    return ((hg - mu) / jnp.sqrt(var + eps)).reshape(h.shape)


def convnet_apply(p: Dict, x: jnp.ndarray,
                  ghost_batch: int | None = None) -> jnp.ndarray:
    """x: (B, 32, 32, 3) -> logits (B, n_classes).  ``ghost_batch``
    normalizes each conv pre-activation over ghost groups."""
    def conv(x, w, b, stride=1):
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + b
        if ghost_batch:
            y = ghost_norm(y, ghost_batch)
        return jax.nn.relu(y)

    h = conv(x, p["conv1"], p["b1"])
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")          # 16x16
    h = conv(h, p["conv2"], p["b2"])
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")          # 8x8
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1"] + p["bf"])
    return h @ p["fc2"] + p["bo"]


def ce_loss(p, x, y, ghost_batch=None):
    logits = convnet_apply(p, x, ghost_batch=ghost_batch)
    ll = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(ll, y[:, None], axis=1))


def accuracy(p, x, y, ghost_batch=None):
    return jnp.mean(
        jnp.argmax(convnet_apply(p, x, ghost_batch=ghost_batch), -1) == y)


def init_convnet(seed: int = 0, **kw):
    return materialize(convnet_defs(**kw), jax.random.PRNGKey(seed))
