"""Core layers: norms, RoPE, attention (GQA / MLA / sliding-window /
softcap / QK-norm / cross), gated & ungated MLPs.

All functions are pure: ``(params_subtree, inputs, cfg, ...) -> outputs``.
Abstract parameter trees are built by the ``*_defs`` functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef

NEG_INF = -2.0e38  # large-negative for masking (fp32-safe)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(dim: int):
    return {"scale": ParamDef((dim,), ("norm",), "ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_defs(dim: int):
    return {"scale": ParamDef((dim,), ("norm",), "ones"),
            "bias": ParamDef((dim,), ("norm",), "zeros")}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm_defs(cfg: ModelConfig):
    return layernorm_defs(cfg.d_model) if cfg.act == "gelu" and cfg.is_encoder_decoder \
        else rmsnorm_defs(cfg.d_model)


def apply_norm(cfg: ModelConfig, p, x):
    if "bias" in p:
        return layernorm(p, x)
    return rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding, llama split-half convention.

    x: (..., S, n_heads_or_1, hd) ; pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU and ungated whisper-style)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int, gated: bool = True):
    d = cfg.d_model
    if gated:
        return {"wg": ParamDef((d, d_ff), ("embed", "ffn")),
                "wu": ParamDef((d, d_ff), ("embed", "ffn")),
                "wd": ParamDef((d_ff, d), ("ffn", "embed"))}
    return {"w1": ParamDef((d, d_ff), ("embed", "ffn")),
            "b1": ParamDef((d_ff,), ("ffn",), "zeros"),
            "w2": ParamDef((d_ff, d), ("ffn", "embed")),
            "b2": ParamDef((d,), ("norm",), "zeros")}


def _act(cfg: ModelConfig, x):
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def mlp(p, x, cfg: ModelConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    if "wg" in p:
        h = _act(cfg, xc @ p["wg"].astype(cdt)) * (xc @ p["wu"].astype(cdt))
        return h @ p["wd"].astype(cdt)
    h = _act(cfg, xc @ p["w1"].astype(cdt) + p["b1"].astype(cdt))
    return h @ p["w2"].astype(cdt) + p["b2"].astype(cdt)


# ---------------------------------------------------------------------------
# attention — GQA (+ sliding window, softcap, qk-norm) and MLA
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, cross: bool = False):
    d, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if cfg.mla is not None and not cross:
        m = cfg.mla
        qk_hd = m.qk_nope_dim + m.qk_rope_dim
        defs = {
            "wkv_a": ParamDef((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_lora_in")),
            "kv_norm": ParamDef((m.kv_lora_rank,), ("norm",), "ones"),
            "wk_b": ParamDef((m.kv_lora_rank, H, m.qk_nope_dim), ("kv_lora", "heads", "head_dim")),
            "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim), ("kv_lora", "heads", "head_dim")),
            "wo": ParamDef((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
        }
        if m.q_lora_rank:
            defs["wq_a"] = ParamDef((d, m.q_lora_rank), ("embed", "q_lora"))
            defs["q_norm"] = ParamDef((m.q_lora_rank,), ("norm",), "ones")
            defs["wq_b"] = ParamDef((m.q_lora_rank, H, qk_hd), ("q_lora", "heads", "head_dim"))
        else:
            defs["wq"] = ParamDef((d, H, qk_hd), ("embed", "heads", "head_dim"))
        return defs
    defs = {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        defs["qn"] = ParamDef((hd,), ("norm",), "ones")
        defs["kn"] = ParamDef((hd,), ("norm",), "ones")
    return defs


def _qk_rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ring_cache(entries, S: int, window: int):
    """Compress full-seq cache entries {name: (B,S,...)} + implicit positions
    arange(S) into a ring buffer of size ``window`` (slot = pos % window),
    so a windowed layer's decode state is O(W) not O(S)."""
    B = next(iter(entries.values())).shape[0]
    if window <= 0 or S <= window:
        sp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return {**entries, "slot_pos": sp}
    pos = jnp.arange(S - window, S, dtype=jnp.int32)       # kept positions
    slots = pos % window                                    # a permutation of 0..W-1
    inv = jnp.zeros((window,), jnp.int32).at[slots].set(jnp.arange(window))
    out = {k: v[:, -window:][:, inv] for k, v in entries.items()}
    out["slot_pos"] = jnp.broadcast_to(pos[inv], (B, window))
    return out


def _chunk_mask(q0: int, Qc: int, T: int, causal: bool, window: int):
    """(Qc,T) additive mask for the q-rows [q0, q0+Qc)."""
    i = q0 + jnp.arange(Qc)[:, None]
    j = jnp.arange(T)[None, :]
    ok = jnp.ones((Qc, T), bool)
    if causal:
        ok &= j <= i
    if window > 0:
        ok &= j > i - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _repeat_kv(k, H: int):
    """(B,T,K,hd) -> (B,T,H,hd).  With heads sharded over "model" each
    device materializes only its own heads' K/V — the repeat is free in
    per-device memory, and FLAT head layout (no (K,G) reshape) lets the
    SPMD partitioner keep q/scores head-sharded (a (K,G) factored reshape
    of a 16-way-sharded 64-head dim is unrepresentable when K=8)."""
    K = k.shape[2]
    if K == H:
        return k
    return jnp.repeat(k, H // K, axis=2)


def _sdpa(q, k, v, mask, cap, scale, bf16_mm: bool = False):
    """q: (B,S,H,hd)  k,v: (B,T,K,hd), K | H.  mask: broadcast (B,H,S,T).

    bf16_mm (§Perf): QK^T and PV run bf16-in/f32-accumulate (the MXU's
    native mode) instead of fully-f32 operands — softmax math stays f32."""
    H = q.shape[2]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    if bf16_mm:
        s = jnp.einsum("bshd,bthd->bhst",
                       (q.astype(jnp.float32) * scale).astype(jnp.bfloat16),
                       k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    else:
        s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
    s = softcap(s, cap) + mask
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
    return o


# Route paged decode attention through the Pallas paged-attention
# kernel: None = auto (TPU only), True/False = force.  The jnp
# gather path below is the bitwise reference against the dense decode
# engine; the kernel is the TPU fast path (agrees to ~1e-6 atol in
# fp32 — online vs two-pass softmax reassociates the reduction).
PAGED_DECODE_KERNEL: Optional[bool] = None


def _use_paged_kernel() -> bool:
    if PAGED_DECODE_KERNEL is None:
        return jax.default_backend() == "tpu"
    return PAGED_DECODE_KERNEL


def _paged_write(pool, new, bt, pos):
    """Write this step's entry into the block pool through the table:
    pool (nb, bs, *tail) <- new (B, 1, *tail) at absolute position
    pos (B,).  Active slots always target a private (refcount-1) block;
    inactive slots target the reserved scratch block 0."""
    bs = pool.shape[1]
    B = bt.shape[0]
    bid = bt[jnp.arange(B), (pos // bs).astype(jnp.int32)]
    off = (pos % bs).astype(jnp.int32)
    return pool.at[bid, off].set(new[:, 0].astype(pool.dtype))


def _paged_gather(pool, bt):
    """Dense (B, nbmax*bs, *tail) view of a slot's entries gathered
    through its block table.  Positions t <= pos hold real entries in
    position order (identical to the unrotated dense cache layout);
    everything else is garbage that the caller masks with NEG_INF."""
    B, nbmax = bt.shape
    bs = pool.shape[1]
    return pool[bt].reshape((B, nbmax * bs) + pool.shape[2:])


def _paged_valid(pos, T: int, window: int):
    """(B, T) validity mask for gathered entries: written and causal
    (t <= pos), inside the sliding window when one applies."""
    t_ids = jnp.arange(T, dtype=jnp.int32)[None, :]
    valid = t_ids <= pos[:, None]
    if window > 0:
        valid &= t_ids > pos[:, None] - window
    return valid


Q_CHUNK = 1024


def _sdpa_seq(q, k, v, causal: bool, window: int, cap, scale,
              bf16_mm: bool = False):
    """Full-sequence attention, chunked over the query dim: scores exist
    only per (Q_CHUNK, T) block (XLA-level flash attention; a (S,T) score
    tensor or mask at 32k would be tens of GB).  Each chunk is
    ``jax.checkpoint``ed so backward recomputes its scores."""
    B, S, H, hd = q.shape
    hd_v = v.shape[-1]          # MLA: qk dim (192) != v head dim (128)
    T = k.shape[1]
    if S <= Q_CHUNK or S % Q_CHUNK != 0:  # small or indivisible (enc 1500)
        return _sdpa(q, k, v, _chunk_mask(0, S, T, causal, window)
                     if (causal or window) else jnp.zeros((), jnp.float32),
                     cap, scale, bf16_mm)
    nc = S // Q_CHUNK

    def chunk(c, q_c):
        mask = (_chunk_mask(c * Q_CHUNK, Q_CHUNK, T, causal, window)
                if (causal or window) else jnp.zeros((), jnp.float32))
        return _sdpa(q_c, k, v, mask, cap, scale, bf16_mm)

    chunk = jax.checkpoint(chunk, static_argnums=())

    def body(_, xs):
        c, q_c = xs
        return None, chunk(c, q_c)

    qs = jnp.moveaxis(q.reshape(B, nc, Q_CHUNK, H, hd), 1, 0)
    _, outs = jax.lax.scan(body, None, (jnp.arange(nc), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd_v)


def gqa_attention(p, x, cfg: ModelConfig, *, local: bool, pos, cache=None,
                  causal: bool = True, kv_input=None):
    """General attention. Modes:
      * full-seq (train/prefill): cache=None, pos (B,S) absolute positions.
      * decode: cache={"k","v","slot_pos"}, x (B,1,d), pos (B,) current index.
      * cross: kv_input (B,T,d) (encoder output); no rope, no cache mutation.
    Returns (out, new_cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    B = x.shape[0]
    xc = x.astype(cdt)
    cross = kv_input is not None
    window = (cfg.window if local else 0)

    q = jnp.einsum("bsd,dkh->bskh", xc, p["wq"].astype(cdt))
    src = kv_input.astype(cdt) if cross else xc
    k = jnp.einsum("bsd,dkh->bskh", src, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dkh->bskh", src, p["wv"].astype(cdt))
    if cfg.qk_norm and not cross:
        q = _qk_rms(q, p["qn"], cfg.norm_eps)
        k = _qk_rms(k, p["kn"], cfg.norm_eps)
    if not cross:
        q = rope(q, pos if pos.ndim == 2 else pos[:, None], cfg.rope_theta)
        k = rope(k, pos if pos.ndim == 2 else pos[:, None], cfg.rope_theta)
    scale = hd ** -0.5

    if cache is None:  # full-sequence
        S = x.shape[1]
        o = _sdpa_seq(q, k, v, causal and not cross, window,
                      cfg.attn_softcap, scale, bf16_mm=cfg.sdpa_bf16)
        new_cache = None
        if not cross and causal:
            new_cache = ring_cache({"k": k, "v": v}, S, window)
        return jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt)), new_cache

    # ---- decode (x is (B,1,d)) ----
    if "kp" in cache:  # paged: write/read through the block table
        kp = _paged_write(cache["kp"], k, cache["bt"], pos)
        vp = _paged_write(cache["vp"], v, cache["bt"], pos)
        new_cache = {"kp": kp, "vp": vp, "bt": cache["bt"]}
        if _use_paged_kernel():
            from repro.kernels.paged_attention.ops import paged_attention
            o = paged_attention(q[:, 0], kp, vp, cache["bt"], pos,
                                window=window,
                                softcap=cfg.attn_softcap)[:, None]
        else:
            kd = _paged_gather(kp, cache["bt"])
            vd = _paged_gather(vp, cache["bt"])
            valid = _paged_valid(pos, kd.shape[1], window)
            mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
            o = _sdpa(q, kd.astype(cdt), vd.astype(cdt), mask,
                      cfg.attn_softcap, scale, cfg.sdpa_bf16)
        out = jnp.einsum("bshd,hdo->bso", o.astype(cdt), p["wo"].astype(cdt))
        return out, new_cache

    Sc = cache["k"].shape[1]
    slot = (pos % Sc).astype(jnp.int32)                      # ring-buffer slot
    upd = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, 0))
    new_k = upd(cache["k"], k.astype(cache["k"].dtype), slot)
    new_v = upd(cache["v"], v.astype(cache["v"].dtype), slot)
    new_sp = jax.vmap(lambda spv, s, pp: jax.lax.dynamic_update_slice(
        spv, pp[None].astype(jnp.int32), (s,)))(cache["slot_pos"], slot, pos)
    valid = new_sp >= 0
    valid &= new_sp[:, :] <= pos[:, None]
    if window > 0:
        valid &= new_sp > (pos[:, None] - window)
    mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]     # (B,1,1,T)
    o = _sdpa(q, new_k.astype(cdt), new_v.astype(cdt), mask, cfg.attn_softcap,
              scale, cfg.sdpa_bf16)
    out = jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt))
    return out, {"k": new_k, "v": new_v, "slot_pos": new_sp}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_q(p, xc, cfg, cdt):
    m = cfg.mla
    if m.q_lora_rank:
        ql = rmsnorm({"scale": p["q_norm"]}, xc @ p["wq_a"].astype(cdt), cfg.norm_eps)
        q = jnp.einsum("bsr,rkh->bskh", ql.astype(cdt), p["wq_b"].astype(cdt))
    else:
        q = jnp.einsum("bsd,dkh->bskh", xc, p["wq"].astype(cdt))
    return q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]


def mla_attention(p, x, cfg: ModelConfig, *, local: bool, pos, cache=None):
    """MLA: full-seq path decompresses K/V; decode path runs *absorbed*
    attention directly in the kv_lora latent space, caching only
    (c_kv, k_rope) — the technique's memory win."""
    cdt = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    H = cfg.n_heads
    B = x.shape[0]
    xc = x.astype(cdt)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    window = (cfg.window if local else 0)

    q_nope, q_rope = _mla_q(p, xc, cfg, cdt)
    kv_a = xc @ p["wkv_a"].astype(cdt)                      # (B,S,lora+rope)
    ckv = rmsnorm({"scale": p["kv_norm"]}, kv_a[..., :m.kv_lora_rank], cfg.norm_eps).astype(cdt)
    k_rope = kv_a[..., m.kv_lora_rank:]                     # shared across heads

    pos2 = pos if pos.ndim == 2 else pos[:, None]
    q_rope = rope(q_rope, pos2, cfg.rope_theta)
    k_rope = rope(k_rope[..., None, :], pos2, cfg.rope_theta)[..., 0, :]

    if cache is None:  # full-sequence: decompress (standard MHA form)
        S = x.shape[1]
        k_nope = jnp.einsum("bsr,rkh->bskh", ckv, p["wk_b"].astype(cdt))
        v = jnp.einsum("bsr,rkh->bskh", ckv, p["wv_b"].astype(cdt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                      (B, S, H, m.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)          # (B,S,H,qk)
        o = _sdpa_seq(q, k, v, True, window, cfg.attn_softcap, scale,
                      bf16_mm=cfg.sdpa_bf16)
        out = jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt))
        new_cache = ring_cache({"ckv": ckv, "krope": k_rope}, S, window)
        return out, new_cache

    # ---- absorbed decode ----
    if "ckvp" in cache:  # paged: latent pools through the block table
        ckvp = _paged_write(cache["ckvp"], ckv, cache["bt"], pos)
        kropep = _paged_write(cache["kropep"], k_rope, cache["bt"], pos)
        ckv_d = _paged_gather(ckvp, cache["bt"])           # (B, T, r)
        kr_d = _paged_gather(kropep, cache["bt"])          # (B, T, rr)
        q_lat = jnp.einsum("bskh,rkh->bskr", q_nope, p["wk_b"].astype(cdt))
        s = jnp.einsum("bskr,btr->bkst", q_lat.astype(jnp.float32),
                       ckv_d.astype(jnp.float32))
        s = s + jnp.einsum("bskh,bth->bkst", q_rope.astype(jnp.float32),
                           kr_d.astype(jnp.float32))
        s = s * scale
        valid = _paged_valid(pos, ckv_d.shape[1], window)
        s = softcap(s, cfg.attn_softcap) + \
            jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bkst,btr->bskr", prob.astype(cdt),
                         ckv_d.astype(cdt))
        o = jnp.einsum("bskr,rkh->bskh", ctx, p["wv_b"].astype(cdt))
        out = jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt))
        return out, {"ckvp": ckvp, "kropep": kropep, "bt": cache["bt"]}

    Sc = cache["ckv"].shape[1]
    slot = (pos % Sc).astype(jnp.int32)
    upd2 = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, 0))
    new_ckv = upd2(cache["ckv"], ckv.astype(cache["ckv"].dtype), slot)
    new_kr = upd2(cache["krope"], k_rope.astype(cache["krope"].dtype), slot)
    new_sp = jax.vmap(lambda spv, s, pp: jax.lax.dynamic_update_slice(
        spv, pp[None].astype(jnp.int32), (s,)))(cache["slot_pos"], slot, pos)

    # absorb wk_b into the query:  q_lat = q_nope @ wk_b  (B,1,H,lora)
    q_lat = jnp.einsum("bskh,rkh->bskr", q_nope, p["wk_b"].astype(cdt))
    s = jnp.einsum("bskr,btr->bkst", q_lat.astype(jnp.float32),
                   new_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bskh,bth->bkst", q_rope.astype(jnp.float32),
                       new_kr.astype(jnp.float32))
    s = s * scale
    valid = (new_sp >= 0) & (new_sp <= pos[:, None])
    if window > 0:
        valid &= new_sp > (pos[:, None] - window)
    s = softcap(s, cfg.attn_softcap) + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkst,btr->bskr", prob.astype(cdt), new_ckv.astype(cdt))
    o = jnp.einsum("bskr,rkh->bskh", ctx, p["wv_b"].astype(cdt))   # (B,1,H,vhd)
    out = jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt))
    return out, {"ckv": new_ckv, "krope": new_kr, "slot_pos": new_sp}
