"""Model assembly: decoder-only stacks (dense / MoE / SSM / hybrid / VLM)
and the Whisper encoder-decoder, built from the layer library.

Compile tractability (DESIGN.md §7): the repeating layer *period* is
stacked and iterated with ``lax.scan`` — HLO size is O(period), not
O(n_layers).  Heterogeneous patterns (jamba 1:7+MoE, gemma2 local/global)
unroll the period inside the scan body.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, layer_pattern
from repro.models import layers, mamba, moe
from repro.models.param import ParamDef, stack
from repro.models.runtime import Runtime


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------

def _gated(cfg: ModelConfig) -> bool:
    return not cfg.is_encoder_decoder     # whisper: 2-matrix GELU MLP


def block_defs(cfg: ModelConfig, spec: LayerSpec, with_cross: bool = False):
    d: Dict[str, Any] = {}
    if spec.mixer in ("attn", "attn_local"):
        d["attn_norm"] = layers.norm_defs(cfg)
        d["attn"] = layers.attention_defs(cfg)
    else:
        d["mixer_norm"] = layers.norm_defs(cfg)
        d["mamba"] = mamba.mamba_defs(cfg)
    if with_cross:
        d["cross_norm"] = layers.norm_defs(cfg)
        d["cross"] = layers.attention_defs(cfg, cross=True)
    if spec.ffn == "dense":
        d["ffn_norm"] = layers.norm_defs(cfg)
        d["ffn"] = layers.mlp_defs(cfg, cfg.d_ff, gated=_gated(cfg))
    elif spec.ffn == "moe":
        d["ffn_norm"] = layers.norm_defs(cfg)
        d["moe"] = moe.moe_defs(cfg)
    return d


def model_defs(cfg: ModelConfig):
    prefix, period, n_periods = layer_pattern(cfg)
    cross = cfg.is_encoder_decoder
    defs: Dict[str, Any] = {
        # the table's vocab dim stays unsharded ("vocab_table" rule): XLA
        # partitions token-gathers from a vocab-sharded table by full
        # replication (involuntary remat) — d_model sharding is enough.
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab_table", "embed"),
                          "embed", scale=0.02),
        "final_norm": layers.norm_defs(cfg),
    }
    if prefix:
        defs["prefix"] = {f"P{i}": block_defs(cfg, s, cross) for i, s in enumerate(prefix)}
    defs["blocks"] = stack({f"L{i}": block_defs(cfg, s, cross)
                            for i, s in enumerate(period)}, n_periods)
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), scale=0.02)
    if cfg.is_encoder_decoder:
        enc_spec = LayerSpec("attn", "dense")
        defs["encoder"] = {
            "blocks": stack({"L0": block_defs(cfg, enc_spec)}, cfg.n_encoder_layers),
            "final_norm": layers.norm_defs(cfg),
        }
    if cfg.param_dtype != "float32":
        # mixed-precision storage (jamba-398B: fp32 state = 4.8 TB exceeds a
        # 256-chip pod's 4 TB HBM — params/grads bf16, momentum fp32)
        import jax.numpy as _jnp
        from repro.models.param import ParamDef as _PD, is_def as _is_def
        dt = _jnp.dtype(cfg.param_dtype)
        defs = jax.tree_util.tree_map(
            lambda d: d._replace(dtype=dt), defs, is_leaf=_is_def)
    return defs


# ---------------------------------------------------------------------------
# cross attention helper (whisper decoder)
# ---------------------------------------------------------------------------

def _cross_kv(p, enc, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    ck = jnp.einsum("btd,dkh->btkh", enc.astype(cdt), p["wk"].astype(cdt))
    cv = jnp.einsum("btd,dkh->btkh", enc.astype(cdt), p["wv"].astype(cdt))
    return ck, cv


def _cross_attend(p, x, cfg, ck, cv):
    cdt = jnp.dtype(cfg.compute_dtype)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S = x.shape[:2]
    q = jnp.einsum("bsd,dkh->bskh", x.astype(cdt), p["wq"].astype(cdt))
    o = layers._sdpa_seq(q, ck.astype(cdt), cv.astype(cdt),
                         False, 0, 0.0, hd ** -0.5, bf16_mm=cfg.sdpa_bf16)
    return jnp.einsum("bshd,hdo->bso", o, p["wo"].astype(cdt))


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------

def block_apply(p, spec: LayerSpec, h, cfg: ModelConfig, rt: Runtime, *,
                pos, cache=None, build_cache: bool, encoder_out=None):
    """Returns (h, new_cache_or_None, aux_loss)."""
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)

    from jax.ad_checkpoint import checkpoint_name

    def _name_tp(x):     # mark post-TP-collective activations for remat
        return checkpoint_name(x, "tp_out") if rt.remat_policy == "save_tp" else x

    if spec.mixer in ("attn", "attn_local"):
        xin = layers.apply_norm(cfg, p["attn_norm"], h)
        fn = layers.mla_attention if cfg.mla is not None else layers.gqa_attention
        a, c = fn(p["attn"], xin, cfg, local=(spec.mixer == "attn_local"),
                  pos=pos, cache=(cache or {}).get("attn"))
        h = h + _name_tp(a).astype(h.dtype)
        if build_cache:
            new_cache["attn"] = c
    else:
        xin = layers.apply_norm(cfg, p["mixer_norm"], h)
        a, c = mamba.mamba_block(p["mamba"], xin, cfg,
                                 cache=(cache or {}).get("mamba"), pos=pos)
        h = h + a.astype(h.dtype)
        if build_cache:
            new_cache["mamba"] = c

    if "cross" in p and encoder_out is not None or (cache and "cross" in cache):
        xin = layers.apply_norm(cfg, p["cross_norm"], h)
        if cache and "cross" in cache:
            ck, cv = cache["cross"]["ck"], cache["cross"]["cv"]
        else:
            ck, cv = _cross_kv(p["cross"], encoder_out, cfg)
        h = h + _cross_attend(p["cross"], xin, cfg, ck, cv).astype(h.dtype)
        if build_cache:
            new_cache["cross"] = {"ck": ck, "cv": cv}

    if spec.ffn == "dense":
        xin = layers.apply_norm(cfg, p["ffn_norm"], h)
        h = h + _name_tp(layers.mlp(p["ffn"], xin, cfg)).astype(h.dtype)
    elif spec.ffn == "moe":
        xin = layers.apply_norm(cfg, p["ffn_norm"], h)
        y, a_loss = moe.moe_apply(p["moe"], xin, cfg, rt)
        h = h + _name_tp(y).astype(h.dtype)
        aux = aux + a_loss

    return h, (new_cache if build_cache else None), aux


# ---------------------------------------------------------------------------
# whisper encoder
# ---------------------------------------------------------------------------

def _remat_group(n_periods: int) -> int:
    """Group size for sqrt-remat: ~sqrt(n), only worth it for deep stacks."""
    if n_periods < 12:
        return 1
    import math
    return max(2, round(math.sqrt(n_periods)))


def _sinusoid(T: int, d: int):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ModelConfig, rt: Runtime, encoder_embeds):
    """Stub-frontend encoder: (B, T, d) frame embeddings -> (B, T, d)."""
    h = encoder_embeds.astype(jnp.dtype(cfg.compute_dtype))
    h = h + _sinusoid(h.shape[1], cfg.d_model).astype(h.dtype)
    spec = LayerSpec("attn", "dense")

    def body(h, p_layer):
        xin = layers.apply_norm(cfg, p_layer["L0"]["attn_norm"], h)
        a, _ = layers.gqa_attention(p_layer["L0"]["attn"], xin, cfg, local=False,
                                    pos=jnp.arange(h.shape[1])[None], causal=False)
        h = h + a.astype(h.dtype)
        xin = layers.apply_norm(cfg, p_layer["L0"]["ffn_norm"], h)
        h = h + layers.mlp(p_layer["L0"]["ffn"], xin, cfg).astype(h.dtype)
        return h, None

    if rt.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
    return layers.apply_norm(cfg, params["encoder"]["final_norm"], h)


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, rt: Runtime, tokens, *,
            mode: str = "train", cache=None, pos=None, encoder_embeds=None,
            last_pos=None):
    """mode: "train" | "prefill" | "decode".

    train:   tokens (B,S)             -> (logits, None, aux)
    prefill: tokens (B,S)             -> (logits, cache, aux)
    decode:  tokens (B,1), pos (B,)   -> (logits, cache', aux)

    ``last_pos`` (B,), prefill only: per-row position whose logits to
    return instead of the last one — bucket-padded batched prefill
    right-pads each prompt to a shared length, and causal masking keeps
    every position <= last_pos bitwise independent of the padding.
    (SSM layers scan left-to-right through the padding, so bucketed
    prefill is only valid for attention-only stacks; the scheduler
    falls back to exact lengths when ``cfg.has_ssm_layers``.)
    """
    prefix, period, n_periods = layer_pattern(cfg)
    build_cache = mode != "train"
    B, S = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)

    h = params["embed"][tokens].astype(cdt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cdt)
    batch_sharded = mode != "decode" or (rt.mesh is None) or all(
        (B % rt.mesh.shape[a] == 0) for a in rt.data_axes)
    hspec = (rt.data_axes if batch_sharded else None, None, None)
    h = rt.constrain(h, *hspec)

    if mode == "decode":
        rope_pos = pos
    else:
        rope_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    encoder_out = None
    if cfg.is_encoder_decoder and encoder_embeds is not None:
        encoder_out = encode(params, cfg, rt, encoder_embeds)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    # --- unrolled prefix layers ---
    for i, spec in enumerate(prefix):
        c_in = (cache or {}).get("prefix", {}).get(f"P{i}")
        h, c, aux = block_apply(params["prefix"][f"P{i}"], spec, h, cfg, rt,
                                pos=rope_pos, cache=c_in, build_cache=build_cache,
                                encoder_out=encoder_out)
        aux_total += aux
        if build_cache:
            new_cache.setdefault("prefix", {})[f"P{i}"] = c

    # --- scanned periods ---
    remat = rt.remat and mode == "train"

    def body(carry, xs):
        hh, aux_acc = carry
        p_period, c_period = xs
        cs_out = {}
        for i, spec in enumerate(period):
            c_in = c_period[f"L{i}"] if c_period is not None else None

            def run_block(pp, hin, spec=spec, c_in=c_in):
                return block_apply(pp, spec, hin, cfg, rt, pos=rope_pos,
                                   cache=c_in, build_cache=build_cache,
                                   encoder_out=encoder_out)
            if remat:   # per-block remat: one block's internals live in bwd
                policy = None
                if rt.remat_policy == "save_tp":
                    from jax.ad_checkpoint import checkpoint_policies
                    policy = checkpoint_policies.save_only_these_names("tp_out")
                run_block = jax.checkpoint(run_block, policy=policy)
            hh, c, aux = run_block(p_period[f"L{i}"], hh)
            aux_acc = aux_acc + aux
            if build_cache:
                cs_out[f"L{i}"] = c
        hh = rt.constrain(hh, *hspec)
        return (hh, aux_acc), (cs_out if build_cache else None)

    scan_cache = (cache or {}).get("blocks")
    n_periods = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
    group = _remat_group(n_periods) if remat else 1

    if group <= 1 or build_cache:
        (h, aux_total), cache_out = jax.lax.scan(
            body, (h, aux_total), (params["blocks"], scan_cache))
        if build_cache:
            new_cache["blocks"] = cache_out
    else:
        # sqrt-remat: outer scan over groups of `group` periods with the
        # group body checkpointed — the inter-period h stash shrinks from
        # n_periods entries to n_groups (+ one group recompute in bwd).
        # Remainder periods (prime n_periods) run in a flat scan.
        n_g, rem = divmod(n_periods, group)

        def group_body(carry, xs_group):
            return jax.lax.scan(body, carry, (xs_group, None))[0], None

        group_body = jax.checkpoint(group_body)
        head = jax.tree.map(
            lambda a: a[:n_g * group].reshape(n_g, group, *a.shape[1:]),
            params["blocks"])
        (h, aux_total), _ = jax.lax.scan(group_body, (h, aux_total), head)
        if rem:
            tail = jax.tree.map(lambda a: a[n_g * group:], params["blocks"])
            (h, aux_total), _ = jax.lax.scan(body, (h, aux_total),
                                             (tail, None))

    h = layers.apply_norm(cfg, params["final_norm"], h)

    if mode == "train":
        # Return hidden states; the loss computes the vocab projection in
        # sequence chunks so (B,S,vocab) logits never materialize
        # (vocab up to 256k -> full fp32 logits would be tens of GB).
        return h, None, aux_total

    if mode == "prefill":
        # serving only needs one position's logits per row: the last, or
        # the per-row prompt end under bucket-padded batched prefill
        h = (h[:, -1:, :] if last_pos is None
             else h[jnp.arange(B), last_pos.astype(jnp.int32)][:, None])
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        unembed_matrix(params).astype(jnp.float32))
    logits = layers.softcap(logits, cfg.final_softcap)
    return logits, new_cache, aux_total


def unembed_matrix(params):
    u = params.get("unembed")
    return u if u is not None else params["embed"].T
