from repro.checkpoint.io import (check_loadable, is_committed,
                                 load_checkpoint, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "is_committed",
           "check_loadable"]
