from repro.checkpoint.io import (AsyncCheckpointer, check_loadable,
                                 is_committed, load_checkpoint,
                                 load_loader_state, resolve_checkpoint,
                                 save_checkpoint, step_dir)

__all__ = ["save_checkpoint", "load_checkpoint", "is_committed",
           "check_loadable", "load_loader_state", "resolve_checkpoint",
           "step_dir", "AsyncCheckpointer"]
