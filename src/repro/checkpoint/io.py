"""Sharding-aware checkpointing: each host saves its addressable shards to
an .npz (path-keyed); restore re-places shards onto the current mesh.
Single-host CPU runs degenerate to a plain full save/restore.

Dtype fidelity: ``np.savez`` silently stores extension dtypes (bfloat16,
float8_*) as raw void records (``|V2``), which ``jnp.asarray`` then
rejects.  We therefore save such arrays as a same-width unsigned-int VIEW
and record the true dtype of EVERY leaf in a per-key ``dtypes`` map in
``meta.json`` (the sidecar); restore views the bits back and finally
casts every leaf to the dtype of the ``like`` template, so a checkpoint
round-trip is bit-exact in both values and dtypes while old/drifted
checkpoints still load.  Works for any state form — plain param trees,
``OptState`` pytrees, flat-buffer-resident ``FlatOptState`` (whose
static ``TreeLayout``/``form`` are pytree aux data and never touch disk;
the Adam family's ``m_flats``/``v_flats`` moment slots and the segment
compiler's ``e_flats`` EMA shadow slots — one f32 bucket set per
``ema_params`` stage, keyed under ``e_flats`` by slot-then-bucket
position — are ordinary child buffers and round-trip like any leaf;
a nesterov trace adds NO slot, its look-ahead recomputes from the same
momentum buffers), or the chain interpreter's ``ChainOptState`` (a
NamedTuple-of-NamedTuples whose keys come from the tuple positions, so
a chain's state layout — i.e. the transform sequence — must match
between save and load; the optimizer spec in ``train_meta.json`` is
what guarantees that on ``--resume``).  ``to_pytree``/``from_pytree``
interconvert the flat and pytree forms losslessly, so a checkpoint
saved in either form resumes in either execution mode — including the
``("chain", slots)`` segment-plan form, whose pytree view is the
interpreter's ``ChainOptState``.

Atomic commit: a save is staged in a ``<path>.tmp-staging`` directory,
finished with a ``COMMIT`` marker file, and renamed into place (an
existing checkpoint is moved aside, never deleted, until the new one is
installed) — so a crash mid-save can never leave a half-written
directory that LOOKS like a checkpoint.  ``check_loadable`` (used by
``load_checkpoint`` and the launcher's ``--resume``) rejects a torn
save, recovers a crash-interrupted swap from its surviving committed
staging/backup dir, and still accepts markerless LEGACY checkpoints
when demonstrably complete (meta ``n_leaves`` matches the archive).
Multi-host runs fall back to in-place shard writes with the marker
written LAST by process 0 (cross-host atomic commit is the orbax-style
coordination on the ROADMAP).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMIT"


def is_committed(path: str) -> bool:
    """True iff ``path`` holds a fully committed checkpoint (the marker is
    the LAST thing a save produces before the atomic rename)."""
    return os.path.exists(os.path.join(path, COMMIT_MARKER))


def _recover_interrupted_swap(path: str) -> None:
    """A crash between the swap's rename and replace steps leaves ``path``
    missing while a FULLY COMMITTED staging (new save) or backup (old
    save) directory survives.  Move the best committed candidate back
    into place — newest first — so neither save-over nor resume ever
    deletes or overlooks the only committed copy on disk."""
    if os.path.exists(path):
        return
    for cand in (f"{path}.tmp-staging", f"{path}.tmp-old"):
        if os.path.isdir(cand) and is_committed(cand):
            os.replace(cand, path)
            return


def check_loadable(path: str) -> None:
    """Raise unless ``path`` is safe to load: committed (marker present),
    or a LEGACY pre-marker checkpoint that is demonstrably complete —
    the old writer produced meta.json after the shard, so a markerless
    dir whose meta ``n_leaves`` matches the archive's key count was
    finished.  Anything else is a torn/interrupted save.  Recovers a
    crash-interrupted swap first (see ``_recover_interrupted_swap``)."""
    _recover_interrupted_swap(path)
    if is_committed(path):
        return
    meta_p = os.path.join(path, "meta.json")
    shard_p = os.path.join(path, f"shard_{jax.process_index():05d}.npz")
    if os.path.exists(meta_p) and os.path.exists(shard_p):
        try:
            with open(meta_p) as f:
                n_meta = json.load(f).get("n_leaves")
            n_arch = len(np.load(shard_p).files)
        except Exception:
            n_meta, n_arch = None, -1
        if n_meta is not None and n_meta == n_arch:
            return                              # legacy-complete
    raise ValueError(
        f"checkpoint at {path!r} has no {COMMIT_MARKER} marker and is not "
        f"a complete legacy save: the write was interrupted before "
        f"committing (or the directory is not a checkpoint); refusing to "
        f"load a torn save")


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(p, "key", p)) for p in path): leaf
            for path, leaf in leaves}


def _np_savable(dt: np.dtype) -> bool:
    """The .npy format round-trips only dtypes its descr strings can
    express; extension dtypes (bfloat16, float8_*) degrade to void
    records ('<V2') even though numpy can name them, so check the
    descriptor round-trip, not the dtype constructor."""
    import warnings
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            descr = np.lib.format.dtype_to_descr(dt)
            return np.lib.format.descr_to_dtype(descr) == dt
    except Exception:
        return False


def _dtype_by_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; owns bfloat16/float8_* dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _write_shard_and_meta(outdir: str, tree: Any, step: int) -> None:
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = a.dtype.name
        if not _np_savable(a.dtype):
            a = a.view(f"uint{8 * a.dtype.itemsize}")
        arrays[k] = a
    np.savez(os.path.join(outdir, f"shard_{jax.process_index():05d}.npz"),
             **arrays)
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(arrays), "format": 2,
                   "dtypes": dtypes}, f)


def _looks_like_checkpoint(path: str) -> bool:
    """Conservative guard before replacing an existing destination: only a
    previous checkpoint (committed or torn) or an empty dir may be
    clobbered — anything else is a user error we refuse to delete.
    Requires checkpoint-SPECIFIC evidence: a bare file named meta.json is
    not enough (datasets use that name too) — it must parse as our
    sidecar, or a shard archive / COMMIT marker must be present."""
    if not os.path.isdir(path):
        return False                           # a regular file is never ours
    entries = os.listdir(path)
    if not entries:
        return True
    if is_committed(path) or any(e.startswith("shard_") and e.endswith(".npz")
                                 for e in entries):
        return True
    meta_p = os.path.join(path, "meta.json")
    if os.path.exists(meta_p):
        try:
            with open(meta_p) as f:
                meta = json.load(f)
            return isinstance(meta, dict) and "n_leaves" in meta \
                and "step" in meta
        except Exception:
            return False
    return False


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    """Save ``tree`` atomically: shards + meta are staged in a temp dir,
    the ``COMMIT`` marker is written last, and the staged dir is renamed
    into place — a reader never observes a torn save at ``path``."""
    path = path.rstrip(os.sep)
    if jax.process_count() > 1:
        # multi-host: every process writes its own shard into the live
        # dir; process 0 INVALIDATES any stale marker first (an
        # interrupted overwrite must not leave an old COMMIT blessing a
        # mixed-step shard set) and drops a fresh marker after its
        # (local) writes.  Not torn-proof across hosts — the coordinated
        # commit is a ROADMAP follow-up — but single-host (the
        # container, tests) takes the atomic staging path below.
        os.makedirs(path, exist_ok=True)
        marker = os.path.join(path, COMMIT_MARKER)
        if jax.process_index() == 0 and os.path.exists(marker):
            os.remove(marker)
        _write_shard_and_meta(path, tree, step)
        if jax.process_index() == 0:
            with open(marker, "w") as f:
                f.write("committed\n")
        return
    # a previous save may have crashed mid-swap: restore its surviving
    # committed dir to `path` BEFORE the leftover cleanup below, so the
    # only committed copy on disk is never deleted
    _recover_interrupted_swap(path)
    # clobber guard BEFORE any work: never delete something that is not a
    # previous checkpoint (and never leak a staging dir on refusal)
    if os.path.exists(path) and not _looks_like_checkpoint(path):
        raise ValueError(
            f"refusing to overwrite {path!r}: it exists but does not "
            f"look like a checkpoint directory (no meta.json/"
            f"{COMMIT_MARKER}); choose an empty or fresh --ckpt path")
    staging = f"{path}.tmp-staging"
    backup = f"{path}.tmp-old"
    for leftover in (staging, backup):
        if os.path.exists(leftover):
            shutil.rmtree(leftover)
    os.makedirs(staging)
    _write_shard_and_meta(staging, tree, step)
    with open(os.path.join(staging, COMMIT_MARKER), "w") as f:
        f.write("committed\n")                 # marker iff dir is complete
    # swap: move the old checkpoint ASIDE (not rmtree) before installing
    # the staged one, so a crash at any point leaves either the old or
    # the new FULLY-COMMITTED dir on disk — never a half-written one at
    # `path`, and never a window with the only copy deleted
    if os.path.exists(path):
        os.rename(path, backup)
    os.replace(staging, path)                  # atomic on POSIX
    shutil.rmtree(backup, ignore_errors=True)


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (params/state pytree or
    abstract tree); optionally re-place onto ``shardings``.  Every
    restored leaf takes the DTYPE OF ``like`` — the sidecar recovers the
    stored bits exactly, then a cast (no-op when dtypes already agree)
    shields against checkpoints written at a different precision.

    Raises ``ValueError`` for a torn save: no ``COMMIT`` marker and not a
    demonstrably complete legacy (pre-marker) checkpoint — an interrupted
    save must never load as if it were whole.  (The launcher's
    ``--resume`` is stricter and requires the marker outright.)"""
    check_loadable(path)
    data = np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes", {})
    flat_like = _flatten(like)
    missing = sorted(set(flat_like) - set(data.files))
    if missing:
        raise KeyError(
            f"checkpoint at {path!r} lacks {len(missing)} leaves the "
            f"template expects (template/archive structure mismatch — "
            f"e.g. a different optimizer or chain layout than the one "
            f"saved): first missing {missing[:5]}")
    restored = {}
    for k, leaf in flat_like.items():
        a = data[k]
        stored = dtypes.get(k)
        if stored is not None and a.dtype.name != stored:
            a = a.view(_dtype_by_name(stored))
        want = np.dtype(leaf.dtype)
        if a.dtype.kind == "V":
            # pre-sidecar checkpoint of an extension dtype: the bits are
            # intact, only the dtype tag was lost — recover it from `like`
            if a.dtype.itemsize != want.itemsize:
                raise TypeError(
                    f"checkpoint leaf {k!r} has raw dtype {a.dtype} with no "
                    f"dtype sidecar and does not match like dtype {want}")
            a = a.view(want)
        arr = jnp.asarray(a)
        if arr.dtype != want:
            arr = arr.astype(want)
        restored[k] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = ["/".join(str(getattr(p, "key", p)) for p in path)
               for path, _ in leaves_paths]
    out = jax.tree_util.tree_unflatten(treedef, [restored[k] for k in ordered])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out, meta["step"]
