"""Sharding-aware checkpointing: each host saves its addressable shards to
an .npz (path-keyed); restore re-places shards onto the current mesh.
Single-host CPU runs degenerate to a plain full save/restore.

Dtype fidelity: ``np.savez`` silently stores extension dtypes (bfloat16,
float8_*) as raw void records (``|V2``), which ``jnp.asarray`` then
rejects.  We therefore save such arrays as a same-width unsigned-int VIEW
and record the true dtype of EVERY leaf in a per-key ``dtypes`` map in
``meta.json`` (the sidecar); restore views the bits back and finally
casts every leaf to the dtype of the ``like`` template, so a checkpoint
round-trip is bit-exact in both values and dtypes while old/drifted
checkpoints still load.  Works for any state form — plain param trees,
``OptState`` pytrees, flat-buffer-resident ``FlatOptState`` (whose
static ``TreeLayout``/``form`` are pytree aux data and never touch disk;
the Adam family's ``m_flats``/``v_flats`` moment slots are ordinary
child buffers and round-trip like any leaf), or the chain interpreter's
``ChainOptState`` (a NamedTuple-of-NamedTuples whose keys come from the
tuple positions, so a chain's state layout — i.e. the transform
sequence — must match between save and load; the optimizer spec in
``train_meta.json`` is what guarantees that on ``--resume``).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(p, "key", p)) for p in path): leaf
            for path, leaf in leaves}


def _np_savable(dt: np.dtype) -> bool:
    """The .npy format round-trips only dtypes its descr strings can
    express; extension dtypes (bfloat16, float8_*) degrade to void
    records ('<V2') even though numpy can name them, so check the
    descriptor round-trip, not the dtype constructor."""
    import warnings
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            descr = np.lib.format.dtype_to_descr(dt)
            return np.lib.format.descr_to_dtype(descr) == dt
    except Exception:
        return False


def _dtype_by_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; owns bfloat16/float8_* dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = a.dtype.name
        if not _np_savable(a.dtype):
            a = a.view(f"uint{8 * a.dtype.itemsize}")
        arrays[k] = a
    np.savez(os.path.join(path, f"shard_{jax.process_index():05d}.npz"),
             **arrays)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(arrays), "format": 2,
                   "dtypes": dtypes}, f)


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (params/state pytree or
    abstract tree); optionally re-place onto ``shardings``.  Every
    restored leaf takes the DTYPE OF ``like`` — the sidecar recovers the
    stored bits exactly, then a cast (no-op when dtypes already agree)
    shields against checkpoints written at a different precision."""
    data = np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes", {})
    flat_like = _flatten(like)
    missing = sorted(set(flat_like) - set(data.files))
    if missing:
        raise KeyError(
            f"checkpoint at {path!r} lacks {len(missing)} leaves the "
            f"template expects (template/archive structure mismatch — "
            f"e.g. a different optimizer or chain layout than the one "
            f"saved): first missing {missing[:5]}")
    restored = {}
    for k, leaf in flat_like.items():
        a = data[k]
        stored = dtypes.get(k)
        if stored is not None and a.dtype.name != stored:
            a = a.view(_dtype_by_name(stored))
        want = np.dtype(leaf.dtype)
        if a.dtype.kind == "V":
            # pre-sidecar checkpoint of an extension dtype: the bits are
            # intact, only the dtype tag was lost — recover it from `like`
            if a.dtype.itemsize != want.itemsize:
                raise TypeError(
                    f"checkpoint leaf {k!r} has raw dtype {a.dtype} with no "
                    f"dtype sidecar and does not match like dtype {want}")
            a = a.view(want)
        arr = jnp.asarray(a)
        if arr.dtype != want:
            arr = arr.astype(want)
        restored[k] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = ["/".join(str(getattr(p, "key", p)) for p in path)
               for path, _ in leaves_paths]
    out = jax.tree_util.tree_unflatten(treedef, [restored[k] for k in ordered])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out, meta["step"]
