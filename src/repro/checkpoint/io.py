"""Sharding-aware checkpointing: each host saves its addressable shards to
an .npz (path-keyed); restore re-places shards onto the current mesh.
Single-host CPU runs degenerate to a plain full save/restore.

Dtype fidelity: ``np.savez`` silently stores extension dtypes (bfloat16,
float8_*) as raw void records (``|V2``), which ``jnp.asarray`` then
rejects.  We therefore save such arrays as a same-width unsigned-int VIEW
and record the true dtype of EVERY leaf in a per-key ``dtypes`` map in
``meta.json`` (the sidecar); restore views the bits back and finally
casts every leaf to the dtype of the ``like`` template, so a checkpoint
round-trip is bit-exact in both values and dtypes while old/drifted
checkpoints still load.  Works for any state form — plain param trees,
``OptState`` pytrees, flat-buffer-resident ``FlatOptState`` (whose
static ``TreeLayout``/``form`` are pytree aux data and never touch disk;
the Adam family's ``m_flats``/``v_flats`` moment slots and the segment
compiler's ``e_flats`` EMA shadow slots — one f32 bucket set per
``ema_params`` stage, keyed under ``e_flats`` by slot-then-bucket
position — are ordinary child buffers and round-trip like any leaf;
a nesterov trace adds NO slot, its look-ahead recomputes from the same
momentum buffers), or the chain interpreter's ``ChainOptState`` (a
NamedTuple-of-NamedTuples whose keys come from the tuple positions, so
a chain's state layout — i.e. the transform sequence — must match
between save and load; the optimizer spec in ``train_meta.json`` is
what guarantees that on ``--resume``).  ``to_pytree``/``from_pytree``
interconvert the flat and pytree forms losslessly, so a checkpoint
saved in either form resumes in either execution mode — including the
``("chain", slots)`` segment-plan form, whose pytree view is the
interpreter's ``ChainOptState``.

Atomic commit: a save is staged in a ``<path>.tmp-staging`` directory,
finished with a ``COMMIT`` marker file, and renamed into place (an
existing checkpoint is moved aside, never deleted, until the new one is
installed) — so a crash mid-save can never leave a half-written
directory that LOOKS like a checkpoint.  ``check_loadable`` (used by
``load_checkpoint`` and the launcher's ``--resume``) rejects a torn
save, recovers a crash-interrupted swap from its surviving committed
staging/backup dir, and still accepts markerless LEGACY checkpoints
when demonstrably complete (meta ``n_leaves`` matches the archive).
Multi-host runs take the coordinated shared-filesystem barrier
(``_multihost_save``): every rank stages its shard plus a per-rank done
marker, and process 0 writes ``COMMIT`` and swaps the staged dir into
place only after ALL ranks report done — so the marker can never bless
a shard set another host was still writing.

Loader state (meta format 3): ``save_checkpoint(..., loader_state=)``
persists the data pipeline's serialized cursor (``repro.data.loader
.LoaderState.to_dict()`` — epoch, shard cursor, within-shard offset,
rng key) as a ``loader_state`` entry in ``meta.json``, and
``load_loader_state`` reads it back — so ``--resume`` re-seeks the
``StreamingLoader`` and batch ``t`` after resume is bitwise the batch
``t`` of an uninterrupted run.  Format 2 checkpoints (no entry) load
fine and report no loader state; format 3 adds only the two optional
entries ``loader_state`` and ``metric``, so older readers that ignore
unknown keys keep working.  Under prefetch the caller must snapshot
``PrefetchIterator.state`` (the cursor of the next batch TRAINING will
consume), not the run-ahead loader's.

Retention & symlinks: ``save_checkpoint(..., keep_last_n=, metric=)``
maintains sibling symlinks ``latest`` (always the newest commit) and
``best`` (the commit with the LOWEST ``metric`` seen so far, e.g. loss)
next to step-named checkpoint dirs (``step_00000010/``), then prunes
older committed ``step_*`` siblings beyond ``keep_last_n`` — never the
dir a symlink points at, never the one just written, and never a
non-checkpoint dir.  ``resolve_checkpoint`` follows ``latest`` (or
picks the newest committed ``step_*`` child) so ``--resume`` can point
at the base directory.

Async save: ``AsyncCheckpointer.save`` copies device→host synchronously
at the step boundary (so the donated ``TrainState`` buffers are free to
be aliased by the very next step) and runs the UNCHANGED atomic-commit
path above on a background thread — training never blocks on commit
I/O.  Saves commit in submission order (one worker, FIFO); ``wait()``
drains the queue and re-raises the first background failure.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = "COMMIT"


def is_committed(path: str) -> bool:
    """True iff ``path`` holds a fully committed checkpoint (the marker is
    the LAST thing a save produces before the atomic rename)."""
    return os.path.exists(os.path.join(path, COMMIT_MARKER))


def _recover_interrupted_swap(path: str) -> None:
    """A crash between the swap's rename and replace steps leaves ``path``
    missing while a FULLY COMMITTED staging (new save) or backup (old
    save) directory survives.  Move the best committed candidate back
    into place — newest first — so neither save-over nor resume ever
    deletes or overlooks the only committed copy on disk."""
    if os.path.exists(path):
        return
    for cand in (f"{path}.tmp-staging", f"{path}.tmp-old"):
        if os.path.isdir(cand) and is_committed(cand):
            os.replace(cand, path)
            return


def check_loadable(path: str) -> None:
    """Raise unless ``path`` is safe to load: committed (marker present),
    or a LEGACY pre-marker checkpoint that is demonstrably complete —
    the old writer produced meta.json after the shard, so a markerless
    dir whose meta ``n_leaves`` matches the archive's key count was
    finished.  Anything else is a torn/interrupted save.  Recovers a
    crash-interrupted swap first (see ``_recover_interrupted_swap``)."""
    _recover_interrupted_swap(path)
    if is_committed(path):
        return
    meta_p = os.path.join(path, "meta.json")
    shard_p = os.path.join(path, f"shard_{jax.process_index():05d}.npz")
    if os.path.exists(meta_p) and os.path.exists(shard_p):
        try:
            with open(meta_p) as f:
                n_meta = json.load(f).get("n_leaves")
            n_arch = len(np.load(shard_p).files)
        except Exception:
            n_meta, n_arch = None, -1
        if n_meta is not None and n_meta == n_arch:
            return                              # legacy-complete
    raise ValueError(
        f"checkpoint at {path!r} has no {COMMIT_MARKER} marker and is not "
        f"a complete legacy save: the write was interrupted before "
        f"committing (or the directory is not a checkpoint); refusing to "
        f"load a torn save")


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(p, "key", p)) for p in path): leaf
            for path, leaf in leaves}


def _np_savable(dt: np.dtype) -> bool:
    """The .npy format round-trips only dtypes its descr strings can
    express; extension dtypes (bfloat16, float8_*) degrade to void
    records ('<V2') even though numpy can name them, so check the
    descriptor round-trip, not the dtype constructor."""
    import warnings
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            descr = np.lib.format.dtype_to_descr(dt)
            return np.lib.format.descr_to_dtype(descr) == dt
    except Exception:
        return False


def _dtype_by_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; owns bfloat16/float8_* dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _write_shard_and_meta(outdir: str, tree: Any, step: int,
                          loader_state: Optional[Dict[str, Any]] = None,
                          metric: Optional[float] = None, *,
                          process_index: Optional[int] = None,
                          write_meta: bool = True) -> None:
    """Write this process's shard archive (and, when ``write_meta``, the
    meta.json sidecar — exactly ONE writer per save under the multi-host
    barrier, so the sidecar can never tear from concurrent writes)."""
    rank = jax.process_index() if process_index is None else process_index
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = a.dtype.name
        if not _np_savable(a.dtype):
            a = a.view(f"uint{8 * a.dtype.itemsize}")
        arrays[k] = a
    np.savez(os.path.join(outdir, f"shard_{rank:05d}.npz"), **arrays)
    if not write_meta:
        return
    meta: Dict[str, Any] = {"step": step, "n_leaves": len(arrays),
                            "format": 3, "dtypes": dtypes}
    if loader_state is not None:
        meta["loader_state"] = loader_state
    if metric is not None:
        meta["metric"] = float(metric)
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f)


# ---------------------------------------------------------------------------
# multi-host coordinated commit (shared-filesystem marker barrier)
# ---------------------------------------------------------------------------

def _wait_for(predicate, timeout_s: float, poll_s: float, desc: str) -> None:
    """Poll ``predicate`` until true; TimeoutError naming ``desc``
    otherwise.  Plain filesystem polling — the barrier must work with
    nothing but the shared checkpoint directory (no collective runtime),
    so it also coordinates processes that are mid-teardown."""
    deadline = time.monotonic() + timeout_s
    while not predicate():
        if time.monotonic() >= deadline:
            raise TimeoutError(
                f"multi-host checkpoint barrier timed out after "
                f"{timeout_s:.0f}s waiting for {desc}")
        time.sleep(poll_s)


def _ready_marker(staging: str, step: int) -> str:
    return os.path.join(staging, f".ready.{step}")


def _done_marker(staging: str, step: int, rank: int) -> str:
    return os.path.join(staging, f".done.{step}.{rank:05d}")


def _multihost_save(path: str, tree: Any, step: int,
                    loader_state: Optional[Dict[str, Any]],
                    metric: Optional[float],
                    keep_last_n: Optional[int], *,
                    process_index: Optional[int] = None,
                    process_count: Optional[int] = None,
                    timeout_s: float = 300.0,
                    poll_s: float = 0.05) -> None:
    """Coordinated atomic commit over a SHARED filesystem.

    The old multi-host path wrote shards straight into the live dir with
    process 0 dropping the marker after its own (local) write — a commit
    race: a fast process 0 could bless a shard set other hosts were
    still writing, and a crashed peer left a torn-but-committed dir.
    This barrier stages everything and commits only after every rank
    reports done:

      rank 0   prepares ``<path>.tmp-staging`` and drops ``.ready.<step>``
      ranks    wait for ready, write ``shard_<rank>.npz``, then drop
               ``.done.<step>.<rank>``   (meta.json: rank 0 only — one
               sidecar writer, no tearing)
      rank 0   waits for ALL done markers, removes the barrier markers,
               writes COMMIT, and swaps the staged dir into place
               (rename-aside + replace, same crash story as single-host)
      ranks    wait until ``path`` is committed at this step

    A crash before COMMIT leaves an uncommitted staging dir that
    ``check_loadable`` rejects and the next save clears; a crash during
    the swap is recovered by ``_recover_interrupted_swap``.  Saves are
    collective and in program order on every rank (the launcher's hooks
    guarantee this).  ``process_index``/``process_count`` default to the
    jax runtime but stay injectable so thread-based tests can exercise
    the barrier without a multi-process jax client."""
    rank = jax.process_index() if process_index is None else process_index
    world = jax.process_count() if process_count is None else process_count
    staging = f"{path}.tmp-staging"
    backup = f"{path}.tmp-old"
    ready = _ready_marker(staging, step)
    if rank == 0:
        _recover_interrupted_swap(path)
        if os.path.exists(path) and not _looks_like_checkpoint(path):
            raise ValueError(
                f"refusing to overwrite {path!r}: it exists but does not "
                f"look like a checkpoint directory (no meta.json/"
                f"{COMMIT_MARKER}); choose an empty or fresh --ckpt path")
        for leftover in (staging, backup):
            if os.path.exists(leftover):
                shutil.rmtree(leftover)
        os.makedirs(staging)
        with open(ready, "w") as f:
            f.write("ready\n")
    else:
        _wait_for(lambda: os.path.exists(ready), timeout_s, poll_s,
                  f"rank 0 to stage {staging!r} for step {step}")
    _write_shard_and_meta(staging, tree, step, loader_state, metric,
                          process_index=rank, write_meta=(rank == 0))
    with open(_done_marker(staging, step, rank), "w") as f:
        f.write("done\n")
    if rank == 0:
        def all_done():
            return all(os.path.exists(_done_marker(staging, step, r))
                       for r in range(world))
        _wait_for(all_done, timeout_s, poll_s,
                  f"all {world} ranks to write their step-{step} shards")
        os.remove(ready)
        for r in range(world):
            os.remove(_done_marker(staging, step, r))
        with open(os.path.join(staging, COMMIT_MARKER), "w") as f:
            f.write("committed\n")
        if os.path.exists(path):
            os.rename(path, backup)
        os.replace(staging, path)              # atomic on POSIX
        shutil.rmtree(backup, ignore_errors=True)
        if keep_last_n is not None or metric is not None:
            _apply_retention(path, keep_last_n, metric)
    else:
        def committed_here():
            if not is_committed(path):
                return False
            try:
                with open(os.path.join(path, "meta.json")) as f:
                    return json.load(f).get("step") == step
            except Exception:
                return False
        _wait_for(committed_here, timeout_s, poll_s,
                  f"rank 0 to commit {path!r} at step {step}")


def _looks_like_checkpoint(path: str) -> bool:
    """Conservative guard before replacing an existing destination: only a
    previous checkpoint (committed or torn) or an empty dir may be
    clobbered — anything else is a user error we refuse to delete.
    Requires checkpoint-SPECIFIC evidence: a bare file named meta.json is
    not enough (datasets use that name too) — it must parse as our
    sidecar, or a shard archive / COMMIT marker must be present."""
    if not os.path.isdir(path):
        return False                           # a regular file is never ours
    entries = os.listdir(path)
    if not entries:
        return True
    if is_committed(path) or any(e.startswith("shard_") and e.endswith(".npz")
                                 for e in entries):
        return True
    meta_p = os.path.join(path, "meta.json")
    if os.path.exists(meta_p):
        try:
            with open(meta_p) as f:
                meta = json.load(f)
            return isinstance(meta, dict) and "n_leaves" in meta \
                and "step" in meta
        except Exception:
            return False
    return False


STEP_DIR_RE = re.compile(r"^step_\d+$")


def step_dir(base: str, step: int) -> str:
    """Canonical step-named checkpoint path under a base directory —
    what the retention policy prunes and ``latest``/``best`` point at."""
    return os.path.join(base, f"step_{step:08d}")


def _repoint_symlink(parent: str, name: str, target: str) -> None:
    """Atomically (re)point ``parent/name`` at sibling ``target``."""
    link = os.path.join(parent, name)
    tmp = os.path.join(parent, f".{name}.tmp-link")
    if os.path.lexists(tmp):
        os.remove(tmp)
    os.symlink(target, tmp)
    os.replace(tmp, link)


def _symlink_target(parent: str, name: str) -> Optional[str]:
    link = os.path.join(parent, name)
    if os.path.islink(link):
        return os.readlink(link)
    return None


def _metric_of(path: str) -> Optional[float]:
    meta_p = os.path.join(path, "meta.json")
    try:
        with open(meta_p) as f:
            m = json.load(f).get("metric")
        return float(m) if m is not None else None
    except Exception:
        return None


def _apply_retention(path: str, keep_last_n: Optional[int],
                     metric: Optional[float]) -> None:
    """Maintain ``latest``/``best`` symlinks beside ``path`` and prune
    old committed ``step_*`` siblings beyond ``keep_last_n``.  Pruning
    is deliberately narrow: only dirs NAMED like step checkpoints that
    also pass ``_looks_like_checkpoint`` are candidates, and a symlink
    target or the dir just written is never deleted."""
    parent = os.path.dirname(os.path.abspath(path))
    name = os.path.basename(path.rstrip(os.sep))
    _repoint_symlink(parent, "latest", name)
    if metric is not None:
        best = _symlink_target(parent, "best")
        best_metric = (_metric_of(os.path.join(parent, best))
                       if best is not None else None)
        # lower is better (loss-like); first metric-stamped save wins
        if best_metric is None or float(metric) <= best_metric:
            _repoint_symlink(parent, "best", name)
    if not keep_last_n or keep_last_n <= 0:
        return
    protected = {name}
    for link in ("latest", "best"):
        t = _symlink_target(parent, link)
        if t is not None:
            protected.add(t)
    sibs = [d for d in os.listdir(parent)
            if STEP_DIR_RE.match(d) and d not in protected
            and is_committed(os.path.join(parent, d))
            and _looks_like_checkpoint(os.path.join(parent, d))]
    # newest keep_last_n step dirs survive IN ADDITION to the protected
    # set; step number comes from the name (zero-padded, so lexical ==
    # numeric order)
    survivors = sorted(sibs)[-(keep_last_n - 1):] if keep_last_n > 1 else []
    for d in sibs:
        if d not in survivors:
            shutil.rmtree(os.path.join(parent, d), ignore_errors=True)


def resolve_checkpoint(path: str) -> str:
    """Resolve a ``--resume`` target: ``path`` itself when it is a
    checkpoint dir; otherwise follow a ``latest`` symlink inside it, or
    fall back to the newest committed ``step_*`` child.  Returns
    ``path`` unchanged when nothing matches (the loader then fails with
    its own, clearer error)."""
    if _looks_like_checkpoint(path) and os.listdir(path):
        return path
    if os.path.isdir(path):
        latest = _symlink_target(path, "latest")
        if latest is not None:
            cand = os.path.join(path, latest)
            if os.path.isdir(cand):
                return cand
        steps = sorted(d for d in os.listdir(path)
                       if STEP_DIR_RE.match(d)
                       and is_committed(os.path.join(path, d)))
        if steps:
            return os.path.join(path, steps[-1])
    return path


def load_loader_state(path: str) -> Optional[Dict[str, Any]]:
    """The ``loader_state`` entry saved with this checkpoint (format 3),
    or None for older checkpoints / runs without a streaming loader."""
    check_loadable(path)
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f).get("loader_state")


def save_checkpoint(path: str, tree: Any, step: int = 0, *,
                    loader_state: Optional[Any] = None,
                    keep_last_n: Optional[int] = None,
                    metric: Optional[float] = None) -> None:
    """Save ``tree`` atomically: shards + meta are staged in a temp dir,
    the ``COMMIT`` marker is written last, and the staged dir is renamed
    into place — a reader never observes a torn save at ``path``.

    ``loader_state`` (a dict or anything with ``.to_dict()``, e.g. a
    ``repro.data.LoaderState``) rides ``meta.json`` so resume can
    re-seek the data stream exactly.  ``keep_last_n``/``metric`` turn on
    the retention policy (module docstring): ``latest``/``best``
    symlinks in the parent dir and pruning of older committed ``step_*``
    siblings — meant for step-named paths from ``step_dir()``."""
    if loader_state is not None and hasattr(loader_state, "to_dict"):
        loader_state = loader_state.to_dict()
    path = path.rstrip(os.sep)
    if jax.process_count() > 1:
        # multi-host: the shared-filesystem marker barrier — every rank
        # stages its shard, and process 0 commits + swaps only after ALL
        # ranks report done (see _multihost_save; fixes the old commit
        # race where rank 0 could bless a shard set peers were still
        # writing)
        _multihost_save(path, tree, step, loader_state, metric, keep_last_n)
        return
    # a previous save may have crashed mid-swap: restore its surviving
    # committed dir to `path` BEFORE the leftover cleanup below, so the
    # only committed copy on disk is never deleted
    _recover_interrupted_swap(path)
    # clobber guard BEFORE any work: never delete something that is not a
    # previous checkpoint (and never leak a staging dir on refusal)
    if os.path.exists(path) and not _looks_like_checkpoint(path):
        raise ValueError(
            f"refusing to overwrite {path!r}: it exists but does not "
            f"look like a checkpoint directory (no meta.json/"
            f"{COMMIT_MARKER}); choose an empty or fresh --ckpt path")
    staging = f"{path}.tmp-staging"
    backup = f"{path}.tmp-old"
    for leftover in (staging, backup):
        if os.path.exists(leftover):
            shutil.rmtree(leftover)
    os.makedirs(staging)
    _write_shard_and_meta(staging, tree, step, loader_state, metric)
    with open(os.path.join(staging, COMMIT_MARKER), "w") as f:
        f.write("committed\n")                 # marker iff dir is complete
    # swap: move the old checkpoint ASIDE (not rmtree) before installing
    # the staged one, so a crash at any point leaves either the old or
    # the new FULLY-COMMITTED dir on disk — never a half-written one at
    # `path`, and never a window with the only copy deleted
    if os.path.exists(path):
        os.rename(path, backup)
    os.replace(staging, path)                  # atomic on POSIX
    shutil.rmtree(backup, ignore_errors=True)
    if keep_last_n is not None or metric is not None:
        _apply_retention(path, keep_last_n, metric)


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (params/state pytree or
    abstract tree); optionally re-place onto ``shardings``.  Every
    restored leaf takes the DTYPE OF ``like`` — the sidecar recovers the
    stored bits exactly, then a cast (no-op when dtypes already agree)
    shields against checkpoints written at a different precision.

    Raises ``ValueError`` for a torn save: no ``COMMIT`` marker and not a
    demonstrably complete legacy (pre-marker) checkpoint — an interrupted
    save must never load as if it were whole.  (The launcher's
    ``--resume`` is stricter and requires the marker outright.)"""
    check_loadable(path)
    data = np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes", {})
    flat_like = _flatten(like)
    missing = sorted(set(flat_like) - set(data.files))
    if missing:
        raise KeyError(
            f"checkpoint at {path!r} lacks {len(missing)} leaves the "
            f"template expects (template/archive structure mismatch — "
            f"e.g. a different optimizer or chain layout than the one "
            f"saved): first missing {missing[:5]}")
    restored = {}
    for k, leaf in flat_like.items():
        a = data[k]
        stored = dtypes.get(k)
        if stored is not None and a.dtype.name != stored:
            a = a.view(_dtype_by_name(stored))
        want = np.dtype(leaf.dtype)
        if a.dtype.kind == "V":
            # pre-sidecar checkpoint of an extension dtype: the bits are
            # intact, only the dtype tag was lost — recover it from `like`
            if a.dtype.itemsize != want.itemsize:
                raise TypeError(
                    f"checkpoint leaf {k!r} has raw dtype {a.dtype} with no "
                    f"dtype sidecar and does not match like dtype {want}")
            a = a.view(want)
        arr = jnp.asarray(a)
        if arr.dtype != want:
            arr = arr.astype(want)
        restored[k] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = ["/".join(str(getattr(p, "key", p)) for p in path)
               for path, _ in leaves_paths]
    out = jax.tree_util.tree_unflatten(treedef, [restored[k] for k in ordered])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out, meta["step"]


class AsyncCheckpointer:
    """Non-blocking saves on top of the atomic ``save_checkpoint`` path.

    ``save()`` does the only step-coupled work SYNCHRONOUSLY — a
    device→host copy of every leaf (``jax.device_get``), after which the
    donated device buffers are free for the next step to alias — and
    hands the host copy to a single background worker that runs the
    unchanged staged/atomic commit (including retention).  One worker
    thread means saves commit in submission order; a bounded queue
    applies back-pressure if commits fall behind the save cadence
    instead of accumulating host copies without limit.

    ``wait()`` blocks until every queued save has committed and
    re-raises the first background failure (also re-raised by the next
    ``save()`` — an async save error must not be silently swallowed).
    ``close()`` waits and stops the worker; the instance is also a
    context manager.  ``commit_delay_s`` artificially delays each commit
    — a test hook to prove training never blocks on commit I/O.
    """

    def __init__(self, max_pending: int = 2, commit_delay_s: float = 0.0):
        self.commit_delay_s = commit_delay_s
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, max_pending))
        self._error: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-async-ckpt")
        self._thread.start()

    def _worker(self) -> None:
        while True:
            job = self._q.get()
            try:
                if job is None:
                    return
                if self.commit_delay_s:
                    time.sleep(self.commit_delay_s)
                path, tree, step, kw = job
                if self._error is None:   # fail fast after first error
                    save_checkpoint(path, tree, step, **kw)
            except BaseException as e:
                if self._error is None:
                    self._error = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, path: str, tree: Any, step: int = 0, *,
             loader_state: Optional[Any] = None,
             keep_last_n: Optional[int] = None,
             metric: Optional[float] = None) -> None:
        """Snapshot ``tree`` to host memory now; commit in background."""
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        self._raise_pending()
        if loader_state is not None and hasattr(loader_state, "to_dict"):
            loader_state = loader_state.to_dict()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((path, host_tree, step,
                     {"loader_state": loader_state, "keep_last_n": keep_last_n,
                      "metric": metric}))

    def wait(self) -> None:
        """Block until all queued saves have committed; re-raise the
        first background failure."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain, stop the worker, and surface any pending error.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._q.join()
        self._q.put(None)
        self._thread.join(timeout=30.0)
        self._raise_pending()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *_) -> None:
        self.close()
