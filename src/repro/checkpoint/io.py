"""Sharding-aware checkpointing: each host saves its addressable shards to
an .npz (path-keyed); restore re-places shards onto the current mesh.
Single-host CPU runs degenerate to a plain full save/restore.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(p, "key", p)) for p in path): leaf
            for path, leaf in leaves}


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        arrays[k] = np.asarray(jax.device_get(v))
    np.savez(os.path.join(path, f"shard_{jax.process_index():05d}.npz"),
             **arrays)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(arrays)}, f)


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like`` (params/state pytree or
    abstract tree); optionally re-place onto ``shardings``."""
    data = np.load(os.path.join(path, f"shard_{jax.process_index():05d}.npz"))
    flat_like = _flatten(like)
    restored = {}
    for k in flat_like:
        restored[k] = jnp.asarray(data[k])
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = ["/".join(str(getattr(p, "key", p)) for p in path)
               for path, _ in leaves_paths]
    out = jax.tree_util.tree_unflatten(treedef, [restored[k] for k in ordered])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f)["step"]
    return out, step
