"""Production training launcher.

On real hardware this runs under `jax.distributed.initialize()` with the
production mesh; on the CPU container it runs the same code path on a
host mesh (all devices present).  The step function, sharding rules and
optimizer are identical to the dry-run's — `dryrun.py` IS this launcher's
compile-only mode.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b \
        --steps 50 --batch 8 --seq 128 --reduced

Memory residency: the training loop threads ONE donated ``TrainState``
through ``jax.jit(step, donate_argnums=(0,))``.  On the resident fast
path (``--fused multi_tensor``) the flat buffers are the single owner of
the parameters — device memory holds ~1x parameter bytes instead of the
2x the old (params pytree, FlatOptState) pairing kept live — and XLA
aliases params/momentum/moments in place across steps (README: "Memory
residency & donation").

Checkpoint/resume: ``--ckpt DIR`` saves {"params", "opt"} at the end,
reading both from the live ``TrainState`` (atomic commit: temp dir +
rename + ``COMMIT`` marker); ``--resume`` restores from DIR (either
optimizer state form — OptState pytree or flat-buffer-resident
FlatOptState), rejects torn saves without the marker, and continues from
the saved step, with ``--total-steps`` pinning the schedule horizon
across the save/resume split (README: "Checkpoint format and resume").
``--save-every K`` switches to periodic step-named saves under DIR
(``step_00000010/`` + ``latest``/retention via ``--keep-last-n``), and
``--async-save`` moves the commit I/O off the training thread
(``AsyncCheckpointer``: the step pays only the device→host copy).
``--resume`` accepts either layout — ``resolve_checkpoint`` follows
``latest`` when DIR is the base of a step-named family.

Data: the default input is the synthetic ``batch_at(t)`` stream.
``--data-dir`` trains from an on-disk ``repro-data-pack`` dataset
through the ``StreamingLoader`` (per-process sharded, seekable) with
``--prefetch``-deep host→device prefetch; the loader cursor
(``LoaderState``) rides every checkpoint, so ``--resume`` re-seeks the
stream and batch ``t`` after resume is bitwise the batch ``t`` of an
uninterrupted run (README: "Data pipeline & resumable input").
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import (AsyncCheckpointer, check_loadable,
                              load_checkpoint, load_loader_state,
                              resolve_checkpoint, save_checkpoint, step_dir)
from repro.configs import ARCHS, get_config, smoke_variant
from repro.core import make_optimizer
from repro.core.optim import (FlatOptState, OptState, OptimizerSpec,
                              TrainState, builder_accepts, from_pytree,
                              optimizer_names, to_pytree)
from repro.core.transform import ChainOptState, place_chain_state
from repro.data import (DiskShardedSource, LoaderState, PrefetchIterator,
                        StreamingLoader, SyntheticLM, device_put_batch)
from repro.launch.mesh import (data_axes_of, init_distributed,
                               is_main_process, make_train_mesh,
                               process_count)
from repro.models import model_defs
from repro.models.param import count, materialize
from repro.models.runtime import Runtime
from repro.sharding import batch_spec, param_shardings, param_specs
from repro.tracker import (CompositeTracker, JsonlTracker, MemoryTracker,
                           StdoutTracker)
from repro.tracker.callbacks import PrefetchMonitor, StepTimer
from repro.training import make_train_step, run_steps


def _restore(path: str, params, state):
    """Restore {"params", "opt"} regardless of which STATE FORM the
    checkpoint holds (pytree form — OptState, or a ChainOptState from
    lamb / a segment-compiled chain — vs flat-buffer-resident
    FlatOptState): detect the saved form from the archive's key set, load
    via a matching template, and convert to the live form with
    to_pytree/from_pytree (both lossless, including the Adam-moment
    slots of a fused-lamb FlatOptState and the EMA shadow slots of a
    ``("chain", slots)`` segment-plan state).  ChainOptState for
    interpreter-run NOVEL compositions has one form and loads directly.

    A torn directory (no ``COMMIT`` marker and not a demonstrably
    complete legacy save) is rejected up front — resuming from half a
    shard set would silently corrupt the run.  Complete pre-marker
    checkpoints keep working, and a crash-interrupted swap is recovered
    from its surviving committed staging/backup dir first."""
    import os

    import numpy as np
    try:
        check_loadable(path)
    except ValueError as e:
        raise SystemExit(f"--resume: {e}") from e
    shard = os.path.join(path, f"shard_{jax.process_index():05d}.npz")
    saved_flat = any("p_flats" in k for k in np.load(shard).files)
    want_flat = isinstance(state, FlatOptState)
    if saved_flat == want_flat:
        return load_checkpoint(path, {"params": params, "opt": state})
    alt = to_pytree(state) if want_flat else from_pytree(state, params)
    restored, step = load_checkpoint(path, {"params": params, "opt": alt})
    opt_state = (from_pytree(restored["opt"], restored["params"])
                 if want_flat else to_pytree(restored["opt"]))
    return {"params": restored["params"], "opt": opt_state}, step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--optimizer", default="sngm",
                    choices=list(optimizer_names()))
    ap.add_argument("--fused", default="none",
                    choices=["none", "per_leaf", "multi_tensor"],
                    help="optimizer execution path: pure jnp (none), one "
                         "Pallas kernel per tensor (per_leaf), or the "
                         "dtype-bucketed multi-tensor engine (multi_tensor; "
                         "O(1) kernel launches per step)")
    ap.add_argument("--lr", type=float, default=1.6)
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--nesterov", action="store_true",
                    help="look-ahead momentum (optimizers that accept it); "
                         "the engine fuses it into the update pass, so the "
                         "launch count is unchanged")
    ap.add_argument("--ema-decay", type=float, default=0.0,
                    help="keep an exponential moving average of the params "
                         "(0 = off); on the resident path the shadow params "
                         "live in the flat f32 EMA slots and ride the "
                         "checkpoint like any other optimizer state")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-mesh size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pod-axis", type=int, default=1,
                    help="outer pure-DP pod axis size (1 = no pod axis); "
                         ">1 builds the (pod, data, model) production mesh")
    ap.add_argument("--coordinator", default="",
                    help="multi-process JAX coordinator address host:port "
                         "(jax.distributed.initialize); also picked up from "
                         "JAX_COORDINATOR_ADDRESS / COORDINATOR_ADDRESS")
    ap.add_argument("--num-processes", type=int, default=0,
                    help="multi-process world size (0 = single process "
                         "unless the environment configures one)")
    ap.add_argument("--process-id", type=int, default=-1,
                    help="this process's rank for --coordinator runs "
                         "(-1 = from the environment)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true",
                    help="restore {params, opt} from --ckpt (either state "
                         "form) and continue from the saved step, so the "
                         "schedule picks up at the right t")
    ap.add_argument("--total-steps", type=int, default=0,
                    help="schedule horizon (0 = --steps); set this when a "
                         "run is split across save/resume segments so every "
                         "segment builds the same poly_power schedule")
    ap.add_argument("--data-dir", default="",
                    help="train from an on-disk repro-data-pack dataset "
                         "(python -m repro.data.pack) via the sharded "
                         "StreamingLoader; its LoaderState rides every "
                         "checkpoint for exact-batch resume.  Default: the "
                         "synthetic batch_at stream")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host->device prefetch depth for --data-dir runs "
                         "(0 = synchronous next(); 2 = double buffering)")
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint every K steps into step-named dirs "
                         "under --ckpt (step_00000010/, latest symlink); "
                         "0 = a single final save at --ckpt itself")
    ap.add_argument("--keep-last-n", type=int, default=0,
                    help="with --save-every: prune committed step_* dirs "
                         "beyond the newest N (0 = keep all; symlink "
                         "targets survive)")
    ap.add_argument("--async-save", action="store_true",
                    help="commit checkpoints on a background thread — the "
                         "step only pays the device->host copy, never the "
                         "commit I/O")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-jsonl", default="",
                    help="append per-step metrics (loss, grad_norm, lr, "
                         "wall-clock, tokens/sec) as JSON lines to this "
                         "path via the repro.tracker JSONL backend")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke_variant(cfg)

    # multi-process init FIRST — jax.devices() below must see the global
    # device set; a guarded no-op for single-process runs
    init_distributed(
        coordinator_address=args.coordinator or None,
        num_processes=args.num_processes or None,
        process_id=args.process_id if args.process_id >= 0 else None)
    main_proc = is_main_process()

    n_dev = len(jax.devices())
    mesh = make_train_mesh(args.data_axis, args.model_axis, args.pod_axis)
    rt = Runtime(mesh=mesh,
                 data_axes=data_axes_of(mesh) if mesh is not None
                 else ("data",),
                 remat=not args.reduced)

    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    if main_proc:
        print(f"[train] {cfg.name}: {count(defs):,} params on {n_dev} "
              f"device(s) across {process_count()} process(es)"
              f"{f' mesh={dict(mesh.shape)}' if mesh else ''}")

    gspecs = None
    if mesh is not None:
        psh = param_shardings(defs, mesh)
        params = jax.device_put(params, psh)
        gspecs = param_specs(defs, mesh)

    fused = None if args.fused == "none" else args.fused
    horizon = args.total_steps or args.steps
    saved_meta = {}
    resume_path = ""
    if args.resume and args.ckpt:
        # --ckpt may be the checkpoint itself or the BASE of a
        # --save-every step_* family; follow latest/newest committed
        resume_path = resolve_checkpoint(args.ckpt)
        # the schedule horizon is part of the run's identity: adopt the
        # saved one when --total-steps is omitted, warn on a mismatch —
        # otherwise poly_power silently decays on a different horizon and
        # the resumed lr diverges from the uninterrupted run
        tm_path = os.path.join(args.ckpt, "train_meta.json")
        if os.path.exists(tm_path):
            with open(tm_path) as f:
                saved_meta = json.load(f)
            saved_horizon = saved_meta.get("total_steps")
            if saved_horizon:
                if not args.total_steps:
                    horizon = saved_horizon
                elif saved_horizon != horizon:
                    print(f"[train] WARNING: --total-steps {horizon} != "
                          f"checkpoint horizon {saved_horizon}; the lr "
                          f"schedule will not match the original run")
    if args.resume and saved_meta.get("optimizer_spec"):
        # the optimizer's identity travels with the run: reconstruct it
        # from the saved spec so the resumed steps are bit-identical to
        # an uninterrupted run.  Only the execution mode (--fused) stays
        # a per-run hardware choice; the schedule horizon is re-pinned
        # in case the user forced a different --total-steps above.
        spec = OptimizerSpec.from_json(saved_meta["optimizer_spec"])
        if spec.name != args.optimizer and \
                args.optimizer != ap.get_default("optimizer"):
            print(f"[train] WARNING: --optimizer {args.optimizer} ignored; "
                  f"resuming the checkpoint's {spec.name!r} spec")
        kwargs = dict(spec.kwargs)
        if builder_accepts(spec.name, "fused"):
            kwargs["fused"] = fused
        sched = dict(kwargs["schedule"])
        skw = dict(sched.get("kwargs", {}))
        if "total_steps" in skw and skw["total_steps"] != horizon:
            skw["total_steps"] = horizon
            sched["kwargs"] = skw
            kwargs["schedule"] = sched
        spec = OptimizerSpec(spec.name, kwargs)
    else:
        kwargs = {"schedule": {"name": "poly_power",
                               "kwargs": {"lr0": args.lr,
                                          "total_steps": horizon,
                                          "power": 1.1}}}
        for k, v in (("beta", args.beta),
                     ("weight_decay", args.weight_decay),
                     ("nesterov", args.nesterov),
                     ("ema_decay", args.ema_decay or None),
                     ("fused", fused)):
            if builder_accepts(args.optimizer, k):
                kwargs[k] = v
        spec = OptimizerSpec(args.optimizer, kwargs)
    # the spec stays mesh-free (it is the run's serializable identity);
    # the mesh is a per-run hardware choice injected at build time, so the
    # resident flat buffers come up sharded across the whole device set
    opt = make_optimizer(spec, mesh=mesh)
    state = opt.init(params)
    start = 0
    if args.resume:
        if not args.ckpt:
            raise SystemExit("--resume requires --ckpt")
        restored, start = _restore(resume_path, params, state)
        params, state = restored["params"], restored["opt"]
        if mesh is not None:
            # re-place onto the mesh: load_checkpoint materialized every
            # leaf on the default device.  Resident flat buffers are
            # rebuilt FROM the sharded leaves (bitwise-identical values,
            # same placement as an unresumed opt.init).
            params = jax.device_put(params, psh)
            if isinstance(state, FlatOptState):
                # round-trip through the pytree form (momentum or lamb's
                # Adam-moment chain state — to_pytree picks the right one);
                # mesh= re-packs the layout at the mesh's shard count and
                # places the buffers, same as an unresumed opt.init
                state = from_pytree(to_pytree(state), params, mesh=mesh)
            elif isinstance(state, OptState):
                state = OptState(state.step,
                                 jax.device_put(state.momentum, psh))
            elif isinstance(state, ChainOptState):
                # interpreter-run chains (lamb with --fused none, novel
                # compositions): every sub-state tree mirroring the params
                # (moments, EMA shadows) takes the param shardings
                state = place_chain_state(state, psh)
        if main_proc:
            print(f"[train] resumed {resume_path} at step {start}")
    # unify into the donated TrainState: on the resident path the flat
    # buffers own the params (single copy on device) and the params
    # pytree reference is dropped here
    ts = TrainState.wrap(params, state)
    del params, state
    # donate the state through jit: XLA aliases params/momentum/moments
    # in place across steps instead of double-buffering them
    step = jax.jit(make_train_step(cfg, rt, opt, n_micro=args.n_micro,
                                   grad_specs=gspecs),
                   donate_argnums=(0,))
    loader = None
    prefetcher = None
    seq = args.seq
    if args.data_dir:
        source = DiskShardedSource(args.data_dir)
        v = source.meta.get("vocab_size")
        if v is not None and v != cfg.vocab_size:
            raise SystemExit(f"--data-dir vocab_size {v} != model vocab "
                             f"{cfg.vocab_size} ({cfg.name})")
        if cfg.is_encoder_decoder and "encoder_embeds" not in source.fields:
            raise SystemExit("--data-dir: encoder-decoder archs need an "
                             "'encoder_embeds' field in the dataset")
        seq = int(source.meta.get("seq_len", args.seq))
        ls = load_loader_state(resume_path) if resume_path else None
        if args.resume and ls is None:
            print("[train] WARNING: checkpoint carries no loader_state; "
                  "the data stream restarts from the beginning")
        loader = StreamingLoader(
            source, args.batch,
            state=LoaderState.from_dict(ls) if ls else None)
        batches = loader
        if args.prefetch > 0:
            bsh = (NamedSharding(mesh, batch_spec(mesh, 2))
                   if mesh is not None else None)
            prefetcher = PrefetchIterator(
                loader, depth=args.prefetch,
                place=lambda b: device_put_batch(b, bsh))
            batches = prefetcher
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, branching=4)

        def batch_at(t):
            batch = data.batch_at(t)
            if cfg.is_encoder_decoder:
                batch["encoder_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(t),
                    (args.batch, cfg.encoder_len, cfg.d_model))
            return batch

        batches = batch_at

    def loader_state_now():
        """Cursor of the next batch TRAINING will consume: the
        prefetcher's snapshot under run-ahead, the loader's otherwise."""
        it = prefetcher if prefetcher is not None else loader
        return None if it is None else it.state

    # tracker stack: in-memory (the returned loss curve), rate-limited
    # stdout progress, and optionally a durable JSONL metrics file.  The
    # run_steps loop keeps stats as device scalars between log-boundary
    # drains, so logging never serializes dispatch (retained buffers stay
    # bounded by --log-every).
    def fmt(t, m):
        return (f"  step {t:5d} loss={m['loss']:.4f} "
                f"||g||={m.get('grad_norm', float('nan')):.3f} "
                f"lr={m.get('lr', float('nan')):.4f} "
                f"({m.get('it_per_s', 0.0):.2f} it/s)")

    mem = MemoryTracker()
    backends = [mem]
    # per-host guards: stdout progress and the metrics file come from
    # process 0 only; every process keeps the in-memory curve (the
    # return value) since stats are replicated scalars
    if main_proc:
        backends.append(StdoutTracker(every=args.log_every, fmt=fmt))
        if args.metrics_jsonl:
            backends.append(JsonlTracker(args.metrics_jsonl))
    tracker = CompositeTracker(backends)
    callbacks = [StepTimer(tokens_per_step=args.batch * seq)]
    if prefetcher is not None:
        callbacks.append(PrefetchMonitor(prefetcher))

    def train_meta():
        return {"total_steps": horizon, "optimizer": spec.name,
                "lr": args.lr, "optimizer_spec": spec.to_json()}

    # periodic (optionally async) checkpointing: the hook runs after each
    # step with the NEW TrainState, and saves it together with the data
    # cursor of the NEXT batch — the pair that makes resume exact
    saver = AsyncCheckpointer() if (args.ckpt and args.async_save) else None

    def save_step(step_no, state_ts):
        tree = {"params": state_ts.params_view,
                "opt": to_pytree(state_ts.opt_state)}
        # keep_last_n=0 still maintains the latest/best symlinks (no
        # pruning) — step-named families always carry their pointers
        kw = dict(loader_state=loader_state_now(),
                  keep_last_n=args.keep_last_n)
        dest = step_dir(args.ckpt, step_no)
        if saver is not None:
            saver.save(dest, tree, step_no, **kw)
        else:
            save_checkpoint(dest, tree, step_no, **kw)

    step_hook = None
    if args.ckpt and args.save_every > 0:
        # train_meta.json up front (base dir), so an interrupted run is
        # already resumable from its newest periodic save; one writer
        # (process 0) on a shared filesystem
        os.makedirs(args.ckpt, exist_ok=True)
        if main_proc:
            with open(os.path.join(args.ckpt, "train_meta.json"), "w") as f:
                json.dump(train_meta(), f)

        def step_hook(t, state_ts):
            if (t + 1) % args.save_every == 0:
                save_step(t + 1, state_ts)

    ts = run_steps(step, ts, batches, args.steps, start=start,
                   tracker=tracker, log_every=args.log_every,
                   callbacks=callbacks, step_hook=step_hook)
    losses = mem.series("loss")
    if args.ckpt:
        # checkpoint from the LIVE TrainState.  A FlatOptState holds the
        # params in its flat buffers (bit-equal to the view by the
        # padding invariant), so persist the pytree form — halves the
        # checkpoint; --resume rebuilds the resident buffers losslessly
        final_step = max(start, args.steps)
        in_family = args.save_every > 0 or (
            os.path.isdir(args.ckpt)
            and resolve_checkpoint(args.ckpt) != args.ckpt)
        if in_family:
            # step-named family: periodic mode, or a resume whose --ckpt
            # is the BASE of one (don't clobber the base — join it)
            hook_saved = (args.save_every > 0 and final_step > start
                          and final_step % args.save_every == 0)
            if not hook_saved:
                save_step(final_step, ts)
        else:
            save_checkpoint(args.ckpt,
                            {"params": ts.params_view,
                             "opt": to_pytree(ts.opt_state)},
                            step=final_step, loader_state=loader_state_now())
        if main_proc:
            with open(os.path.join(args.ckpt, "train_meta.json"), "w") as f:
                json.dump(train_meta(), f)
            print(f"[train] checkpoint -> {args.ckpt}")
    if saver is not None:
        saver.close()                  # drain pending commits, re-raise errors
    if prefetcher is not None:
        c = prefetcher.counters()
        if main_proc:
            print(f"[train] input stall "
                  f"{c['input_stall_s_per_step']*1e3:.2f} ms/step, "
                  f"prefetch depth avg {c['prefetch_depth_avg']:.2f}")
        prefetcher.close()             # also closes the loader + source
    elif loader is not None:
        loader.close()
    return losses


if __name__ == "__main__":
    main()
