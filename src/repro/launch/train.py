"""Production training launcher.

On real hardware this runs under `jax.distributed.initialize()` with the
production mesh; on the CPU container it runs the same code path on a
host mesh (all devices present).  The step function, sharding rules and
optimizer are identical to the dry-run's — `dryrun.py` IS this launcher's
compile-only mode.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b \
        --steps 50 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_config, smoke_variant
from repro.core import make_optimizer
from repro.core.optim import OptState
from repro.core.schedules import poly_power
from repro.data import SyntheticLM
from repro.launch.mesh import data_axes_of
from repro.models import model_defs
from repro.models.param import count, materialize
from repro.models.runtime import Runtime
from repro.sharding import batch_spec, param_shardings, param_specs
from repro.training import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--optimizer", default="sngm",
                    choices=["sngm", "sngd", "msgd", "lars", "lamb"])
    ap.add_argument("--fused", default="none",
                    choices=["none", "per_leaf", "multi_tensor"],
                    help="optimizer execution path: pure jnp (none), one "
                         "Pallas kernel per tensor (per_leaf), or the "
                         "dtype-bucketed multi-tensor engine (multi_tensor; "
                         "O(1) kernel launches per step)")
    ap.add_argument("--lr", type=float, default=1.6)
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-mesh size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke_variant(cfg)

    n_dev = len(jax.devices())
    n_data = args.data_axis or max(1, n_dev // args.model_axis)
    mesh = None
    if n_data * args.model_axis > 1:
        mesh = jax.make_mesh((n_data, args.model_axis), ("data", "model"))
    rt = Runtime(mesh=mesh, data_axes=("data",) if mesh else ("data",),
                 remat=not args.reduced)

    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    print(f"[train] {cfg.name}: {count(defs):,} params on {n_dev} device(s)"
          f"{f' mesh={dict(mesh.shape)}' if mesh else ''}")

    gspecs = None
    if mesh is not None:
        psh = param_shardings(defs, mesh)
        params = jax.device_put(params, psh)
        gspecs = param_specs(defs, mesh)

    fused = None if args.fused == "none" else args.fused
    if args.optimizer == "lamb":
        if fused:
            raise SystemExit("--fused is not supported for lamb")
        opt = make_optimizer("lamb", poly_power(args.lr, args.steps, 1.1),
                             weight_decay=args.weight_decay)
    else:
        kw = dict(beta=args.beta, weight_decay=args.weight_decay, fused=fused)
        if args.optimizer == "sngd":
            kw.pop("beta")
        opt = make_optimizer(args.optimizer,
                             poly_power(args.lr, args.steps, 1.1), **kw)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, rt, opt, n_micro=args.n_micro,
                                   grad_specs=gspecs))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, branching=4)

    t0 = time.time()
    for t in range(args.steps):
        batch = data.batch_at(t)
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = jax.random.normal(
                jax.random.PRNGKey(t), (args.batch, cfg.encoder_len, cfg.d_model))
        params, state, stats = step(params, state, batch)
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"  step {t:5d} loss={float(stats['loss']):.4f} "
                  f"||g||={float(stats['grad_norm']):.3f} "
                  f"lr={float(stats['lr']):.4f} "
                  f"({(t+1)/(time.time()-t0):.2f} it/s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": state},
                        step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
