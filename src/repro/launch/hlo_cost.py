"""Trip-count-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
empirically), which under-counts scan-over-layers models by n_layers x
n_microbatches.  This module re-derives per-device costs by walking the
HLO computation graph and multiplying loop bodies by their
``known_trip_count`` backend annotation:

  * flops — 2 * prod(result_dims) * contracted_size for every `dot`
    (matmuls dominate every model here; elementwise flops ignored);
  * bytes — for every top-level op: result bytes + operand bytes
    (= one write + one read per tensor).  Ops inside *fused* computations
    are free (registers/VMEM); a fusion contributes only its own
    operands/result — post-fusion HLO therefore approximates real HBM
    traffic.  Metadata ops (tuple/GTE/parameter/bitcast/constant) are free.
  * collectives — result-shape bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), also trip-scaled.

All numbers are per-device (SPMD: one program per device).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.+?)\s+([\w\-]+)\(")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)%([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "get-dimension-size", "opt-barrier"}


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class Op:
    __slots__ = ("name", "result", "opcode", "rest")

    def __init__(self, name, result, opcode, rest):
        self.name, self.result, self.opcode, self.rest = name, result, opcode, rest

    def operands(self):
        return re.findall(r"%([\w\.\-]+)", self.rest.split(")")[0])


def _parse(text: str):
    comps: Dict[str, List[Op]] = {}
    fused: Dict[str, bool] = {}
    shapes: Dict[str, str] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        hdr = _HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur = hdr.group(2)
            comps[cur] = []
            fused[cur] = "fused_computation" in cur or cur.startswith("wrapped_")
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, result, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        shapes[name] = result
        comps[cur].append(Op(name, result, opcode, rest))
    return comps, shapes


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    # contracted size from lhs operand shape + lhs_contracting_dims
    ops_str = op.rest.split(")")[0]
    operands = re.findall(r"%([\w\.\-]+)", ops_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contracted = 1
    if operands and m:
        lhs_shape = shapes.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contracted *= dims[int(idx)]
    return 2.0 * _shape_elems(op.result) * contracted


def _fusion_bytes(body: List[Op], result_shape: str) -> float:
    """HBM bytes for one fusion execution, slice-aware:

    * a fusion parameter consumed ONLY through dynamic-slice reads just the
      slice (scan-over-layers weight stacks, remat stashes);
    * if the fusion root is dynamic-update-slice the output aliases the
      input buffer — only the updated window is written (+ its read);
    * every other parameter is read in full; non-DUS roots write in full.
    """
    uses: Dict[str, List[Op]] = {}
    alias: Dict[str, str] = {}
    for op in body:
        if op.opcode in ("bitcast", "copy", "transpose", "reshape") and op.operands():
            alias[op.name] = op.operands()[0]
        for o in op.operands():
            uses.setdefault(o, []).append(op)

    def resolve_uses(name):
        out = []
        for u in uses.get(name, []):
            if u.opcode in ("bitcast", "copy", "transpose", "reshape"):
                out += resolve_uses(u.name)
            else:
                out.append(u)
        return out

    reads = 0.0
    for op in body:
        if op.opcode != "parameter":
            continue
        us = resolve_uses(op.name)
        if us and all(u.opcode in ("dynamic-slice", "dynamic-update-slice")
                      for u in us):
            for u in us:
                if u.opcode == "dynamic-slice":
                    reads += _shape_bytes(u.result)
                # DUS first operand = aliased target: no read
        else:
            reads += _shape_bytes(op.result)
    root = body[-1] if body else None
    if root is not None and root.opcode == "dynamic-update-slice":
        ops_ = root.operands()
        upd = _shape_bytes(_lookup(body, ops_[1])) if len(ops_) > 1 else 0
        writes = float(upd)
    else:
        writes = float(_shape_bytes(result_shape))
    return reads + writes


def _lookup(body: List[Op], name: str) -> str:
    for op in body:
        if op.name == name:
            return op.result
    return ""


def analyze(text: str) -> Dict:
    """Returns {"flops", "bytes", "coll": {kind: bytes}, "coll_bytes"}."""
    comps, shapes = _parse(text)
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def cost(cname: str, in_fusion: bool):
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        flops, bts = 0.0, 0.0
        coll: Dict[str, float] = {}
        for op in comps.get(cname, []):
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if base.endswith("-done") or base.endswith("-update"):
                continue
            # recurse into called computations
            trip = 1.0
            called = []
            for m in _CALLED_RE.finditer(op.rest):
                if m.group(1):
                    called.append(m.group(1))
                else:
                    called += re.findall(r"%([\w\.\-]+)", m.group(2))
            if oc == "while":
                tm = _TRIP_RE.search(op.rest)
                trip = float(tm.group(1)) if tm else 1.0
            child_fusion = in_fusion or oc == "fusion"
            for ch in called:
                f, b, c = cost(ch, child_fusion)
                flops += trip * f
                if not child_fusion:
                    bts += trip * b
                for k, v in c.items():
                    coll[k] = coll.get(k, 0.0) + trip * v
            if oc == "dot":
                flops += _dot_flops(op, shapes)
            if base in COLLECTIVES:
                b = float(_shape_bytes(op.result))
                coll[base] = coll.get(base, 0.0) + b
            if not in_fusion and oc == "fusion" and called:
                bts += _fusion_bytes(comps.get(called[0], []), op.result)
            elif not in_fusion and oc == "dynamic-update-slice":
                opnds = op.operands()
                upd = _shape_bytes(shapes.get(opnds[1], "")) if len(opnds) > 1 else 0
                bts += 2.0 * upd        # in-place: read update + write window
            elif not in_fusion and oc == "dynamic-slice":
                bts += 2.0 * _shape_bytes(op.result)
            elif not in_fusion and oc not in _FREE_OPS and oc != "while":
                bts += _shape_bytes(op.result)
                bts += sum(_shape_bytes(shapes.get(o, "")) for o in op.operands())
        memo[key] = (flops, bts, coll)
        return memo[key]

    entry = None
    m = re.search(r"^ENTRY\s+%([\w\.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back to the computation named like the module
        entry = next(iter(comps))
    flops, bts, coll = cost(entry, False)
    return {"flops": flops, "bytes": bts, "coll": coll,
            "coll_bytes": sum(coll.values())}
