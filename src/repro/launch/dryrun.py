"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
on the production mesh, prove it fits, and extract roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes one JSON per combo under results/dryrun/.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production mesh.  These two lines MUST run
# before any other import — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, input_specs
from repro.core import sngm
from repro.core.optim import OptState, TrainState
from repro.core.schedules import poly_power
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.launch.roofline import Roofline, model_flops
from repro.models import model_defs
from repro.models.param import abstract
from repro.models.runtime import Runtime
from repro.serving import cache_abstract, make_prefill_step, make_serve_step
from repro.sharding import batch_spec, cache_specs, param_shardings
from repro.training import make_train_step

N_MICRO = 16          # max micro-steps (paper-style gradient accumulation)


def _n_data(mesh):
    n = 1
    for a in data_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def build_lowered(arch: str, shape_name: str, mesh, precision: str = "baseline",
                  n_micro_override: int = 0):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k":
        if not cfg.supports_long_context:
            return None, "skip: no long-context regime (DESIGN.md §6)"
        cfg = cfg.for_long_context()
    if precision.startswith("opt"):
        # §Perf beyond-paper variant: bf16 weight gathers, bf16-in/f32-acc
        # attention + logits matmuls (numerics policy, math unchanged)
        import dataclasses as _dc
        cfg = _dc.replace(cfg, sdpa_bf16=True, logits_bf16=True)
        if precision == "opt-cf1" and cfg.moe is not None:
            # tighter expert capacity: ~20% smaller dispatch buffers for
            # a few % more dropped tokens
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, capacity_factor=1.0))

    daxes = data_axes_of(mesh)
    rules = None
    n_batch = _n_data(mesh)
    # accumulate until each device sees ONE sequence per micro-step (the
    # paper trains its large batches exactly this way, §5: 128-sized
    # micro-batch accumulation), capped at 16 micro-steps
    n_micro = min(N_MICRO, max(1, shape.global_batch // n_batch))
    if n_micro_override:
        n_micro = n_micro_override
    # pure-DP archs (whisper): batch also shards over "model"; weights
    # replicate on "model" (heads indivisible by 16 — DESIGN.md §4)
    if cfg.pure_dp and shape.kind == "train" \
            and shape.global_batch % (n_batch * mesh.shape["model"]) == 0:
        daxes = daxes + ("model",)
        from repro.sharding.rules import DEFAULT_RULES
        rules = {k: tuple(a for a in v if a != "model")
                 for k, v in DEFAULT_RULES.items()}
        n_micro = 1

    rt = Runtime(mesh=mesh, data_axes=daxes, remat=True,
                 gather_dtype="bfloat16" if precision.startswith("opt") else "float32",
                 remat_policy="save_tp" if precision.startswith("opt") else "full")
    defs = model_defs(cfg)
    params_abs = abstract(defs)
    params_sh = param_shardings(defs, mesh, rules)
    bspec = lambda nd: NamedSharding(mesh, P(daxes, *([None] * (nd - 1))))
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        from repro.sharding import param_specs
        opt = sngm(poly_power(1.6, 10_000, 1.1), beta=0.9, weight_decay=1e-4)
        # the SAME donated TrainState step the production launcher jits:
        # params + optimizer slots unified, donated end to end
        ts_abs = jax.eval_shape(opt.init_state, params_abs)
        ts_sh = TrainState(
            params=params_sh,
            opt_state=OptState(step=NamedSharding(mesh, P()),
                               momentum=params_sh))
        gspecs = None if precision == "baseline" \
            else param_specs(defs, mesh, rules)     # §Perf iter 1: RS grads
        step = make_train_step(cfg, rt, opt, n_micro=n_micro,
                               grad_specs=gspecs)
        batch_abs = specs
        batch_sh = {k: bspec(v.ndim) for k, v in specs.items()}
        fn = jax.jit(step,
                     in_shardings=(ts_sh, batch_sh),
                     out_shardings=(ts_sh, None),
                     donate_argnums=(0,))
        lowered = fn.lower(ts_abs, batch_abs)

    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, rt)
        args = [params_abs, specs["tokens"]]
        shs = [params_sh, bspec(2)]
        if cfg.is_encoder_decoder:
            args.append(specs["encoder_embeds"])
            shs.append(bspec(3))
        fn = jax.jit(step, in_shardings=tuple(shs))
        lowered = fn.lower(*args)

    else:  # decode
        B, S = shape.global_batch, shape.seq_len
        cache_abs = cache_abstract(cfg, B, S)
        shardable = (B % _n_data(mesh) == 0)
        cache_sh = cache_specs(cache_abs, mesh, batch_shardable=shardable)
        tok_sh = bspec(2) if shardable else NamedSharding(mesh, P())
        pos_sh = bspec(1) if shardable else NamedSharding(mesh, P())
        step = make_serve_step(cfg, rt)
        fn = jax.jit(step,
                     in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
                     donate_argnums=(1,))
        lowered = fn.lower(params_abs, cache_abs, specs["tokens"], specs["pos"])

    return (lowered, cfg, shape), None


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str,
            precision: str = "baseline", n_micro_override: int = 0):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if precision != "baseline":
        tag += f"__{precision}"
    if n_micro_override:
        tag += f"__m{n_micro_override}"
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path):
        print(f"[cached] {tag}")
        return json.load(open(path))

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    try:
        built, skip = build_lowered(arch, shape_name, mesh, precision,
                                    n_micro_override)
        if skip:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "skipped", "reason": skip}
            json.dump(rec, open(path, "w"), indent=1)
            print(f"[skip]   {tag}: {skip}")
            return rec
        lowered, cfg, shape = built
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                    + mem.output_size_in_bytes - getattr(mem, "alias_size_in_bytes", 0))
        except Exception:
            mem, peak = None, 0
        # trip-count-aware per-device cost model over the partitioned HLO
        # (compiled.cost_analysis() counts while bodies once — see hlo_cost)
        cost = analyze(compiled.as_text())

        r = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
            hlo_gflops=cost["flops"] / 1e9,
            hlo_gbytes=cost["bytes"] / 1e9,
            coll_gbytes=cost["coll_bytes"] / 1e9,
            coll_breakdown={k: v / 1e9 for k, v in cost["coll"].items()},
            model_gflops_per_chip=model_flops(cfg, shape, n_chips) / 1e9,
            peak_bytes_per_chip=float(peak),
        ).finalize()
        rec = {"status": "ok", "t_lower_s": round(t_lower, 1),
               "t_compile_s": round(t_compile, 1), **r.to_dict()}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[ok]     {tag}: compute={r.t_compute:.4f}s memory={r.t_memory:.4f}s "
              f"coll={r.t_collective:.4f}s bound={r.bottleneck} "
              f"peak={peak/1e9:.2f}GB/chip (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        return rec
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[FAIL]   {tag}: {type(e).__name__}: {str(e)[:300]}")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--precision", default="baseline",
                    choices=["baseline", "opt", "opt-cf1"])
    ap.add_argument("--n-micro", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    n_fail = 0
    for a in archs:
        for s in shapes:
            rec = run_one(a, s, args.multi_pod, args.out, args.precision,
                          args.n_micro)
            n_fail += rec.get("status") == "error"
    if n_fail:
        raise SystemExit(f"{n_fail} combinations failed")


if __name__ == "__main__":
    main()
