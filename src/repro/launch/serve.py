"""Production serving launcher: batched prefill + decode with a simple
continuous-batching request scheduler (new requests join at slot
granularity between decode steps; finished sequences free their slot).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --slots 4 --requests 10 --max-new 12
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, smoke_variant
from repro.models import model_defs
from repro.models.param import materialize
from repro.models.runtime import CPU_RUNTIME
from repro.serving import make_prefill_step, make_serve_step
from repro.serving.engine import pad_cache


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching: one shared ring of `n_slots`
    sequences decoded in lockstep; empty slots are refilled from the
    queue via a fresh prefill whose cache is spliced into slot state."""

    def __init__(self, cfg, params, n_slots: int, ctx_len: int):
        self.cfg, self.params = cfg, params
        self.n = n_slots
        self.ctx = ctx_len
        self.prefill = jax.jit(make_prefill_step(cfg, CPU_RUNTIME))
        self.step = jax.jit(make_serve_step(cfg, CPU_RUNTIME))
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.cache = None
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)

    def _admit(self, req: Request, slot: int):
        """Prefill the request alone, splice its cache row into the slot."""
        S0 = req.prompt.shape[1]
        logits, cache1 = self.prefill(self.params, req.prompt)
        cache1 = pad_cache(cache1, self.ctx - S0)
        if self.cache is None:
            # zero template with the BATCH dim (the size-1 axis of the
            # single-request cache; leading dims may be period stacks)
            # widened to n_slots
            def widen(l):
                ax = _batch_axis(l)
                return jnp.zeros(l.shape[:ax] + (self.n,) + l.shape[ax + 1:],
                                 l.dtype)
            self.cache = jax.tree.map(widen, cache1)
        def splice(full, one):
            ax = _batch_axis(one)
            idx = (slice(None),) * ax + (slot,)
            src = jnp.squeeze(one, axis=ax) if one.ndim else one
            return full.at[idx].set(src)
        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.slots[slot] = req
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out.append(nxt)
        self.tok = self.tok.at[slot, 0].set(nxt)
        self.pos = self.pos.at[slot].set(S0)

    def decode_step(self):
        nxt, _, self.cache = self.step(self.params, self.cache,
                                       self.tok, self.pos)
        self.pos = self.pos + 1
        for s, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[s] = None
        self.tok = nxt[:, None]

    def free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]


def _batch_axis(one) -> int:
    """Batch dim of a single-request cache leaf = its first size-1 axis
    (leading dims may be stacked scan periods of size > 1)."""
    for ax in range(one.ndim):
        if one.shape[ax] == 1:
            return ax
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    ctx = args.prompt_len + args.max_new

    rng = np.random.RandomState(0)
    queue = [Request(i, jnp.asarray(rng.randint(0, cfg.vocab_size,
                                                (1, args.prompt_len)),
                                    jnp.int32), args.max_new)
             for i in range(args.requests)]
    finished: List[Request] = []

    b = ContinuousBatcher(cfg, params, args.slots, ctx)
    t0 = time.time()
    steps = 0
    while queue or any(s is not None for s in b.slots):
        for s in b.free_slots():
            if queue:
                b._admit(queue.pop(0), s)
        if any(s is not None for s in b.slots):
            b.decode_step()
            steps += 1
        finished += [r for r in b.slots if r and r.done]
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"[serve] {args.requests} requests x {args.max_new} tokens on "
          f"{args.slots} slots: {steps} decode steps, "
          f"{total_tokens/dt:.1f} tok/s, {dt:.1f}s")


if __name__ == "__main__":
    main()
