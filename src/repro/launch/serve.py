"""Production serving launcher: continuous batching on either engine.

  * ``--engine paged`` (default): ``serving.scheduler.PagedScheduler`` —
    paged KV blocks, COW prefix sharing, bucket-padded batched prefill,
    chunked on-device decode, preemption under memory pressure.
  * ``--engine dense``: the slot-spliced ``ContinuousBatcher`` baseline
    (O(n_slots x ctx) cache, per-length prefill compiles, one host sync
    per token).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --slots 4 --requests 10 --max-new 12 --temperature 0.7
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, smoke_variant
from repro.models import model_defs
from repro.models.param import materialize
from repro.models.runtime import CPU_RUNTIME
from repro.serving import make_prefill_step, make_serve_step
from repro.serving.engine import cache_batch_axes, pad_cache, sample_logits


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ContinuousBatcher:
    """Slot-based continuous batching over the DENSE cache: one shared
    ring of `n_slots` sequences decoded in lockstep; empty slots are
    refilled from the queue via a fresh prefill whose cache is spliced
    into slot state.  Kept as the baseline the paged engine is gated
    against (benchmarks/bench_serving.py)."""

    def __init__(self, cfg, params, n_slots: int, ctx_len: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.n = n_slots
        self.ctx = ctx_len
        self.temperature, self.top_k = temperature, top_k
        self.prefill = jax.jit(make_prefill_step(cfg, CPU_RUNTIME))
        self.step = jax.jit(make_serve_step(cfg, CPU_RUNTIME,
                                            temperature=temperature,
                                            top_k=top_k))
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.cache = None
        # explicit per-leaf batch-axis metadata (a pytree of ints) —
        # replaces the old first-size-1-axis sniffing, which guessed
        # wrong whenever a genuine size-1 period/state dim preceded the
        # batch dim
        self.batch_axes = cache_batch_axes(cfg)
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        self._rng_ctr = 0
        self.prefill_shapes = set()

    def _next_rng(self):
        rng = jax.random.fold_in(self._key, self._rng_ctr)
        self._rng_ctr += 1
        return rng

    def _admit(self, req: Request, slot: int):
        """Prefill the request alone, splice its cache row into the slot."""
        S0 = req.prompt.shape[1]
        self.prefill_shapes.add((1, S0))
        logits, cache1 = self.prefill(self.params, req.prompt)
        cache1 = pad_cache(cache1, self.ctx - S0)
        if self.cache is None:
            def widen(l, ax):
                return jnp.zeros(l.shape[:ax] + (self.n,) + l.shape[ax + 1:],
                                 l.dtype)
            self.cache = jax.tree.map(widen, cache1, self.batch_axes)
        def splice(full, one, ax):
            idx = (slice(None),) * ax + (slot,)
            return full.at[idx].set(jnp.squeeze(one, axis=ax))
        self.cache = jax.tree.map(splice, self.cache, cache1, self.batch_axes)
        self.slots[slot] = req
        if self.temperature == 0.0:
            nxt = int(jnp.argmax(logits[0, -1]))
        else:
            nxt = int(sample_logits(logits[:, -1], self._next_rng(),
                                    self.temperature, self.top_k)[0])
        req.out.append(nxt)
        req.t_first = time.monotonic()
        self.tok = self.tok.at[slot, 0].set(nxt)
        self.pos = self.pos.at[slot].set(S0)

    def decode_step(self) -> List[Request]:
        """One lockstep decode step.  Returns the requests that finished
        on this step (their slots are freed before returning, so callers
        must use the returned list — inspecting ``slots`` afterwards
        finds them already evicted)."""
        nxt, _, self.cache = self.step(self.params, self.cache,
                                       self.tok, self.pos,
                                       self._next_rng())
        self.pos = self.pos + 1
        finished: List[Request] = []
        now = time.monotonic()
        for s, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                req.done = True
                req.t_done = now
                finished.append(req)
                self.slots[s] = None
        self.tok = nxt[:, None]
        return finished

    def free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]


def _report(finished, dt: float, steps: int, label: str):
    total_tokens = sum(len(r.out) for r in finished)
    lats = [r.t_done - r.t_submit for r in finished if r.t_done]
    print(f"[serve:{label}] {len(finished)} requests, {total_tokens} tokens, "
          f"{steps} decode steps, {total_tokens / dt:.1f} tok/s, {dt:.2f}s")
    if lats:
        print(f"[serve:{label}] request latency "
              f"p50 {np.percentile(lats, 50) * 1e3:.0f}ms "
              f"p99 {np.percentile(lats, 99) * 1e3:.0f}ms "
              f"mean {np.mean(lats) * 1e3:.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(ARCHS))
    ap.add_argument("--engine", default="paged", choices=["paged", "dense"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (bitwise-reproducible); >0 samples")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=0,
                    help="KV pool blocks (0 = enough for all slots)")
    ap.add_argument("--decode-chunk", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    ctx = args.prompt_len + args.max_new

    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(0, cfg.vocab_size, (args.prompt_len,))
               .astype(np.int32) for _ in range(args.requests)]

    if args.engine == "paged":
        from repro.serving.paged_cache import n_blocks_for
        from repro.serving.scheduler import PagedScheduler, ServeRequest
        n_blocks = args.blocks or (
            1 + args.slots * n_blocks_for(ctx, args.block_size))
        sched = PagedScheduler(
            cfg, params, CPU_RUNTIME, n_slots=args.slots,
            block_size=args.block_size, n_blocks=n_blocks, ctx_max=ctx,
            decode_chunk=args.decode_chunk, temperature=args.temperature,
            top_k=args.top_k, seed=args.seed)
        t0 = time.monotonic()
        for i, p in enumerate(prompts):
            sched.submit(ServeRequest(rid=i, prompt=p, max_new=args.max_new))
        finished = sched.run()
        _report(finished, time.monotonic() - t0,
                sched.stats["decode_steps"], "paged")
        print(f"[serve:paged] peak blocks {sched.stats['peak_used_blocks']}"
              f"/{n_blocks - 1}, preemptions {sched.stats['preemptions']}, "
              f"compiles {sched.compile_counts()}")
        return

    queue = [Request(i, jnp.asarray(p)[None], args.max_new,
                     t_submit=time.monotonic()) for i, p in enumerate(prompts)]
    finished: List[Request] = []
    b = ContinuousBatcher(cfg, params, args.slots, ctx,
                          temperature=args.temperature, top_k=args.top_k,
                          seed=args.seed)
    t0 = time.monotonic()
    steps = 0
    while queue or any(s is not None for s in b.slots):
        for s in b.free_slots():
            if queue:
                b._admit(queue.pop(0), s)
        if any(s is not None for s in b.slots):
            finished += b.decode_step()
            steps += 1
    _report(finished, time.monotonic() - t0, steps, "dense")


if __name__ == "__main__":
    main()
