"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` supplies per-device FLOPs/bytes (SPMD: one program);
collective bytes are parsed from the post-partitioning HLO text
(``compiled.as_text()``): we sum the *result-shape* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(result bytes ~ data a device moves per op; for reduce-scatter we use the
larger operand).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (constants from the assignment).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s/link ICI

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.:  %all-reduce.5 = f32[2048,512]{1,0} all-reduce(...)
#        ROOT %t = (bf16[8,16]{...}, bf16[8,16]{...}) all-to-all(...)
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device), summed over ops."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        b = _shape_bytes(shapes)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_gflops: float            # per device
    hlo_gbytes: float            # per device
    coll_gbytes: float           # per device
    coll_breakdown: Dict[str, float]
    model_gflops_per_chip: float  # 6*N_active*D / chips (train: *3 incl bwd? no: 6ND includes fwd+bwd)
    peak_bytes_per_chip: float   # from memory_analysis
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_flop_frac: float = 0.0

    def finalize(self):
        self.t_compute = self.hlo_gflops * 1e9 / PEAK_FLOPS
        self.t_memory = self.hlo_gbytes * 1e9 / HBM_BW
        self.t_collective = self.coll_gbytes * 1e9 / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_flop_frac = (self.model_gflops_per_chip / self.hlo_gflops
                                 if self.hlo_gflops else 0.0)
        return self

    def to_dict(self):
        return asdict(self)


def model_flops(cfg, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6*N_active*D (train; fwd+bwd) or 2*N_active*D (fwd-only),
    D = tokens processed.  Decode: one token per sequence."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * n_active * tokens
    else:  # decode: one new token per sequence
        f = 2.0 * n_active * shape.global_batch
    return f / n_chips
