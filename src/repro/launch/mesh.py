"""Production mesh builders + the multi-host init lane.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count`` before calling it.

Multi-host: ``init_distributed()`` is the single entry point for
``jax.distributed.initialize`` — guarded so single-process runs (tests,
the CPU container) never touch the distributed client — and
``is_main_process()`` / ``process_count()`` are the per-host guards the
launcher and checkpoint layer route through.  ``make_train_mesh`` is the
launcher's one mesh constructor: flags land here instead of ad-hoc
``jax.make_mesh`` calls, so the pod axis and the single-device
degenerate case are handled in exactly one place.
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def init_distributed(*, coordinator_address: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None,
                     local_device_ids=None) -> bool:
    """Initialize the multi-process JAX runtime when one is configured.

    Guarded no-op returning False when nothing asks for it: no explicit
    arguments AND no coordinator in the environment
    (``JAX_COORDINATOR_ADDRESS`` / ``COORDINATOR_ADDRESS`` — the names
    jax's cluster autodetect and TPU pod launchers export).  Calling it
    a second time in an already-initialized process is safe."""
    env = os.environ
    configured = (coordinator_address is not None
                  or bool(num_processes)
                  or bool(env.get("JAX_COORDINATOR_ADDRESS"))
                  or bool(env.get("COORDINATOR_ADDRESS")))
    if not configured:
        return False
    kw = {}
    if coordinator_address is not None:
        kw["coordinator_address"] = coordinator_address
    if num_processes:
        kw["num_processes"] = num_processes
    if process_id is not None:
        kw["process_id"] = process_id
    if local_device_ids is not None:
        kw["local_device_ids"] = local_device_ids
    try:
        jax.distributed.initialize(**kw)
    except RuntimeError as e:  # double init: keep the existing client
        if "already" not in str(e).lower():
            raise
    return True


def process_count() -> int:
    return jax.process_count()


def is_main_process() -> bool:
    """Per-host guard: logging, metrics files, and meta writes happen on
    process 0 only (every process still writes its own checkpoint
    shard)."""
    return jax.process_index() == 0


def make_train_mesh(data: int = 0, model: int = 1,
                    pod: int = 1) -> Optional[jax.sharding.Mesh]:
    """The launcher's mesh: ``(pod?, data, model)`` axes over the global
    device set, with the size-1 pod axis dropped.  ``data=0`` means "all
    remaining devices".  Returns None for the degenerate 1x1x1 case so
    single-device runs skip sharding machinery entirely."""
    n_dev = len(jax.devices())
    n_data = data or max(1, n_dev // (model * pod))
    if pod > 1:
        return jax.make_mesh((pod, n_data, model), ("pod", "data", "model"))
    if n_data * model > 1:
        return jax.make_mesh((n_data, model), ("data", "model"))
    return None


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; "pod" is pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes_of(mesh: jax.sharding.Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
