"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count`` before calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; "pod" is pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes_of(mesh: jax.sharding.Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
