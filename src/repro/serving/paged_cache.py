"""Paged KV cache: a global block pool + per-sequence block tables.

The dense serving cache allocates O(n_slots x ctx_max) per attention
layer no matter how much of it is used.  The paged cache replaces each
attention layer's (B, S, ...) arrays with a global pool of fixed-size
blocks plus an int32 block table per slot:

    dense  {"k":   (B, S, K, hd), "v": ...,     "slot_pos": (B, S)}
    paged  {"kp":  (n_blocks, bs, K, hd), "vp": ..., "bt": (B, nbmax)}

    dense  {"ckv": (B, S, r), "krope": (B, S, rr), "slot_pos": (B, S)}
    paged  {"ckvp": (n_blocks, bs, r), "kropep": ..., "bt": (B, nbmax)}

Token position t of slot b lives at ``pool[bt[b, t // bs], t % bs]`` —
pool memory is O(used blocks), not O(slots x ctx).  Fixed-size per-slot
state (Mamba conv/ssm, whisper cross ck/cv) is left dense: there is
nothing to page in an O(1) recurrent state.  Scanned-period cache
leaves keep their leading n_periods dim, exactly like the dense tree.

Block 0 is a reserved scratch block: inactive slots point their whole
table at it, so lockstep decode writes land somewhere harmless without
masking the write path (scratch contents are garbage and never read —
every read is masked by ``t <= pos``).

``BlockAllocator`` is the host-side free-list allocator with refcounted
copy-on-write prefix sharing at *full-block* granularity: a prompt's
full blocks are registered under a chained content hash, a later prompt
with the same prefix retains those blocks instead of recomputing and
rewriting them, and a block with refcount > 1 is never written — the
write frontier (a sequence's last, partial block and everything it
grows into) is always private, so no device-side copy is ever needed.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# pool-leaf name -> (dense-leaf name, n trailing dims after (B, S))
POOL_LEAVES = {"kp": ("k", 2), "vp": ("v", 2),
               "ckvp": ("ckv", 1), "kropep": ("krope", 1)}
DENSE_KV_NAMES = {d for d, _ in POOL_LEAVES.values()}

# per-slot (unpaged) leaf name -> batch axis from the END.  Explicit
# metadata, mirroring pad_cache's seq-axis map: leaves may carry a
# leading stacked period dim, so counting from the end is unambiguous.
#   conv (B, W-1, conv_dim); ssm (B, H, P, N); ck/cv (B, T, K, hd)
SLOT_BATCH_AXIS_FROM_END = {"conv": 3, "ssm": 4, "ck": 4, "cv": 4}


def n_blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return -(-n_tokens // block_size)


class PoolExhausted(RuntimeError):
    """The free list is empty; the scheduler preempts and retries."""


class BlockAllocator:
    """Host-side free-list allocator over ``n_blocks`` KV blocks with
    refcounted full-block prefix sharing (see module docstring)."""

    def __init__(self, n_blocks: int, block_size: int):
        assert n_blocks >= 2, "need at least scratch block 0 + one real block"
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list, block 0 reserved as scratch; low ids first out
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        self._hash2block: Dict[Any, int] = {}
        self._block2hash: Dict[int, Any] = {}

    # -- core alloc/free ---------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(f"all {self.n_blocks - 1} blocks in use")
        bid = self._free.pop()
        self._ref[bid] = 1
        return bid

    def retain(self, bid: int) -> int:
        assert self._ref.get(bid, 0) > 0, f"retain of free block {bid}"
        self._ref[bid] += 1
        return bid

    def release(self, bid: int) -> None:
        assert self._ref.get(bid, 0) > 0, f"release of free block {bid}"
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            h = self._block2hash.pop(bid, None)
            if h is not None:
                del self._hash2block[h]
            self._free.append(bid)

    # -- copy-on-write prefix sharing --------------------------------------

    @staticmethod
    def prefix_key(prev_key: Any, block_tokens: Tuple[int, ...]) -> Any:
        """Chained content key: a block's identity is (everything before
        it, its tokens) — equal keys mean bitwise-equal pool contents
        (prefill is deterministic and RoPE positions are absolute)."""
        return (prev_key, block_tokens)

    def lookup(self, key: Any) -> Optional[int]:
        return self._hash2block.get(key)

    def register(self, key: Any, bid: int) -> None:
        """Publish a freshly written full block for reuse.  First writer
        wins; keys/blocks already mapped are left alone (the caller
        should have used lookup/retain for those)."""
        if key not in self._hash2block and bid not in self._block2hash:
            self._hash2block[key] = bid
            self._block2hash[bid] = key

    def plan_prompt(self, tokens) -> Tuple[List[int], List[Any]]:
        """COW admission plan for a prompt: returns ``(shared_block_ids,
        full_block_keys)``.  The shared blocks (a prefix of the prompt's
        full blocks, longest registered chain) are *retained* here — the
        caller must release them if admission is abandoned.
        ``full_block_keys`` has one chained key per full block of the
        prompt, for registering the privately written ones."""
        toks = [int(t) for t in tokens]
        bs = self.block_size
        keys: List[Any] = []
        prev: Any = None
        for i in range(len(toks) // bs):
            prev = self.prefix_key(prev, tuple(toks[i * bs:(i + 1) * bs]))
            keys.append(prev)
        shared: List[int] = []
        for key in keys:
            bid = self.lookup(key)
            if bid is None:
                break
            shared.append(self.retain(bid))
        return shared, keys

    def check(self) -> None:
        """Invariants (property tests): conservation, scratch never
        handed out, free list duplicate-free, hash maps consistent."""
        assert self.used_blocks == len(self._ref)
        assert self.used_blocks + self.n_free == self.n_blocks - 1
        assert 0 not in self._ref and 0 not in self._free
        assert len(set(self._free)) == len(self._free)
        for h, b in self._hash2block.items():
            assert self._block2hash.get(b) == h and self._ref.get(b, 0) > 0


# ---------------------------------------------------------------------------
# paged cache tree construction & manipulation
# ---------------------------------------------------------------------------

def _is_attn_entry(d: Any) -> bool:
    return isinstance(d, dict) and ("k" in d or "ckv" in d) and "slot_pos" in d


def is_paged_entry(d: Any) -> bool:
    return isinstance(d, dict) and ("kp" in d or "ckvp" in d) and "bt" in d


def paged_cache_init(cfg: ModelConfig, n_slots: int, block_size: int,
                     n_blocks: int, nbmax: int):
    """Zero-initialized paged cache tree mirroring the model's dense
    cache structure, with attention entries replaced by pools + block
    tables (see module docstring).  Built from the eval_shape'd dense
    tree — no dense allocation ever happens."""
    from repro.serving.engine import cache_abstract
    assert not cfg.is_encoder_decoder, "paged serving is decoder-only"
    abstract = cache_abstract(cfg, n_slots, block_size)

    def convert(d):
        if _is_attn_entry(d):
            out = {}
            lead = None
            for pool_name, (dense_name, tail_nd) in POOL_LEAVES.items():
                if dense_name not in d:
                    continue
                leaf = d[dense_name]
                b_ax = leaf.ndim - 2 - tail_nd        # (lead?, B, S, *tail)
                lead = leaf.shape[:b_ax]
                out[pool_name] = jnp.zeros(
                    lead + (n_blocks, block_size) + leaf.shape[b_ax + 2:],
                    leaf.dtype)
            out["bt"] = jnp.zeros(lead + (n_slots, nbmax), jnp.int32)
            return out
        if isinstance(d, dict):
            return {k: convert(v) for k, v in d.items()}
        return jnp.zeros(d.shape, d.dtype)     # per-slot leaf (conv/ssm/...)

    return convert(abstract)


def set_block_table(paged, slot: int, block_ids: List[int]):
    """Point slot ``slot``'s table row (every layer) at ``block_ids``,
    zero-padded (scratch) to the table width."""
    def upd(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name != "bt":
            return leaf
        nbmax = leaf.shape[-1]
        assert len(block_ids) <= nbmax, (len(block_ids), nbmax)
        row = jnp.asarray(list(block_ids) + [0] * (nbmax - len(block_ids)),
                          jnp.int32)
        return leaf.at[..., slot, :].set(row)
    return jax.tree_util.tree_map_with_path(upd, paged)


def splice_prefill(paged, dense, row: int, slot: int, block_ids: List[int],
                   skip_blocks: int = 0):
    """Write row ``row`` of a (group) dense prefill cache into the pool
    blocks ``block_ids`` and per-slot row ``slot`` of a paged tree.
    The first ``skip_blocks`` blocks are COW-shared (already bitwise
    correct from an earlier identical prefix) and are not written.
    Block tables are untouched — use ``set_block_table``."""

    def walk(p, d, name=""):
        if _is_attn_entry(d):
            out = dict(p)
            for pool_name, (dense_name, tail_nd) in POOL_LEAVES.items():
                if pool_name in p:
                    out[pool_name] = _splice_pool(
                        p[pool_name], d[dense_name], tail_nd, row,
                        block_ids, skip_blocks)
            return out
        if isinstance(d, dict):
            return {k: walk(p[k], d[k], k) for k in p}
        return _splice_slot(p, d, row, slot,
                            SLOT_BATCH_AXIS_FROM_END[name])

    return walk(paged, dense)


def _splice_pool(pool, dense_leaf, tail_nd: int, row: int,
                 block_ids: List[int], skip_blocks: int):
    """pool (lead?, nb, bs, *tail) <- dense (lead?, B, S, *tail)[row]."""
    b_ax = dense_leaf.ndim - 2 - tail_nd
    bs = pool.shape[b_ax + 1]
    sel = jnp.take(dense_leaf, row, axis=b_ax)      # (lead?, S, *tail)
    L = len(block_ids) * bs
    S = sel.shape[b_ax]
    if S < L:                                        # pad up to block cover
        pad = [(0, 0)] * sel.ndim
        pad[b_ax] = (0, L - S)
        sel = jnp.pad(sel, pad)
    elif S > L:                                      # bucket overshoot: trim
        sel = jax.lax.slice_in_dim(sel, 0, L, axis=b_ax)
    chunk = sel.reshape(sel.shape[:b_ax] + (len(block_ids), bs)
                        + sel.shape[b_ax + 1:])
    if skip_blocks:
        chunk = jax.lax.slice_in_dim(chunk, skip_blocks, len(block_ids),
                                     axis=b_ax)
    ids = jnp.asarray(block_ids[skip_blocks:], jnp.int32)
    if ids.size == 0:
        return pool
    chunk = chunk.astype(pool.dtype)
    if b_ax == 0:
        return pool.at[ids].set(chunk)
    assert b_ax == 1, b_ax                           # leading period dim
    return pool.at[:, ids].set(chunk)


def _splice_slot(pool_leaf, dense_leaf, row: int, slot: int,
                 batch_axis_from_end: int):
    """Per-slot (unpaged) leaf, e.g. Mamba conv/ssm state: copy dense
    row -> pool slot row at the explicit (name-keyed) batch axis."""
    ax = dense_leaf.ndim - batch_axis_from_end
    src = jnp.take(dense_leaf, row, axis=ax)
    idx = (slice(None),) * ax + (slot,)
    return pool_leaf.at[idx].set(src.astype(pool_leaf.dtype))


# ---------------------------------------------------------------------------
# memory accounting (for the bench's O(used) claim)
# ---------------------------------------------------------------------------

def _named_bytes(tree, names) -> int:
    total = 0

    def visit(path, leaf):
        nonlocal total
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in names:
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
        return leaf
    jax.tree_util.tree_map_with_path(visit, tree)
    return total


def paged_kv_bytes_per_block(paged) -> int:
    """Bytes of pool storage per block, summed over every attention
    layer (the unit of the O(used-blocks) memory claim)."""
    total = 0

    def visit(path, leaf):
        nonlocal total
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in POOL_LEAVES:
            tail_nd = POOL_LEAVES[name][1]
            n_blocks = leaf.shape[leaf.ndim - 2 - tail_nd]
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize // n_blocks
        return leaf
    jax.tree_util.tree_map_with_path(visit, paged)
    assert total, "no pool leaves found"
    return total


def dense_kv_bytes(cache_tree) -> int:
    """Bytes of a dense engine's attention cache (abstract or concrete
    tree): the k/v/ckv/krope leaves it allocates for (n_slots, ctx)."""
    return _named_bytes(cache_tree, DENSE_KV_NAMES)
