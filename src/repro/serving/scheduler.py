"""Continuous-batching request scheduler over the paged KV cache.

Replaces the ad-hoc slot loop of ``launch.serve.ContinuousBatcher``
(one prefill compile per distinct prompt length, one host sync per
decoded token, O(n_slots x ctx) cache) with:

  * **Admission by free-block budget** — a request is admitted only
    when the ``BlockAllocator`` can cover its prompt; copy-on-write
    prefix sharing (``plan_prompt``) retains already-resident blocks
    instead of re-writing them, so identical prompt prefixes cost one
    set of blocks no matter how many slots share them.
  * **Bucket-padded batched prefill** — admitted prompts are grouped,
    right-padded to a bucket length and to ``n_slots`` rows, and
    prefilled in ONE call per bucket; ``last_pos`` picks each row's
    true last-token logits.  Causal masking makes positions
    ``t <= last_pos`` bitwise independent of right padding, so padded
    group prefill equals a solo prefill exactly.  SSM architectures
    scan *through* padding (state would see the pad tokens), so for
    ``cfg.has_ssm_layers`` buckets degrade to exact prompt lengths.
    The compile count is bounded by the number of buckets, not by the
    number of distinct prompt lengths.
  * **Chunked on-device decode** — ``lax.scan`` of ``decode_chunk``
    serve steps per host round-trip (one compile total); requests that
    finish mid-chunk have their overshoot tokens discarded host-side.
    Inactive slots point their block table at the scratch block and
    hold ``pos = 0``, so lockstep writes land harmlessly.
  * **Preemption & requeue** — when decode growth needs blocks the
    pool cannot supply, the latest-admitted victim releases its blocks
    and re-enters the queue for full recomputation (prompt + tokens
    generated so far), bounding memory at O(used blocks) with no
    reserved worst-case allocation.

Token streams are bitwise equal to the dense engine's at matched
geometry (gathered length == dense context; see layers.py paged
branches), independent of arrival order, grouping, or preemption —
prefill is deterministic and RoPE positions are absolute.  Sampling
(``temperature > 0``) is driven by a fold_in-counted PRNG key, so a
fixed seed and workload reproduce exactly.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.runtime import Runtime
from repro.serving.engine import (cache_abstract, make_prefill_step,
                                  make_serve_step, sample_logits)
from repro.serving.paged_cache import (BlockAllocator, PoolExhausted,
                                       n_blocks_for, paged_cache_init,
                                       set_block_table, splice_prefill)


@dataclass
class ServeRequest:
    """One generation request and its lifecycle record."""
    rid: int
    prompt: np.ndarray                  # (S0,) int32 token ids
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    # timeline (host wall clock, for latency reporting)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    token_times: List[float] = field(default_factory=list)
    preemptions: int = 0
    # tokens already folded back into ``prompt`` by preemption recompute
    n_folded: int = 0

    @property
    def n_generated(self) -> int:
        return len(self.out)


def default_buckets(ctx_max: int, lo: int = 8) -> List[int]:
    """Power-of-two prompt-length buckets up to ``ctx_max``."""
    out, b = [], lo
    while b < ctx_max:
        out.append(b)
        b *= 2
    return out + [ctx_max]


class PagedScheduler:
    """Continuous batching over ``n_slots`` lockstep decode lanes backed
    by a shared pool of ``n_blocks`` KV blocks (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params, rt: Runtime, *,
                 n_slots: int, block_size: int, n_blocks: int, ctx_max: int,
                 decode_chunk: int = 4, buckets: Optional[Sequence[int]] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        assert not cfg.is_encoder_decoder, "paged serving is decoder-only"
        if cfg.window:
            assert ctx_max <= cfg.window, \
                "paged serving keeps windowed caches unrotated (ctx <= window)"
        self.cfg, self.params, self.rt = cfg, params, rt
        self.n_slots, self.block_size = n_slots, block_size
        self.ctx_max = ctx_max
        self.decode_chunk = decode_chunk
        self.temperature, self.top_k = temperature, top_k
        self.nbmax = n_blocks_for(ctx_max, block_size)
        self.buckets = sorted(buckets) if buckets else default_buckets(ctx_max)

        self.alloc = BlockAllocator(n_blocks, block_size)
        self.paged = paged_cache_init(cfg, n_slots, block_size, n_blocks,
                                      self.nbmax)
        self._prefill = jax.jit(make_prefill_step(cfg, rt))
        step = make_serve_step(cfg, rt, temperature=temperature, top_k=top_k)

        def chunk(params, cache, tok, pos, active, rngs):
            # active: (k, n_slots) per-step mask — a slot whose request
            # finishes mid-chunk freezes (pos held, token pinned 0), so
            # lockstep never writes past a request's own quota and pos
            # never overruns the block table.
            def body(carry, xs):
                tok, pos, cache = carry
                rng, act = xs
                nxt, _, cache = step(params, cache, tok, pos, rng)
                nxt = jnp.where(act, nxt, tok[:, 0])
                pos = jnp.where(act, pos + 1, pos)
                return (nxt[:, None], pos, cache), nxt
            (tok, pos, cache), toks = jax.lax.scan(
                body, (tok, pos, cache), (rngs, active))
            return tok, pos, cache, toks      # toks: (k, n_slots)
        self._chunk = jax.jit(chunk)

        self.queue: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * n_slots
        self.blocks: Dict[int, List[int]] = {}      # slot -> owned block ids
        self._admit_order: List[tuple] = []         # (slot, rid), oldest first
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        self._rng_ctr = 0

        self.finished: List[ServeRequest] = []
        self.stats = {"prefill_shapes": set(), "decode_shapes": set(),
                      "peak_used_blocks": 0, "preemptions": 0,
                      "decode_steps": 0, "prefill_calls": 0}

    # -- submission --------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        S0 = len(req.prompt)
        assert S0 + req.max_new <= self.ctx_max, \
            f"request {req.rid}: {S0}+{req.max_new} exceeds ctx_max"
        req.t_submit = req.t_submit or time.monotonic()
        self.queue.append(req)

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    # -- admission (bucket-padded group prefill) ---------------------------

    def _bucket(self, S0: int) -> int:
        if self.cfg.has_ssm_layers:
            return S0            # Mamba scans through padding: exact length
        for b in self.buckets:
            if b >= S0:
                return b
        return self.ctx_max

    def _next_rng(self):
        rng = jax.random.fold_in(self._key, self._rng_ctr)
        self._rng_ctr += 1
        return rng

    def admit(self) -> int:
        """Admit as many queued requests as free slots and the block
        budget allow; one batched prefill per occupied bucket.  Returns
        the number of requests admitted."""
        staged: Dict[int, List[tuple]] = {}      # bucket -> [(slot, req, plan)]
        free = [i for i, r in enumerate(self.slots) if r is None]
        while self.queue and free:
            req = self.queue[0]
            S0 = len(req.prompt)
            shared, keys = self.alloc.plan_prompt(req.prompt)
            need = n_blocks_for(S0, self.block_size) - len(shared)
            if self.alloc.n_free < need:
                for bid in shared:               # abandon: undo retains
                    self.alloc.release(bid)
                break                            # admission never preempts
            self.queue.popleft()
            ids = shared + [self.alloc.alloc() for _ in range(need)]
            slot = free.pop(0)
            staged.setdefault(self._bucket(S0), []).append(
                (slot, req, ids, keys, len(shared)))
        for bucket, group in sorted(staged.items()):
            self._prefill_group(bucket, group)
        return sum(len(g) for g in staged.values())

    def _prefill_group(self, bucket: int, group) -> None:
        toks = np.zeros((self.n_slots, bucket), np.int32)
        last = np.zeros((self.n_slots,), np.int32)
        for i, (_, req, *_rest) in enumerate(group):
            S0 = len(req.prompt)
            toks[i, :S0] = req.prompt
            last[i] = S0 - 1
        self.stats["prefill_shapes"].add((self.n_slots, bucket))
        self.stats["prefill_calls"] += 1
        logits, dense = self._prefill(self.params, jnp.asarray(toks),
                                      last_pos=jnp.asarray(last))
        rng = self._next_rng()
        if self.temperature == 0.0:
            first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        else:
            first = sample_logits(logits[:, -1, :], rng, self.temperature,
                                  self.top_k)
        first = np.asarray(first)
        now = time.monotonic()
        for i, (slot, req, ids, keys, n_shared) in enumerate(group):
            self.paged = set_block_table(self.paged, slot, ids)
            self.paged = splice_prefill(self.paged, dense, i, slot, ids,
                                        skip_blocks=n_shared)
            for j in range(n_shared, len(keys)):   # publish full blocks (COW)
                self.alloc.register(keys[j], ids[j])
            self.slots[slot] = req
            self.blocks[slot] = ids
            self._admit_order.append((slot, req.rid))
            req.out.append(int(first[i]))
            req.t_first = now
            req.token_times.append(now)
            self.tok = self.tok.at[slot, 0].set(int(first[i]))
            self.pos = self.pos.at[slot].set(len(req.prompt))
            self._finish_if_done(slot, now)
        self.stats["peak_used_blocks"] = max(self.stats["peak_used_blocks"],
                                             self.alloc.used_blocks)

    # -- preemption --------------------------------------------------------

    def _preempt_one(self) -> bool:
        """Evict the latest-admitted active request: release its blocks
        and requeue it (front) for full recompute of prompt+generated."""
        while self._admit_order:
            slot, rid = self._admit_order.pop()
            req = self.slots[slot]
            if req is not None and req.rid == rid:   # skip stale entries
                break
        else:
            return False
        for bid in self.blocks.pop(slot):
            self.alloc.release(bid)
        self._clear_slot(slot)
        # recompute path: tokens emitted since the last admission become
        # prompt again (``out`` keeps the full emitted record)
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.out[req.n_folded:], np.int32)])
        req.n_folded = len(req.out)
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.queue.appendleft(req)
        return True

    def _clear_slot(self, slot: int) -> None:
        self.slots[slot] = None
        # point the table at scratch and park pos at 0
        self.paged = set_block_table(self.paged, slot, [])
        self.pos = self.pos.at[slot].set(0)
        self.tok = self.tok.at[slot, 0].set(0)

    def _grow_blocks(self) -> None:
        """Ensure every active slot owns blocks covering its next
        ``decode_chunk`` writes, preempting (latest first) on demand."""
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            take = min(self.decode_chunk, req.max_new - req.n_generated)
            need = n_blocks_for(int(self.pos[slot]) + take, self.block_size)
            while len(self.blocks.get(slot, [])) < need:
                try:
                    self.blocks[slot].append(self.alloc.alloc())
                except PoolExhausted:
                    # never preempt the slot we are growing unless it is
                    # the only active one (then its own requeue frees us)
                    if not self._preempt_one():
                        raise
                    if self.slots[slot] is None:   # we evicted ourselves
                        break
                    continue
            if self.slots[slot] is not None:
                self.paged = set_block_table(self.paged, slot,
                                             self.blocks[slot])

    # -- decode ------------------------------------------------------------

    def _finish_if_done(self, slot: int, now: float) -> None:
        req = self.slots[slot]
        if req is not None and req.n_generated >= req.max_new:
            req.done = True
            req.t_done = now
            self.finished.append(req)
            for bid in self.blocks.pop(slot):
                self.alloc.release(bid)
            self._clear_slot(slot)

    def decode(self) -> None:
        """One chunk of ``decode_chunk`` lockstep steps fully on device."""
        self._grow_blocks()
        takes = [0 if r is None else min(self.decode_chunk,
                                         r.max_new - r.n_generated)
                 for r in self.slots]
        if not any(takes):
            return
        active = jnp.asarray([[i < t for t in takes]
                              for i in range(self.decode_chunk)])
        rngs = jnp.stack([self._next_rng() for _ in range(self.decode_chunk)])
        self.stats["decode_shapes"].add((self.n_slots, self.decode_chunk))
        self.tok, self.pos, self.paged, toks = self._chunk(
            self.params, self.paged, self.tok, self.pos, active, rngs)
        self.stats["decode_steps"] += self.decode_chunk
        toks = np.asarray(toks)                     # (k, n_slots) host sync
        now = time.monotonic()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            take = takes[slot]
            req.out.extend(int(t) for t in toks[:take, slot])
            req.token_times.extend([now] * take)    # chunk-granular stamps
            self._finish_if_done(slot, now)
        self.stats["peak_used_blocks"] = max(self.stats["peak_used_blocks"],
                                             self.alloc.used_blocks)

    # -- driver ------------------------------------------------------------

    def step(self) -> None:
        """One scheduler round: admit what fits, then decode a chunk."""
        self.admit()
        self.decode()

    def run(self) -> List[ServeRequest]:
        """Drain queue and slots to completion; returns finished requests."""
        while not self.idle:
            self.step()
        return self.finished

    def compile_counts(self) -> Dict[str, int]:
        """Distinct jitted shapes — deterministic stand-ins for XLA
        compile counts (each distinct shape is exactly one jit miss)."""
        return {"prefill": len(self.stats["prefill_shapes"]),
                "decode": len(self.stats["decode_shapes"])}
