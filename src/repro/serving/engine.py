"""Serving: KV-cache construction, prefill & decode steps, generation.

Cache layouts (per attention layer):
  * full attention:   k/v (B, S, K, hd) + slot_pos (B, S)
  * sliding window:   ring buffer (B, W, K, hd) — O(W) decode state
  * MLA:              compressed (B, S, kv_lora) + (B, S, qk_rope)
  * Mamba2:           conv tail (B, W-1, conv_dim) + state (B, H, P, N)
  * whisper cross:    ck/cv (B, encoder_len, K, hd), written at prefill

``cache_abstract`` builds the ShapeDtypeStruct tree for a ready cache of
length S by ``jax.eval_shape`` over the prefill — zero allocation, used by
the dry-run for decode shapes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.runtime import Runtime
from repro.models.transformer import forward


NEG_INF = -2.0e38


def make_prefill_step(cfg: ModelConfig, rt: Runtime):
    """``last_pos`` (B,), optional: per-row prompt-end position for
    bucket-padded batched prefill (see transformer.forward)."""
    def prefill(params, tokens, encoder_embeds=None, last_pos=None):
        logits, cache, _ = forward(params, cfg, rt, tokens, mode="prefill",
                                   encoder_embeds=encoder_embeds,
                                   last_pos=last_pos)
        return logits, cache
    return prefill


def sample_logits(logits, rng, temperature: float, top_k: int = 0):
    """Seeded temperature (optionally top-k truncated) sampling over
    (B, V) logits -> (B,) int32.  Softmax math in fp32."""
    l = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, NEG_INF, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, rt: Runtime, *,
                    temperature: float = 0.0, top_k: int = 0):
    """One decode step: (params, cache, tokens (B,1), pos (B,)[, rng])
    -> (next_token (B,), logits (B,V), cache').

    ``temperature == 0`` is greedy argmax — bitwise the historical
    behavior, rng ignored.  ``temperature > 0`` samples from the
    temperature-scaled softmax (top-k truncated when ``top_k > 0``)
    driven by an explicit rng key, so generation is reproducible under
    a fixed seed."""
    def serve_step(params, cache, tokens, pos, rng=None):
        logits, new_cache, _ = forward(params, cfg, rt, tokens, mode="decode",
                                       cache=cache, pos=pos)
        last = logits[:, -1, :]
        if temperature == 0.0:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = sample_logits(last, rng, temperature, top_k)
        return nxt, last, new_cache
    return serve_step


def cache_abstract(cfg: ModelConfig, B: int, S: int):
    """ShapeDtypeStruct tree for a populated cache of sequence length S."""
    from repro.models.transformer import model_defs
    from repro.models.param import abstract

    params_a = abstract(model_defs(cfg))
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    enc = (jax.ShapeDtypeStruct((B, cfg.encoder_len, cfg.d_model), jnp.float32)
           if cfg.is_encoder_decoder else None)

    def run(p, t, e):
        _, cache, _ = forward(p, cfg, Runtime(mesh=None, remat=False), t,
                              mode="prefill", encoder_embeds=e)
        return cache
    return jax.eval_shape(run, params_a, tokens, enc)


def cache_batch_axes(cfg: ModelConfig, S: int = 4):
    """Explicit batch-axis metadata for a prefill cache tree: a pytree
    of ints (same structure as the cache) giving each leaf's
    request/batch axis.  Computed structurally by diffing leaf shapes
    between eval_shape'd prefills at two batch sizes — the unique axis
    that scales with B — instead of sniffing for size-1 axes (a wrong
    guess on a size-1 period dim would silently splice the wrong
    axis)."""
    a2 = cache_abstract(cfg, 2, S)
    a3 = cache_abstract(cfg, 3, S)

    def ax(l2, l3):
        diffs = [i for i, (d2, d3) in enumerate(zip(l2.shape, l3.shape))
                 if d2 != d3]
        assert len(diffs) == 1, (l2.shape, l3.shape)
        return diffs[0]
    return jax.tree.map(ax, a2, a3)


def pad_cache(cache, extra: int):
    """Grow attention caches by ``extra`` decode slots (zeros, slot_pos=-1).
    SSM/conv states (fixed-size) are untouched.  Only valid for unrotated
    caches (prompt length <= window for windowed layers)."""
    # seq-axis position from the END (leaves may carry a leading stacked
    # layer-period dim): k/v (..., S, K, hd); ckv/krope (..., S, r); slot_pos (..., S)
    seq_axis_from_end = {"k": 3, "v": 3, "ckv": 2, "krope": 2, "slot_pos": 1}

    def pad(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name not in seq_axis_from_end:
            return leaf
        padding = [(0, 0)] * leaf.ndim
        padding[leaf.ndim - seq_axis_from_end[name]] = (0, extra)
        return jnp.pad(leaf, padding,
                       constant_values=-1 if name == "slot_pos" else 0)
    return jax.tree_util.tree_map_with_path(pad, cache)


def greedy_generate(cfg: ModelConfig, rt: Runtime, params, prompt,
                    max_new: int, encoder_embeds=None):
    """Simple batched greedy decoding driver (examples / tests)."""
    B, S0 = prompt.shape
    if cfg.window:
        assert S0 <= cfg.window, "pad_cache requires unrotated ring caches"
    prefill = make_prefill_step(cfg, rt)
    step = make_serve_step(cfg, rt)
    logits, cache = prefill(params, prompt, encoder_embeds)
    cache = pad_cache(cache, max_new)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out = [tok]
    pos = jnp.full((B,), S0, jnp.int32)
    for _ in range(max_new - 1):
        tok, _, cache = step(params, cache, tok[:, None], pos)
        out.append(tok)
        pos = pos + 1
    return jnp.stack(out, axis=1)
