from repro.serving.engine import (
    cache_abstract, make_prefill_step, make_serve_step, greedy_generate,
)

__all__ = ["cache_abstract", "make_prefill_step", "make_serve_step",
           "greedy_generate"]
