from repro.serving.engine import (
    cache_abstract, cache_batch_axes, make_prefill_step, make_serve_step,
    sample_logits, greedy_generate,
)
from repro.serving.paged_cache import (
    BlockAllocator, PoolExhausted, n_blocks_for, paged_cache_init,
    set_block_table, splice_prefill,
)
from repro.serving.scheduler import PagedScheduler, ServeRequest

__all__ = ["cache_abstract", "cache_batch_axes", "make_prefill_step",
           "make_serve_step", "sample_logits", "greedy_generate",
           "BlockAllocator", "PoolExhausted", "n_blocks_for",
           "paged_cache_init", "set_block_table", "splice_prefill",
           "PagedScheduler", "ServeRequest"]
