"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""
from repro.configs.base import (
    MLAConfig, MoEConfig, ModelConfig, SSMConfig, ShapeConfig, SHAPES,
    LayerSpec, layer_pattern, input_specs, smoke_variant,
)

from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2l
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.deepseek_7b import CONFIG as _ds7
from repro.configs.gemma_2b import CONFIG as _g2b
from repro.configs.gemma2_27b import CONFIG as _g27
from repro.configs.chameleon_34b import CONFIG as _cham
from repro.configs.whisper_large_v3 import CONFIG as _whis
from repro.configs.mamba2_1_3b import CONFIG as _mamba
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba

ARCHS = {c.name: c for c in
         [_dsv2, _dsv2l, _yi, _ds7, _g2b, _g27, _cham, _whis, _mamba, _jamba]}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS", "get_config", "ModelConfig", "MoEConfig", "MLAConfig",
    "SSMConfig", "ShapeConfig", "SHAPES", "LayerSpec", "layer_pattern",
    "input_specs", "smoke_variant",
]
