"""Gemma2-27B [arXiv:2408.00118] — alternating local(4096)/global attention,
attention- and final-logit soft-capping, GeGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    source="arXiv:2408.00118",
)
