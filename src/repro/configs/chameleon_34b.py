"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM: VQ image tokens share
the text vocabulary, so the backbone is a dense decoder with QK-norm.
The VQ-VAE image tokenizer is the stubbed frontend (input_specs provides
token ids that may be text or image codes)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    source="arXiv:2405.09818",
    tie_embeddings=False,
)
