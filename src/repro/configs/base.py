"""Configuration dataclasses for models, input shapes and training.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` entries in ``SHAPES``.  The
dry-run, smoke tests, benchmarks and examples all consume these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (DeepSeek-V2 / Jamba style)."""
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # shared (always-on) experts
    moe_every: int = 1             # a MoE FFN every `moe_every` layers
    n_dense_prefix: int = 0        # leading layers with dense FFN instead
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    # 'softmax_topk': softmax over all experts then take top-k (DeepSeek-V2)
    # 'topk_softmax': top-k logits then softmax over them (Mixtral/Jamba)
    router_mode: str = "softmax_topk"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = no query compression
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length for the training scan
    ngroups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    source: str = ""               # citation

    # FFN / attention details
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    qk_norm: bool = False          # Chameleon-style QK RMSNorm
    attn_softcap: float = 0.0      # Gemma2 logit soft-capping (attention)
    final_softcap: float = 0.0     # Gemma2 final-logit soft-capping
    window: int = 0                # sliding window for *local* attn layers
    local_global_period: int = 0   # gemma2: alternate local/global attn
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d_model)
    tie_embeddings: bool = True

    # hybrid (jamba): one attention layer every `attn_every` layers
    attn_every: int = 0            # 0 -> attention everywhere (or pure SSM)

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500        # precomputed frame embeddings (stub frontend)

    # long-context behaviour
    supports_long_context: bool = True   # whisper -> False (documented skip)
    long_context_window: int = 8192      # window applied by for_long_context()

    # distribution: small models whose head counts don't divide the model
    # axis (whisper: 20 heads on model=16) train as pure data parallelism —
    # the batch shards over (pod, data, model) and weights replicate on
    # "model" (see DESIGN.md §4 hardware-adaptation notes)
    pure_dp: bool = False

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # ---- beyond-paper performance knobs (§Perf; default = faithful
    # baseline numerics) ----
    sdpa_bf16: bool = False    # attention matmuls bf16-in/f32-accumulate (MXU native)
    logits_bf16: bool = False  # loss vocab projection bf16-in/f32-accumulate

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def has_ssm_layers(self) -> bool:
        return self.ssm is not None

    @property
    def is_pure_ssm(self) -> bool:
        return self.ssm is not None and self.attn_every == 0

    def for_long_context(self) -> "ModelConfig":
        """Variant used for the long_500k shape: every full-attention layer
        becomes sliding-window (``long_context_window``) so decode is O(W).
        SSM layers are untouched (already O(1))."""
        if not self.supports_long_context:
            raise ValueError(f"{self.name} does not support long_500k (see DESIGN.md)")
        return replace(self, window=self.long_context_window,
                       local_global_period=0)  # all layers local

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# layer pattern: what the scanned period looks like
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "attn_local" | "mamba"
    ffn: str            # "dense" | "moe" | "none"


def layer_pattern(cfg: ModelConfig) -> Tuple[Sequence[LayerSpec], Sequence[LayerSpec], int]:
    """Return (prefix_layers, period_layers, n_periods).

    The model = prefix layers (unrolled) + n_periods repetitions of the
    period (lax.scan over stacked params, period unrolled inside the body).
    """
    def ffn_kind(layer_idx: int) -> str:
        if cfg.ssm is not None and cfg.attn_every == 0:
            return "none"  # pure mamba2: the block IS the mixer
        if cfg.moe is None:
            return "dense"
        if layer_idx < cfg.moe.n_dense_prefix:
            return "dense"
        if cfg.moe.moe_every > 1 and (layer_idx % cfg.moe.moe_every != 1):
            return "dense"
        return "moe"

    def mixer_kind(layer_idx: int) -> str:
        if cfg.ssm is not None:
            if cfg.attn_every == 0:
                return "mamba"
            # hybrid: one attn layer per attn_every, centred in the period
            return "attn" if (layer_idx % cfg.attn_every) == cfg.attn_every // 2 else "mamba"
        if cfg.local_global_period:
            return "attn_local" if (layer_idx % cfg.local_global_period) == 0 else "attn"
        if cfg.window:
            return "attn_local"
        return "attn"

    # period length: lcm of the structural periodicities present
    import math
    period = 1
    for p in (cfg.attn_every or 1,
              cfg.local_global_period or 1,
              (cfg.moe.moe_every if cfg.moe else 1) or 1):
        period = math.lcm(period, p)

    n_prefix = cfg.moe.n_dense_prefix if cfg.moe else 0
    body_layers = cfg.n_layers - n_prefix
    assert body_layers % period == 0, (
        f"{cfg.name}: {body_layers} body layers not divisible by period {period}")

    prefix = [LayerSpec(mixer_kind(i), ffn_kind(i)) for i in range(n_prefix)]
    period_specs = [LayerSpec(mixer_kind(n_prefix + i), ffn_kind(n_prefix + i))
                    for i in range(period)]
    return prefix, period_specs, body_layers // period


# ---------------------------------------------------------------------------
# analytic parameter count
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk_hd = m.qk_nope_dim + m.qk_rope_dim
        n = d * (m.kv_lora_rank + m.qk_rope_dim)                  # wkv_a
        n += m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)  # wk_b, wv_b
        if m.q_lora_rank:
            n += d * m.q_lora_rank + m.q_lora_rank * H * qk_hd
        else:
            n += d * H * qk_hd
        n += H * m.v_head_dim * d                                 # wo
        return n
    return d * H * hd + 2 * d * K * hd + H * hd * d


def _ffn_dense_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff  # gate, up, down


def _ffn_moe_params(cfg: ModelConfig, active_only: bool) -> int:
    m = cfg.moe
    n_routed = m.top_k if active_only else m.n_experts
    n = n_routed * 3 * cfg.d_model * m.d_expert
    n += m.n_shared * 3 * cfg.d_model * m.d_expert
    n += cfg.d_model * m.n_experts   # router
    return n


def _mamba_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.headdim
    conv_dim = d_in + 2 * s.ngroups * s.d_state
    n = cfg.d_model * (2 * d_in + 2 * s.ngroups * s.d_state + nheads)  # in_proj
    n += conv_dim * s.conv_width                                        # conv
    n += 3 * nheads + d_in                                              # A_log, D, dt_bias, out norm
    n += d_in * cfg.d_model                                             # out_proj
    return n


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    prefix, period, n_periods = layer_pattern(cfg)
    layers = list(prefix) + [spec for _ in range(n_periods) for spec in period]
    total = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    for spec in layers:
        if spec.mixer in ("attn", "attn_local"):
            total += _attn_params(cfg) + 2 * cfg.d_model
        else:
            total += _mamba_params(cfg) + cfg.d_model
        if spec.ffn == "dense":
            total += _ffn_dense_params(cfg, cfg.d_ff) + cfg.d_model
        elif spec.ffn == "moe":
            total += _ffn_moe_params(cfg, active_only) + cfg.d_model
    total += cfg.d_model  # final norm
    if cfg.is_encoder_decoder:
        # encoder stack: self-attn + dense ffn; decoder adds cross-attn
        enc = cfg.n_encoder_layers * (_attn_params(cfg) + _ffn_dense_params(cfg, cfg.d_ff)
                                      + 3 * cfg.d_model)
        cross = cfg.n_layers * (_attn_params(cfg) + cfg.d_model)
        total += enc + cross
    return total


# ---------------------------------------------------------------------------
# input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig, micro_batch: int = 0):
    """ShapeDtypeStruct stand-ins for every model input of (cfg, shape).

    For train/prefill: token ids (+ stub frame embeddings for audio).
    For decode: one new token per sequence (the KV cache is part of the
    step *state*, produced by ``serving.cache_specs``).
    """
    import jax
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    if cfg.is_encoder_decoder:
        # stub frontend: precomputed mel+conv frame embeddings
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), jnp.float32)
    return specs


# ---------------------------------------------------------------------------
# reduced variant for smoke tests
# ---------------------------------------------------------------------------

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """2 layers, d_model<=512, <=4 experts — same family, CPU-runnable."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        n_layers=2, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=64 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        window=min(cfg.window, 64) if cfg.window else 0,
        long_context_window=64,
        encoder_len=16,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
    )
    if cfg.moe:
        kw["moe"] = replace(cfg.moe, n_experts=4, top_k=2, d_expert=128,
                            n_shared=min(cfg.moe.n_shared, 1),
                            n_dense_prefix=min(cfg.moe.n_dense_prefix, 0))
        kw["n_layers"] = 2 * max(1, cfg.moe.moe_every)
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=32 if cfg.mla.q_lora_rank else 0,
                              qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, d_state=16, headdim=32, chunk=16)
    if cfg.attn_every:
        kw["attn_every"] = 4
        kw["n_layers"] = 8          # 2 periods of 4
        if cfg.moe:
            kw["moe"] = replace(kw["moe"], moe_every=2)
    if cfg.local_global_period:
        kw["n_layers"] = 2 * cfg.local_global_period
    return replace(cfg, name=cfg.name + "-smoke", **kw)
