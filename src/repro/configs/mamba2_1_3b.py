"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                       # the Mamba2 block has no separate FFN
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
