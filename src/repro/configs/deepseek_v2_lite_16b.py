"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MLA + 64-expert MoE.

The assignment line lists "MoE 64e top-6" alongside "2 shared+160 routed";
the 160 duplicates the 236B row — we use 64 routed (the actual Lite model),
noted in DESIGN.md §6.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=0,
    d_ff=10944,                 # dense prefix-layer FFN
    vocab_size=102400,
    source="arXiv:2405.04434",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  n_dense_prefix=1, router_mode="softmax_topk"),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    tie_embeddings=False,
)
