"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.
The mel-spectrogram + conv feature extractor is a stub: input_specs()
provides precomputed (B, 1500, 1280) frame embeddings.  32 encoder +
32 decoder layers; decoder has causal self-attn + cross-attn.
long_500k is skipped (see DESIGN.md §6): a 1500-frame cross-attention
context has no 500k-token decode regime."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                  # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    act="gelu",
    is_encoder_decoder=True,
    encoder_len=1500,
    supports_long_context=False,
    pure_dp=True,                 # 20 heads don't divide model=16: train pure-DP
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
