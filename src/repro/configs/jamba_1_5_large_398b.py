"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention (1:7
interleave) with 16-expert top-2 MoE every other layer."""
from repro.configs.base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,                 # 1 attention layer per 8 (1:7)
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, n_shared=0,
                  moe_every=2, router_mode="topk_softmax"),
    # attention layers use a sliding window only in the long-context variant
    long_context_window=4096,
    tie_embeddings=False,
    # 398B fp32 state (12 B/param = 4.8 TB) exceeds one pod's 4 TB HBM:
    # store params/grads bf16, momentum fp32 (8 B/param) — DESIGN.md §4
    param_dtype="bfloat16",
    source="arXiv:2403.19887",
)
