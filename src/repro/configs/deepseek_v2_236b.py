"""DeepSeek-V2 236B [arXiv:2405.04434] — MoE with Multi-head Latent Attention."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=0,
    d_ff=12288,                 # dense prefix-layer FFN (V2: 12288)
    vocab_size=102400,
    source="arXiv:2405.04434",
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  n_dense_prefix=1, router_mode="softmax_topk"),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    tie_embeddings=False,
)
