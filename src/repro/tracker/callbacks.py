"""Callback layer over the tracker: buffered per-step logging plus
derived-metric hooks (wall-clock timers, throughput).

The train step is jitted and its stats are live device scalars; calling
``float()`` on them every step would block dispatch (the launcher
documents this).  ``MetricsBuffer`` keeps the device scalars and defers
the sync to flush boundaries, stamping each step with its host wall-time
at push time so timing callbacks stay exact even though conversion
happens later.

``CallbackRunner`` drives the full per-step path:

    push(step, stats)            # no sync — buffers (step, stats, t_wall)
    ... every ``flush_every`` steps ...
    flush():  for each buffered step, in order:
        host_stats = scalarized stats
        for cb in callbacks:     # registration order, deterministic
            host_stats.update(cb.on_step(step, host_stats) or {})
        tracker.log(step, host_stats)

Callbacks run in registration order and each sees the metrics produced
by the callbacks before it — ordering is part of the contract (tests pin
it).  ``close()`` flushes, gives every callback its ``on_end`` summary
hook, logs the merged summary, and finishes the tracker.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.tracker import NullTracker, Tracker, scalarize

__all__ = ["Callback", "StepTimer", "PrefetchMonitor", "MetricsBuffer",
           "CallbackRunner"]


class Callback:
    """Per-step hook: ``on_step`` may return extra metrics to merge into
    the step's record; ``on_end`` may return run-level summary metrics."""

    def on_step(self, step: int,
                metrics: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        return None

    def on_end(self) -> Optional[Dict[str, Any]]:
        return None


class StepTimer(Callback):
    """Wall-clock + throughput: ``step_time_s`` (per-step wall time,
    measured between pushes so it includes dispatch but not the flush
    sync), ``it_per_s`` (cumulative), and — when ``tokens_per_step`` or
    ``examples_per_step`` is known — ``tokens_per_s`` / ``examples_per_s``.
    The first step is reported from the loop start so compile time shows
    up in step 0, not as a silent hole in the curve."""

    def __init__(self, tokens_per_step: Optional[int] = None,
                 examples_per_step: Optional[int] = None) -> None:
        self.tokens_per_step = tokens_per_step
        self.examples_per_step = examples_per_step
        self.t_start: Optional[float] = None
        self.t_prev: Optional[float] = None
        self.n_steps = 0

    def on_step(self, step, metrics):
        t_wall = metrics.get("_t_wall", time.perf_counter())
        if self.t_start is None:
            # the runner stamps _t_loop_start on the first record
            self.t_start = metrics.get("_t_loop_start", t_wall)
            self.t_prev = self.t_start
        dt = max(t_wall - self.t_prev, 1e-9)
        self.t_prev = t_wall
        self.n_steps += 1
        elapsed = max(t_wall - self.t_start, 1e-9)
        out = {"step_time_s": dt, "it_per_s": self.n_steps / elapsed}
        if self.tokens_per_step:
            out["tokens_per_s"] = self.tokens_per_step / dt
        if self.examples_per_step:
            out["examples_per_s"] = self.examples_per_step / dt
        return out

    def on_end(self):
        if self.t_start is None:
            return None
        elapsed = max((self.t_prev or self.t_start) - self.t_start, 1e-9)
        out = {"wall_time_s": elapsed,
               "it_per_s": self.n_steps / elapsed}
        if self.tokens_per_step:
            out["tokens_per_s"] = self.tokens_per_step * self.n_steps / elapsed
        if self.examples_per_step:
            out["examples_per_s"] = (self.examples_per_step * self.n_steps
                                     / elapsed)
        return out


class PrefetchMonitor(Callback):
    """Input-pipeline health metrics from a ``repro.data.PrefetchIterator``
    (or anything exposing its ``stall_log``/``counters()`` surface).

    Per step: ``input_stall_s`` (time the step blocked waiting for a
    batch) and ``prefetch_depth`` (queue occupancy when the batch was
    taken).  The prefetcher appends one ``stall_log`` entry per consumed
    batch in order, and the runner flushes records in step order, so
    popping left keeps the pairing exact even though flushes are
    deferred.  ``on_end``: run-level ``input_stall_s`` total /
    ``input_stall_s_per_step`` / ``prefetch_depth_avg`` — the numbers
    ``benchmarks/bench_data_pipeline.py`` stamps and CI gates (stall
    ~ 0 with prefetch on)."""

    def __init__(self, prefetcher) -> None:
        self.prefetcher = prefetcher

    def on_step(self, step, metrics):
        log = getattr(self.prefetcher, "stall_log", None)
        if not log:
            return None
        stall, depth = log.popleft()
        return {"input_stall_s": stall, "prefetch_depth": depth}

    def on_end(self):
        c = self.prefetcher.counters()
        return {"input_stall_s": c["input_stall_s"],
                "input_stall_s_per_step": c["input_stall_s_per_step"],
                "prefetch_depth_avg": c["prefetch_depth_avg"]}


class MetricsBuffer:
    """Defers device->host conversion: ``push`` stores the raw (possibly
    device-scalar) stats dict plus a host wall-time stamp; ``drain``
    block-syncs once and yields scalarized dicts in step order."""

    def __init__(self) -> None:
        self._buf: List[Tuple[int, Dict[str, Any], float]] = []
        self.t_loop_start = time.perf_counter()

    def __len__(self) -> int:
        return len(self._buf)

    def push(self, step: int, stats: Dict[str, Any]) -> None:
        self._buf.append((step, stats, time.perf_counter()))

    def drain(self) -> List[Tuple[int, Dict[str, Any]]]:
        if not self._buf:
            return []
        # one transfer for the whole buffer, not one sync per scalar
        host = jax.device_get([s for _, s, _ in self._buf])
        out = []
        for (step, _, t_wall), stats in zip(self._buf, host):
            rec = {k: scalarize(v) for k, v in stats.items()}
            rec["_t_wall"] = t_wall
            out.append((step, rec))
        self._buf.clear()
        return out


class CallbackRunner:
    """Buffered tracker pump: push device stats each step, flush at
    logging boundaries, close at loop end.  The ``_t_wall`` /
    ``_t_loop_start`` stamps are internal plumbing for timing callbacks
    and are stripped before the record reaches the tracker."""

    def __init__(self, tracker: Optional[Tracker] = None,
                 callbacks: Sequence[Callback] = (),
                 flush_every: int = 1) -> None:
        self.tracker = tracker if tracker is not None else NullTracker()
        self.callbacks = list(callbacks)
        self.flush_every = max(1, flush_every)
        self._buffer = MetricsBuffer()
        self._first = True
        self._n_pushed = 0
        self._closed = False

    def push(self, step: int, stats: Dict[str, Any]) -> None:
        assert not self._closed, "CallbackRunner already closed"
        self._buffer.push(step, stats)
        self._n_pushed += 1
        if self._n_pushed % self.flush_every == 0:
            self.flush()

    def flush(self) -> None:
        for step, metrics in self._buffer.drain():
            if self._first:
                metrics["_t_loop_start"] = self._buffer.t_loop_start
                self._first = False
            for cb in self.callbacks:
                extra = cb.on_step(step, metrics)
                if extra:
                    metrics.update(extra)
            public = {k: v for k, v in metrics.items()
                      if not k.startswith("_")}
            self.tracker.log(step, public)

    def close(self, summary: Optional[Dict[str, Any]] = None) -> None:
        if self._closed:
            return
        self.flush()
        merged: Dict[str, Any] = {}
        for cb in self.callbacks:
            extra = cb.on_end()
            if extra:
                merged.update(extra)
        if summary:
            merged.update(summary)
        if merged:
            self.tracker.log_summary(merged)
        self.tracker.finish()
        self._closed = True
