"""Engine counters: the launch/packing/residency numbers behind the
``BENCH_*.json`` trajectory, factored out of
``benchmarks/bench_optimizer_overhead`` so training loops and the sweep
harness log the same quantities the CI gate enforces.

All counts are TRACE-time (``jax.jit(...).lower(...)``): they measure
what one compiled step would do, without executing it — so they are
exact, deterministic, and free of wall-clock noise.

  * ``launches_per_step``   — Pallas kernel launches traced into one
                              optimizer step (the multi-tensor engine's
                              O(1)-vs-O(n_leaves) claim).
  * ``packed_bytes_per_step`` — bytes flattened into the engine's flat
                              buffers per step (resident FlatOptState
                              packs gradients only).
  * ``param_bytes_live``    — parameter bytes a ``TrainState`` holds
                              across steps (the 1x single-owner
                              invariant of the donated resident path).
  * ``capture_donation_warnings`` — run a donated step and collect any
                              "donated buffer not aliased" warnings
                              (zero means every buffer aliased in place).
  * ``plan_launches_per_step`` — the segment compiler's OWN launch
                              accounting (``SegmentPlan.launches_per_bucket``
                              x bucket count), checked against the traced
                              count so the plan IR never drifts from what
                              actually launches.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.multi_tensor import (FlatOptState, build_layout,
                                     count_packed_bytes)
from repro.core.optim import Optimizer, TrainState
from repro.kernels import count_pallas_launches

__all__ = ["launches_per_step", "packed_bytes_per_step", "param_bytes_live",
           "capture_donation_warnings", "engine_counters",
           "plan_launches_per_step"]


def launches_per_step(opt: Optimizer, grads, state, params) -> int:
    """pallas_call sites traced into one optimizer step = kernel launches
    per step execution."""
    with count_pallas_launches() as c:
        # fresh lambda: a cached jit of opt.step would skip tracing (and
        # therefore skip the trace-time launch recording)
        jax.jit(lambda g, s, p: opt.step(g, s, p)).lower(grads, state, params)
    return c["launches"]


def packed_bytes_per_step(opt: Optimizer, grads, state, params) -> int:
    """Bytes packed into flat buffers per step execution (trace-time
    count, same pattern as launches_per_step).  The flat-buffer-resident
    state (FlatOptState) packs only the gradients; an OptState forces the
    per-step path that re-packs params+grads+momentum every step."""
    with count_packed_bytes() as c:
        jax.jit(lambda g, s, p: opt.step(g, s, p)).lower(grads, state, params)
    return int(c["bytes"])


def param_bytes_live(ts: TrainState) -> int:
    """Parameter bytes the TrainState keeps live across steps: the params
    pytree (when it owns them) plus resident flat buffers (when
    FlatOptState does).  The donated resident path holds ~1x raw param
    bytes; the legacy (pytree, flats) pairing held 2x — the regression
    this counter guards."""
    n = 0
    if ts.params is not None:
        n += sum(l.size * jnp.dtype(l.dtype).itemsize
                 for l in jax.tree.leaves(ts.params))
    if isinstance(ts.opt_state, FlatOptState):
        n += sum(f.size * jnp.dtype(f.dtype).itemsize
                 for f in ts.opt_state.p_flats)
    return n


def capture_donation_warnings(fn: Callable, *args,
                              donate_argnums=(1,)) -> Tuple[Any, List[str]]:
    """jit ``fn`` with the given donation, run it once, and return
    (result, [donation warning messages]).  An empty list means XLA
    consumed every donated buffer — the aliasing contract held."""
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        out = jax.jit(fn, donate_argnums=donate_argnums)(*args)
        jax.block_until_ready(out)
    msgs = [str(w.message) for w in wlog
            if "donat" in str(w.message).lower()]
    return out, msgs


def plan_launches_per_step(opt: Optimizer, params) -> Any:
    """Static launch prediction from the optimizer's ``SegmentPlan`` IR:
    per-bucket plan launches x number of dtype buckets the param tree
    flattens into.  Returns None when the optimizer carries no fused
    plan (interpreter chains, per-leaf path, monolithic optimizers) —
    the traced ``launches_per_step`` is then the only source of truth.
    Tests cross-check this against the traced count so the plan's
    ``launches`` annotations stay honest."""
    plan = getattr(opt, "plan", None)
    if plan is None or plan.kind is None or opt.kind is None:
        return None
    n_buckets = len(build_layout(params).buckets)
    return plan.launches_per_bucket() * n_buckets


def engine_counters(opt: Optimizer, params) -> Dict[str, Any]:
    """One-call counter bundle for a (optimizer, param tree) pair, used
    by the sweep harness to stamp every record with the engine numbers
    the CI gate tracks.  Gradients are synthesized (ones) — the counts
    are trace-time and value-independent."""
    grads = jax.tree.map(jnp.ones_like, params)
    state = opt.init(params)
    ts = TrainState.wrap(params, state)
    return {
        "launches_per_step": launches_per_step(opt, grads, state, params),
        "packed_bytes_per_step": packed_bytes_per_step(opt, grads, state,
                                                       params),
        "param_bytes_live": param_bytes_live(ts),
    }
