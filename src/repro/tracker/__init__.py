"""repro.tracker — lightweight step-scoped metrics layer.

The experiment harness (benchmarks/bench_sweep.py), the launcher
(launch/train.py), and the shared benchmark loops (benchmarks/common.py)
all emit metrics through one interface so every run — paper sweep, CI
smoke, production training — produces the same record stream:

    tracker.log(step, {"loss": 2.31, "grad_norm": 4.2})
    tracker.log_summary({"final_loss": 0.12, "test_acc": 0.94})
    tracker.finish()

Backends are pluggable (modeled on levanter's ``tracker`` +
``callbacks`` split):

  * ``JsonlTracker``   — one JSON object per line; the durable artifact
                         format every ``BENCH_<name>.json`` record is
                         derived from (``read_jsonl`` round-trips it).
  * ``StdoutTracker``  — human-readable progress lines, rate-limited by
                         ``every``.
  * ``MemoryTracker``  — in-memory list of (step, metrics) for tests and
                         for callers that post-process a run (the
                         launcher reads its loss curve back out of one).
  * ``CompositeTracker`` — fan-out to several backends in registration
                         order (deterministic — tests assert it).
  * ``NullTracker``    — the default no-op.

Values may be live jax/numpy device scalars: every backend coerces
through ``scalarize`` at log time, so callers never pay a device sync
just to construct the metrics dict (buffer upstream with
``tracker.callbacks.MetricsBuffer`` to batch the sync).

Host-side only: trackers never appear inside jit. The train step stays
pure (training/step.py) and the loop around it logs.
"""
from __future__ import annotations

import json
import os
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Tracker", "NullTracker", "MemoryTracker", "StdoutTracker",
    "JsonlTracker", "CompositeTracker", "scalarize", "read_jsonl",
    "current_tracker", "set_global_tracker", "with_tracker",
]


def scalarize(value: Any) -> Any:
    """Coerce a metric value to a plain JSON-serializable python scalar.
    Accepts python numbers, strings, bools, None, and 0-d jax/numpy
    arrays (anything with ``.item()``); lists/tuples/dicts are coerced
    elementwise.  Non-scalar arrays are rejected loudly — per-step
    metrics are scalars by contract, and silently serializing a (B,S)
    tensor into JSONL is always a bug upstream."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, dict):
        return {k: scalarize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [scalarize(v) for v in value]
    if hasattr(value, "ndim") and getattr(value, "ndim") != 0:
        raise TypeError(f"metric value must be a scalar, got array with "
                        f"shape {getattr(value, 'shape', '?')}")
    if hasattr(value, "item"):
        v = value.item()
        # np.float32.item() -> float, np.int32.item() -> int
        return v
    raise TypeError(f"unsupported metric value type {type(value).__name__}")


class Tracker:
    """Metrics backend interface.  ``log`` is step-scoped; ``log_summary``
    records run-level results (final loss, test accuracy, counters);
    ``finish`` flushes/closes.  Subclasses override ``_log`` hooks and
    inherit the scalarization."""

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        self._log(int(step), {k: scalarize(v) for k, v in metrics.items()})

    def log_summary(self, metrics: Dict[str, Any]) -> None:
        self._log_summary({k: scalarize(v) for k, v in metrics.items()})

    def finish(self) -> None:  # idempotent
        pass

    # -- backend hooks --------------------------------------------------
    def _log(self, step: int, metrics: Dict[str, Any]) -> None:
        raise NotImplementedError

    def _log_summary(self, metrics: Dict[str, Any]) -> None:
        raise NotImplementedError


class NullTracker(Tracker):
    def _log(self, step, metrics):
        pass

    def _log_summary(self, metrics):
        pass


class MemoryTracker(Tracker):
    """Records everything in memory — the test backend, and the cheapest
    way for a caller to read a run's curve back (``.series("loss")``)."""

    def __init__(self) -> None:
        self.steps: List[Tuple[int, Dict[str, Any]]] = []
        self.summary: Dict[str, Any] = {}
        self.finished = False

    def _log(self, step, metrics):
        self.steps.append((step, metrics))

    def _log_summary(self, metrics):
        self.summary.update(metrics)

    def finish(self):
        self.finished = True

    def series(self, key: str) -> List[Any]:
        return [m[key] for _, m in self.steps if key in m]


class StdoutTracker(Tracker):
    """Progress lines on stdout, at most one per ``every`` steps (summary
    always prints).  ``fmt(step, metrics) -> str`` overrides the line."""

    def __init__(self, every: int = 1, prefix: str = "", fmt=None) -> None:
        self.every = max(1, every)
        self.prefix = prefix
        self.fmt = fmt

    def _default_fmt(self, step, metrics):
        body = " ".join(
            f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in metrics.items())
        return f"{self.prefix}step {step:5d} {body}"

    def _log(self, step, metrics):
        if step % self.every == 0:
            print((self.fmt or self._default_fmt)(step, metrics))

    def _log_summary(self, metrics):
        body = " ".join(
            f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in metrics.items())
        print(f"{self.prefix}summary {body}")


class JsonlTracker(Tracker):
    """One JSON object per line: ``{"step": t, ...metrics}`` for step
    records, ``{"summary": true, ...metrics}`` for run-level records.
    Append mode so a resumed run extends its own file; ``read_jsonl``
    round-trips the stream."""

    def __init__(self, path: str) -> None:
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")

    def _write(self, obj: Dict[str, Any]) -> None:
        if self._f is None:
            raise ValueError(f"JsonlTracker({self.path!r}) already finished")
        self._f.write(json.dumps(obj, sort_keys=True) + "\n")
        self._f.flush()

    def _log(self, step, metrics):
        self._write({"step": step, **metrics})

    def _log_summary(self, metrics):
        self._write({"summary": True, **metrics})

    def finish(self):
        if self._f is not None:
            self._f.close()
            self._f = None


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a JsonlTracker stream back into its records (blank lines
    skipped), preserving order."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class CompositeTracker(Tracker):
    """Fan out to several backends, in the order given.  Every backend
    sees every record; ordering is part of the contract (tests pin it) so
    e.g. the MemoryTracker a caller reads back from is always as complete
    as the JSONL file on disk."""

    def __init__(self, trackers) -> None:
        self.trackers = list(trackers)

    def _log(self, step, metrics):
        for t in self.trackers:
            t._log(step, metrics)

    def _log_summary(self, metrics):
        for t in self.trackers:
            t._log_summary(metrics)

    def finish(self):
        for t in self.trackers:
            t.finish()


# -- ambient tracker ----------------------------------------------------
# A module-level current tracker so deeply nested loops (benchmark
# helpers) can log without threading a tracker argument through every
# call; explicit arguments still win where they exist.
_GLOBAL: List[Tracker] = [NullTracker()]


def current_tracker() -> Tracker:
    return _GLOBAL[-1]


def set_global_tracker(tracker: Optional[Tracker]) -> None:
    _GLOBAL[0] = tracker if tracker is not None else NullTracker()


@contextmanager
def with_tracker(tracker: Tracker) -> Iterator[Tracker]:
    _GLOBAL.append(tracker)
    try:
        yield tracker
    finally:
        _GLOBAL.pop()
