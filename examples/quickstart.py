"""Quickstart: train a small model with SNGM (the paper's optimizer) and
generate from it — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_variant
from repro.core import sngm
from repro.core.schedules import poly_power
from repro.data import SyntheticLM
from repro.models import CPU_RUNTIME, model_defs
from repro.models.param import count, materialize
from repro.serving import greedy_generate
from repro.training import make_train_step


def main():
    # any assigned architecture works: --arch style selection via ARCHS
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                              vocab_size=64)   # small vocab: learns in ~1 min
    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  ({count(defs):,} params)")

    steps = 60
    data = SyntheticLM(cfg.vocab_size, seq_len=32, batch_size=8, branching=4)
    opt = sngm(poly_power(2.0, steps, 1.1), beta=0.9, weight_decay=1e-4)
    # one unified TrainState, donated through jit — params + momentum
    # update in place across steps (README: "Memory residency & donation")
    state = opt.init_state(params)
    del params
    train_step = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2),
                         donate_argnums=(0,))

    for t in range(steps):
        state, stats = train_step(state, data.batch_at(t))
        if t % 10 == 0 or t == steps - 1:
            print(f"step {t:3d}  loss={float(stats['loss']):.4f}  "
                  f"||g||={float(stats['grad_norm']):.3f}  "
                  f"lr={float(stats['lr']):.4f}")
    print(f"(bigram-chain entropy floor: {data.optimal_loss():.3f} nats)")

    prompt = data.batch_at(999)["tokens"][:2, :16]
    out = greedy_generate(cfg, CPU_RUNTIME, state.params_view, prompt,
                          max_new=8)
    print("generated continuation token ids:", out.tolist())


if __name__ == "__main__":
    main()
