"""End-to-end distributed training driver: a multi-million-parameter LM
trained for a few hundred steps with SNGM and large-batch gradient
accumulation, on whatever devices exist (host mesh), with checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --d-model 256

Scale notes: the default (~20M params, B=32x128 tokens) trains in
minutes on the CPU container; on a real mesh raise --d-model/--layers
and the mesh shape — the code path (pjit + sharding rules + grad accum)
is identical to the production dry-run's.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS
from repro.core import make_optimizer
from repro.core.optim import OptState, builder_accepts, optimizer_names
from repro.core.schedules import poly_power
from repro.data import (DiskShardedSource, PrefetchIterator, StreamingLoader,
                        SyntheticLM, device_put_batch)
from repro.models import model_defs
from repro.models.param import count, materialize
from repro.models.runtime import Runtime
from repro.sharding import batch_spec, param_shardings
from repro.training import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=4)
    ap.add_argument("--optimizer", default="sngm",
                    choices=list(optimizer_names()))
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--data-dir", default="",
                    help="train from a packed on-disk dataset "
                         "(python -m repro.data.pack) instead of the "
                         "synthetic stream")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host->device prefetch depth for --data-dir")
    args = ap.parse_args()

    base = ARCHS[args.arch]
    cfg = dataclasses.replace(
        base, n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        head_dim=64, d_ff=args.d_model * 4, vocab_size=args.vocab,
        moe=None, mla=None)  # dense variant of the chosen family

    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count(defs):,} devices={len(jax.devices())}")

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    rt = Runtime(mesh=mesh, remat=False) if mesh else Runtime(mesh=None, remat=False)
    if mesh:
        psh = param_shardings(defs, mesh)
        params = jax.device_put(params, psh)

    kw = {k: v for k, v in (("beta", 0.9), ("weight_decay", 1e-4))
          if builder_accepts(args.optimizer, k)}
    opt = make_optimizer(args.optimizer, poly_power(args.lr, args.steps, 1.1),
                         **kw)
    # donated TrainState: params + optimizer slots alias in place across
    # steps (on the resident fused path, ~1x parameter bytes live)
    state = opt.init_state(params)
    del params
    step = jax.jit(make_train_step(cfg, rt, opt, n_micro=args.n_micro),
                   donate_argnums=(0,))
    seq, it = args.seq, None
    if args.data_dir:
        # on-disk dataset through the streaming pipeline: sharded loader
        # + background host->device prefetch (batches arrive resident)
        source = DiskShardedSource(args.data_dir)
        v = source.meta.get("vocab_size")
        if v is not None and v != cfg.vocab_size:
            raise SystemExit(f"--data-dir vocab_size {v} != model vocab "
                             f"{cfg.vocab_size} (pass --vocab {v})")
        seq = int(source.meta.get("seq_len", args.seq))
        loader = StreamingLoader(source, args.batch)
        bsh = NamedSharding(mesh, batch_spec(mesh, 2)) if mesh else None
        it = (PrefetchIterator(loader, depth=args.prefetch,
                               place=lambda b: device_put_batch(b, bsh))
              if args.prefetch > 0 else loader)
        next_batch = lambda t: next(it)  # noqa: E731
        floor = float(source.meta.get("optimal_loss", float("nan")))
    else:
        data = SyntheticLM(cfg.vocab_size, seq, args.batch, branching=8)
        next_batch = data.batch_at
        floor = float(data.optimal_loss())

    t0 = time.time()
    for t in range(args.steps):
        state, stats = step(state, next_batch(t))
        if t % 20 == 0 or t == args.steps - 1:
            tok_s = args.batch * seq * (t + 1) / (time.time() - t0)
            print(f"step {t:4d}  loss={float(stats['loss']):.4f}  "
                  f"||g||={float(stats['grad_norm']):.2f}  "
                  f"lr={float(stats['lr']):.4f}  tok/s={tok_s:,.0f}")
    if it is not None:
        it.close()
    print(f"entropy floor ~{floor:.3f} nats; total {time.time()-t0:.0f}s")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": state.params_view},
                        step=args.steps)
        print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
