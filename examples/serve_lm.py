"""Batched serving example: prefill a batch of prompts, then decode with
every cache type the framework supports (full KV / sliding-window ring /
MLA latent / SSM state, depending on --arch).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_variant
from repro.models import CPU_RUNTIME, model_defs
from repro.models.param import materialize
from repro.serving import greedy_generate, make_prefill_step, make_serve_step
from repro.serving.engine import pad_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_variant(ARCHS[args.arch])
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    enc = (jax.random.normal(jax.random.PRNGKey(2),
                             (args.batch, cfg.encoder_len, cfg.d_model))
           if cfg.is_encoder_decoder else None)

    prefill = jax.jit(make_prefill_step(cfg, CPU_RUNTIME))
    serve = jax.jit(make_serve_step(cfg, CPU_RUNTIME))

    t0 = time.time()
    logits, cache = prefill(params, prompts, enc) if enc is not None \
        else prefill(params, prompts)
    cache = pad_cache(cache, args.max_new)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s "
          f"(cache leaves: {len(jax.tree.leaves(cache))})")

    out = [tok]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    t0 = time.time()
    for i in range(args.max_new - 1):
        tok, _, cache = serve(params, cache, tok[:, None], pos)
        out.append(tok)
        pos = pos + 1
    dt = time.time() - t0
    toks = jnp.stack(out, 1)
    print(f"decoded {args.max_new} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.max_new / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0].tolist())


if __name__ == "__main__":
    main()
