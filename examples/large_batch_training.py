"""The paper's experiment, end to end (reduced scale): large-batch
training with SNGM matches small-batch MSGD where large-batch MSGD and
LARS fall short (Table 2 on the synthetic CIFAR proxy).

    PYTHONPATH=src python examples/large_batch_training.py
"""
from benchmarks.bench_table2_cifar_proxy import run

if __name__ == "__main__":
    out = run()
    best_large = max(("msgd_large", "lars_large", "sngm_large"),
                     key=lambda k: out[k]["test_acc"])
    print(f"\nbest large-batch optimizer: {best_large} "
          f"(paper predicts sngm_large)")
