"""Checkpoint round-trip fidelity (fast lane).

The headline regression under test: ``np.savez`` stores bfloat16 as a
void record (``|V2``), which used to make ``load_checkpoint`` crash —
the dtype sidecar in meta.json must round-trip every extension dtype
bit-exactly (values AND dtypes), for plain param trees and for both
optimizer state forms (OptState pytree / flat-buffer-resident
FlatOptState).  Restored leaves must also take the dtype of the ``like``
template rather than trusting the file.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import ChainOptState, FlatOptState, OptState, lamb, sngm, \
    to_pytree
from repro.core.schedules import constant

KEY = jax.random.PRNGKey(0)

SHAPES = [(33, 5), (129,), (), (4, 4, 4)]

DTYPE_SPECS = {
    "fp32": [jnp.float32] * len(SHAPES),
    "bf16": [jnp.bfloat16] * len(SHAPES),
    "mixed": [jnp.float32, jnp.bfloat16, jnp.float32, jnp.bfloat16],
}


def make_tree(spec):
    return {f"p{i}": jax.random.normal(jax.random.fold_in(KEY, i), s).astype(d)
            for i, (s, d) in enumerate(zip(SHAPES, DTYPE_SPECS[spec]))}


def assert_tree_bit_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        assert bool(jnp.array_equal(x, y))


@pytest.mark.parametrize("spec", sorted(DTYPE_SPECS))
def test_param_tree_roundtrip_bit_exact(spec, tmp_path):
    tree = make_tree(spec)
    save_checkpoint(str(tmp_path / "ck"), {"params": tree}, step=17)
    restored, step = load_checkpoint(str(tmp_path / "ck"), {"params": tree})
    assert step == 17
    assert_tree_bit_equal(tree, restored["params"])


@pytest.mark.parametrize("spec", sorted(DTYPE_SPECS))
@pytest.mark.parametrize("form", ["pytree", "flat"])
def test_opt_state_roundtrip_bit_exact(spec, form, tmp_path):
    """Both state forms round-trip with non-zero momentum after a step."""
    params = make_tree(spec)
    grads = jax.tree.map(
        lambda p: (2.0 * jax.random.normal(jax.random.fold_in(KEY, p.size),
                                           p.shape)).astype(p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor" if form == "flat" else None)
    state = opt.init(params)
    assert isinstance(state, FlatOptState if form == "flat" else OptState)
    params, state, _ = jax.jit(opt.step)(grads, state, params)

    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": state},
                    step=1)
    like = {"params": params, "opt": opt.init(params)}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 1
    assert_tree_bit_equal(params, restored["params"])
    assert type(restored["opt"]) is type(state)
    assert_tree_bit_equal(state, restored["opt"])      # buffers / momentum
    assert_tree_bit_equal(state.momentum, restored["opt"].momentum)
    assert int(restored["opt"].step) == 1


def test_flat_state_roundtrips_through_pytree_form(tmp_path):
    """A FlatOptState checkpoint can be restored as OptState and back —
    the interconversion launch/train.py --resume relies on."""
    from repro.core import from_pytree
    params = make_tree("mixed")
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    save_checkpoint(str(tmp_path / "ck"), {"opt": to_pytree(state)}, step=1)
    like = {"opt": to_pytree(opt.init(params))}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    back = from_pytree(restored["opt"], params)
    assert_tree_bit_equal(state, back)


@pytest.mark.parametrize("spec", ["fp32", "bf16"])
@pytest.mark.parametrize("form", ["pytree", "flat"])
def test_lamb_adam_slots_roundtrip_bit_exact(spec, form, tmp_path):
    """The Adam-moment flat slots (m_flats/v_flats) and their pytree form
    (the interpreter's ChainOptState) round-trip bit-exactly after a step
    has populated them, fp32 and bf16."""
    params = make_tree(spec)
    grads = jax.tree.map(
        lambda p: (2.0 * jax.random.normal(jax.random.fold_in(KEY, p.size),
                                           p.shape)).astype(p.dtype), params)
    opt = lamb(constant(0.3), weight_decay=1e-4,
               fused="multi_tensor" if form == "flat" else None)
    state = opt.init(params)
    assert isinstance(state,
                      FlatOptState if form == "flat" else ChainOptState)
    params, state, _ = jax.jit(opt.step)(grads, state, params)

    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": state},
                    step=1)
    if form == "flat":
        # both moment buffers must actually be in the archive
        data = np.load(tmp_path / "ck" / "shard_00000.npz")
        assert any("m_flats" in k for k in data.files)
        assert any("v_flats" in k for k in data.files)
        assert not any("u_flats" in k for k in data.files)  # empty for lamb
    like = {"params": params, "opt": opt.init(params)}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 1
    assert_tree_bit_equal(params, restored["params"])
    assert type(restored["opt"]) is type(state)
    assert_tree_bit_equal(state, restored["opt"])
    if form == "flat":
        assert restored["opt"].form == state.form
        m, v = restored["opt"].moments
        ms, vs = state.moments
        assert_tree_bit_equal(m, ms)
        assert_tree_bit_equal(v, vs)


def test_lamb_flat_state_roundtrips_through_chain_form(tmp_path):
    """A fused-lamb FlatOptState saved in its pytree form (ChainOptState,
    what the launcher persists) restores losslessly into either execution
    mode — the cross-form interconversion --resume relies on."""
    from repro.core import from_pytree
    params = make_tree("mixed")
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, p.dtype), params)
    opt = lamb(constant(0.3), weight_decay=1e-4, fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    chain_view = to_pytree(state)
    assert isinstance(chain_view, ChainOptState)
    save_checkpoint(str(tmp_path / "ck"), {"opt": chain_view}, step=1)

    # interpreter-mode template loads it directly...
    opt_i = lamb(constant(0.3), weight_decay=1e-4)
    like = {"opt": opt_i.init(params)}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    assert_tree_bit_equal(chain_view, restored["opt"])
    # ...and from_pytree rebuilds the resident flat form bitwise
    back = from_pytree(restored["opt"], params)
    assert back.form == state.form
    assert_tree_bit_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert_tree_bit_equal(tuple(back.m_flats), tuple(state.m_flats))
    assert_tree_bit_equal(tuple(back.v_flats), tuple(state.v_flats))


def test_restored_leaf_cast_to_like_dtype(tmp_path):
    """Restore must CAST to the template's dtype, not trust the file:
    an fp32 checkpoint loads into a bf16 tree as bf16."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    like = {"w": jnp.zeros((8,), jnp.bfloat16)}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.arange(8, dtype=np.float32))


def test_meta_dtype_sidecar_written(tmp_path):
    tree = make_tree("mixed")
    save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    meta = json.load(open(tmp_path / "ck" / "meta.json"))
    assert meta["format"] == 2
    assert sorted(meta["dtypes"].values()) == sorted(
        jnp.dtype(d).name for d in DTYPE_SPECS["mixed"])
    # bf16 leaves must be stored as a uint16 view, not a void record
    data = np.load(tmp_path / "ck" / "shard_00000.npz")
    for k, name in meta["dtypes"].items():
        if name == "bfloat16":
            assert data[k].dtype == np.uint16


def test_legacy_void_checkpoint_rescued(tmp_path):
    """Pre-sidecar checkpoints stored bf16 as |V2: the bits are intact,
    so restore must recover them via the `like` dtype."""
    w = jax.random.normal(KEY, (6, 3)).astype(jnp.bfloat16)
    os.makedirs(tmp_path / "ck")
    np.savez(tmp_path / "ck" / "shard_00000.npz", w=np.asarray(w))
    assert np.load(tmp_path / "ck" / "shard_00000.npz")["w"].dtype.kind == "V"
    json.dump({"step": 5, "n_leaves": 1},
              open(tmp_path / "ck" / "meta.json", "w"))
    restored, step = load_checkpoint(str(tmp_path / "ck"), {"w": w})
    assert step == 5
    assert restored["w"].dtype == jnp.bfloat16
    assert bool(jnp.array_equal(restored["w"], w))
