"""Checkpoint round-trip fidelity (fast lane).

The headline regression under test: ``np.savez`` stores bfloat16 as a
void record (``|V2``), which used to make ``load_checkpoint`` crash —
the dtype sidecar in meta.json must round-trip every extension dtype
bit-exactly (values AND dtypes), for plain param trees and for both
optimizer state forms (OptState pytree / flat-buffer-resident
FlatOptState).  Restored leaves must also take the dtype of the ``like``
template rather than trusting the file.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import ChainOptState, FlatOptState, OptState, lamb, sngm, \
    to_pytree
from repro.core.schedules import constant

KEY = jax.random.PRNGKey(0)

SHAPES = [(33, 5), (129,), (), (4, 4, 4)]

DTYPE_SPECS = {
    "fp32": [jnp.float32] * len(SHAPES),
    "bf16": [jnp.bfloat16] * len(SHAPES),
    "mixed": [jnp.float32, jnp.bfloat16, jnp.float32, jnp.bfloat16],
}


def make_tree(spec):
    return {f"p{i}": jax.random.normal(jax.random.fold_in(KEY, i), s).astype(d)
            for i, (s, d) in enumerate(zip(SHAPES, DTYPE_SPECS[spec]))}


def assert_tree_bit_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        assert bool(jnp.array_equal(x, y))


@pytest.mark.parametrize("spec", sorted(DTYPE_SPECS))
def test_param_tree_roundtrip_bit_exact(spec, tmp_path):
    tree = make_tree(spec)
    save_checkpoint(str(tmp_path / "ck"), {"params": tree}, step=17)
    restored, step = load_checkpoint(str(tmp_path / "ck"), {"params": tree})
    assert step == 17
    assert_tree_bit_equal(tree, restored["params"])


@pytest.mark.parametrize("spec", sorted(DTYPE_SPECS))
@pytest.mark.parametrize("form", ["pytree", "flat"])
def test_opt_state_roundtrip_bit_exact(spec, form, tmp_path):
    """Both state forms round-trip with non-zero momentum after a step."""
    params = make_tree(spec)
    grads = jax.tree.map(
        lambda p: (2.0 * jax.random.normal(jax.random.fold_in(KEY, p.size),
                                           p.shape)).astype(p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor" if form == "flat" else None)
    state = opt.init(params)
    assert isinstance(state, FlatOptState if form == "flat" else OptState)
    params, state, _ = jax.jit(opt.step)(grads, state, params)

    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": state},
                    step=1)
    like = {"params": params, "opt": opt.init(params)}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 1
    assert_tree_bit_equal(params, restored["params"])
    assert type(restored["opt"]) is type(state)
    assert_tree_bit_equal(state, restored["opt"])      # buffers / momentum
    assert_tree_bit_equal(state.momentum, restored["opt"].momentum)
    assert int(restored["opt"].step) == 1


def test_flat_state_roundtrips_through_pytree_form(tmp_path):
    """A FlatOptState checkpoint can be restored as OptState and back —
    the interconversion launch/train.py --resume relies on."""
    from repro.core import from_pytree
    params = make_tree("mixed")
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    save_checkpoint(str(tmp_path / "ck"), {"opt": to_pytree(state)}, step=1)
    like = {"opt": to_pytree(opt.init(params))}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    back = from_pytree(restored["opt"], params)
    assert_tree_bit_equal(state, back)


@pytest.mark.parametrize("spec", ["fp32", "bf16"])
@pytest.mark.parametrize("form", ["pytree", "flat"])
def test_lamb_adam_slots_roundtrip_bit_exact(spec, form, tmp_path):
    """The Adam-moment flat slots (m_flats/v_flats) and their pytree form
    (the interpreter's ChainOptState) round-trip bit-exactly after a step
    has populated them, fp32 and bf16."""
    params = make_tree(spec)
    grads = jax.tree.map(
        lambda p: (2.0 * jax.random.normal(jax.random.fold_in(KEY, p.size),
                                           p.shape)).astype(p.dtype), params)
    opt = lamb(constant(0.3), weight_decay=1e-4,
               fused="multi_tensor" if form == "flat" else None)
    state = opt.init(params)
    assert isinstance(state,
                      FlatOptState if form == "flat" else ChainOptState)
    params, state, _ = jax.jit(opt.step)(grads, state, params)

    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": state},
                    step=1)
    if form == "flat":
        # both moment buffers must actually be in the archive
        data = np.load(tmp_path / "ck" / "shard_00000.npz")
        assert any("m_flats" in k for k in data.files)
        assert any("v_flats" in k for k in data.files)
        assert not any("u_flats" in k for k in data.files)  # empty for lamb
    like = {"params": params, "opt": opt.init(params)}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 1
    assert_tree_bit_equal(params, restored["params"])
    assert type(restored["opt"]) is type(state)
    assert_tree_bit_equal(state, restored["opt"])
    if form == "flat":
        assert restored["opt"].form == state.form
        m, v = restored["opt"].moments
        ms, vs = state.moments
        assert_tree_bit_equal(m, ms)
        assert_tree_bit_equal(v, vs)


def test_lamb_flat_state_roundtrips_through_chain_form(tmp_path):
    """A fused-lamb FlatOptState saved in its pytree form (ChainOptState,
    what the launcher persists) restores losslessly into either execution
    mode — the cross-form interconversion --resume relies on."""
    from repro.core import from_pytree
    params = make_tree("mixed")
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, p.dtype), params)
    opt = lamb(constant(0.3), weight_decay=1e-4, fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    chain_view = to_pytree(state)
    assert isinstance(chain_view, ChainOptState)
    save_checkpoint(str(tmp_path / "ck"), {"opt": chain_view}, step=1)

    # interpreter-mode template loads it directly...
    opt_i = lamb(constant(0.3), weight_decay=1e-4)
    like = {"opt": opt_i.init(params)}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    assert_tree_bit_equal(chain_view, restored["opt"])
    # ...and from_pytree rebuilds the resident flat form bitwise
    back = from_pytree(restored["opt"], params)
    assert back.form == state.form
    assert_tree_bit_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert_tree_bit_equal(tuple(back.m_flats), tuple(state.m_flats))
    assert_tree_bit_equal(tuple(back.v_flats), tuple(state.v_flats))


@pytest.mark.parametrize("spec", ["fp32", "bf16"])
@pytest.mark.parametrize("form", ["pytree", "flat"])
def test_ema_nesterov_slots_roundtrip_bit_exact(spec, form, tmp_path):
    """Segment-plan state round-trips: a nesterov + ema_params sngm chain
    keeps its f32 EMA shadow slot (``e_flats`` in the flat form, the
    interpreter's ``EmaParamsState`` in the pytree form) bit-exact through
    save/load; the nesterov look-ahead adds NO slot of its own."""
    params = make_tree(spec)
    grads = jax.tree.map(
        lambda p: (2.0 * jax.random.normal(jax.random.fold_in(KEY, p.size),
                                           p.shape)).astype(p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4, nesterov=True,
               ema_decay=0.999,
               fused="multi_tensor" if form == "flat" else None)
    state = opt.init(params)
    assert isinstance(state,
                      FlatOptState if form == "flat" else ChainOptState)
    params, state, _ = jax.jit(opt.step)(grads, state, params)

    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": state},
                    step=1)
    if form == "flat":
        # the shadow bucket set is in the archive; nesterov added nothing
        data = np.load(tmp_path / "ck" / "shard_00000.npz")
        assert any("e_flats" in k for k in data.files)
        assert not any("m_flats" in k for k in data.files)
    like = {"params": params, "opt": opt.init(params)}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 1
    assert_tree_bit_equal(params, restored["params"])
    assert type(restored["opt"]) is type(state)
    assert_tree_bit_equal(state, restored["opt"])
    if form == "flat":
        assert restored["opt"].form == state.form
        assert len(restored["opt"].e_flats) == 1
        assert_tree_bit_equal(tuple(state.e_flats),
                              tuple(restored["opt"].e_flats))
        for bucket in restored["opt"].e_flats[0]:
            assert bucket.dtype == jnp.float32


def test_ema_flat_state_roundtrips_through_chain_form(tmp_path):
    """A segment-plan FlatOptState (``("chain", slots)`` form) saved in
    its pytree view (the interpreter's ChainOptState) restores into the
    interpreter template and rebuilds the resident flat form bitwise —
    the cross-form interconversion --resume relies on."""
    from repro.core import from_pytree
    params = make_tree("mixed")
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, p.dtype), params)
    opt = sngm(constant(0.3), beta=0.9, nesterov=True, ema_decay=0.99,
               fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    chain_view = to_pytree(state)
    assert isinstance(chain_view, ChainOptState)
    save_checkpoint(str(tmp_path / "ck"), {"opt": chain_view}, step=1)

    # interpreter-mode template loads it directly...
    opt_i = sngm(constant(0.3), beta=0.9, nesterov=True, ema_decay=0.99)
    like = {"opt": opt_i.init(params)}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    assert_tree_bit_equal(chain_view, restored["opt"])
    # ...and from_pytree rebuilds the resident flat form bitwise
    back = from_pytree(restored["opt"], params)
    assert back.form == state.form
    assert_tree_bit_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert_tree_bit_equal(tuple(back.u_flats), tuple(state.u_flats))
    assert_tree_bit_equal(tuple(back.e_flats), tuple(state.e_flats))


def test_restored_leaf_cast_to_like_dtype(tmp_path):
    """Restore must CAST to the template's dtype, not trust the file:
    an fp32 checkpoint loads into a bf16 tree as bf16."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    like = {"w": jnp.zeros((8,), jnp.bfloat16)}
    restored, _ = load_checkpoint(str(tmp_path / "ck"), like)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.arange(8, dtype=np.float32))


def test_meta_dtype_sidecar_written(tmp_path):
    tree = make_tree("mixed")
    save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    meta = json.load(open(tmp_path / "ck" / "meta.json"))
    assert meta["format"] == 3
    assert sorted(meta["dtypes"].values()) == sorted(
        jnp.dtype(d).name for d in DTYPE_SPECS["mixed"])
    # bf16 leaves must be stored as a uint16 view, not a void record
    data = np.load(tmp_path / "ck" / "shard_00000.npz")
    for k, name in meta["dtypes"].items():
        if name == "bfloat16":
            assert data[k].dtype == np.uint16


def test_legacy_void_checkpoint_rescued(tmp_path):
    """Pre-sidecar checkpoints stored bf16 as |V2: the bits are intact,
    so restore must recover them via the `like` dtype.  The dir is also
    markerless (legacy writer) but demonstrably complete — meta n_leaves
    matches the archive — so load_checkpoint accepts it."""
    w = jax.random.normal(KEY, (6, 3)).astype(jnp.bfloat16)
    os.makedirs(tmp_path / "ck")
    np.savez(tmp_path / "ck" / "shard_00000.npz", w=np.asarray(w))
    assert np.load(tmp_path / "ck" / "shard_00000.npz")["w"].dtype.kind == "V"
    json.dump({"step": 5, "n_leaves": 1},
              open(tmp_path / "ck" / "meta.json", "w"))
    restored, step = load_checkpoint(str(tmp_path / "ck"), {"w": w})
    assert step == 5
    assert restored["w"].dtype == jnp.bfloat16
    assert bool(jnp.array_equal(restored["w"], w))


# ---------------------------------------------------------------------------
# atomic commit
# ---------------------------------------------------------------------------

def test_save_is_committed_and_staging_cleaned(tmp_path):
    """A completed save carries the COMMIT marker (written into the
    staging dir BEFORE the atomic rename) and leaves no staging dir."""
    from repro.checkpoint import is_committed
    tree = make_tree("fp32")
    path = tmp_path / "ck"
    save_checkpoint(str(path), tree, step=3)
    assert is_committed(str(path))
    assert not os.path.exists(str(path) + ".tmp-staging")
    # overwriting an existing checkpoint also commits atomically
    save_checkpoint(str(path), tree, step=4)
    assert is_committed(str(path))
    _, step = load_checkpoint(str(path), tree)
    assert step == 4


def test_load_rejects_torn_save(tmp_path):
    """A genuinely torn dir — shard written, meta/marker never (what a
    crash in the LEGACY writer left behind) — must be refused, not
    half-loaded.  A markerless dir whose meta n_leaves matches the
    archive is instead accepted as a complete legacy checkpoint."""
    tree = make_tree("fp32")
    path = tmp_path / "ck"
    save_checkpoint(str(path), tree, step=1)
    os.remove(path / "COMMIT")
    os.remove(path / "meta.json")                 # legacy-torn: no meta
    with pytest.raises(ValueError, match="COMMIT"):
        load_checkpoint(str(path), tree)

    # markerless but complete (meta matches archive) = legacy, loads
    path2 = tmp_path / "ck2"
    save_checkpoint(str(path2), tree, step=2)
    os.remove(path2 / "COMMIT")
    _, step = load_checkpoint(str(path2), tree)
    assert step == 2

    # markerless AND meta/archive mismatch = torn, refused
    path3 = tmp_path / "ck3"
    save_checkpoint(str(path3), tree, step=3)
    os.remove(path3 / "COMMIT")
    meta = json.load(open(path3 / "meta.json"))
    meta["n_leaves"] += 1
    json.dump(meta, open(path3 / "meta.json", "w"))
    with pytest.raises(ValueError, match="COMMIT"):
        load_checkpoint(str(path3), tree)


def test_interrupted_swap_recovered_on_load_and_save(tmp_path):
    """Crash between the swap's rename and replace: `path` is gone but a
    fully committed staging (or backup) dir survives.  Both load and a
    subsequent save must recover it instead of failing / deleting it."""
    import shutil
    tree = make_tree("fp32")
    path = tmp_path / "ck"
    save_checkpoint(str(path), tree, step=7)
    # simulate the crash window: the committed dir sits at .tmp-staging
    shutil.move(str(path), str(path) + ".tmp-staging")
    restored, step = load_checkpoint(str(path), tree)   # recovers in place
    assert step == 7
    assert os.path.isdir(path)
    assert not os.path.exists(str(path) + ".tmp-staging")
    assert_tree_bit_equal(tree, restored)

    # same, via the backup slot, recovered by the NEXT save (not deleted)
    shutil.move(str(path), str(path) + ".tmp-old")
    save_checkpoint(str(path), tree, step=8)
    _, step = load_checkpoint(str(path), tree)
    assert step == 8
    assert not os.path.exists(str(path) + ".tmp-old")


def test_save_refuses_to_clobber_regular_file(tmp_path):
    """Destination exists but is a FILE: clean refusal (no
    NotADirectoryError traceback), file untouched, no staging leak."""
    target = tmp_path / "out.json"
    target.write_text("{}")
    with pytest.raises(ValueError, match="look like a checkpoint"):
        save_checkpoint(str(target), make_tree("fp32"), step=0)
    assert target.read_text() == "{}"
    assert not os.path.exists(str(target) + ".tmp-staging")


def test_save_refuses_to_clobber_non_checkpoint_dir(tmp_path):
    """The atomic replace deletes the destination first — it must refuse
    when the destination is NOT a previous checkpoint."""
    path = tmp_path / "precious"
    os.makedirs(path)
    (path / "notes.txt").write_text("not a checkpoint")
    with pytest.raises(ValueError, match="look like a checkpoint"):
        save_checkpoint(str(path), make_tree("fp32"), step=0)
    assert (path / "notes.txt").exists()          # untouched


# ------------------------------------------ multi-host commit barrier

def test_multihost_barrier_commits_only_after_all_ranks(tmp_path):
    """The shared-FS marker barrier: a fast rank 0 must NOT bless the
    save while a peer is still writing — COMMIT appears only after every
    rank's done marker, and the committed dir round-trips bit-exactly.
    Threads stand in for processes via the injectable rank/world."""
    import threading
    import time

    from repro.checkpoint import is_committed
    from repro.checkpoint.io import _multihost_save

    path = str(tmp_path / "ck")
    tree = make_tree("mixed")
    world = 3
    release = threading.Event()
    errs = []

    def run(rank):
        try:
            if rank == world - 1:        # the straggler
                release.wait(timeout=30)
            _multihost_save(path, tree, 5, None, None, None,
                            process_index=rank, process_count=world,
                            timeout_s=60.0, poll_s=0.01)
        except Exception as e:           # pragma: no cover - surfaced below
            errs.append((rank, e))

    threads = [threading.Thread(target=run, args=(r,)) for r in range(world)]
    for t in threads:
        t.start()
    # rank 0 and rank 1 are done writing, rank 2 is held back: the save
    # must stay uncommitted and invisible at the destination
    deadline = time.monotonic() + 10
    staging = path + ".tmp-staging"
    while not os.path.exists(staging) and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.3)
    assert not is_committed(path)
    assert not os.path.exists(path)
    release.set()
    for t in threads:
        t.join(timeout=60)
    assert not errs, errs
    assert is_committed(path)
    assert not os.path.exists(staging)   # barrier markers cleaned up
    # every rank's shard landed in the committed dir
    for r in range(world):
        assert os.path.exists(os.path.join(path, f"shard_{r:05d}.npz"))
    restored, step = load_checkpoint(path, tree)
    assert step == 5
    assert_tree_bit_equal(tree, restored)


def test_multihost_barrier_times_out_on_missing_rank(tmp_path):
    """A dead peer must surface as a TimeoutError on rank 0, leaving an
    UNCOMMITTED staging dir behind (never a blessed torn save)."""
    from repro.checkpoint import is_committed
    from repro.checkpoint.io import _multihost_save

    path = str(tmp_path / "ck")
    tree = make_tree("fp32")
    with pytest.raises(TimeoutError, match="barrier timed out"):
        _multihost_save(path, tree, 3, None, None, None,
                        process_index=0, process_count=2,
                        timeout_s=0.4, poll_s=0.01)
    assert not os.path.exists(path)
    assert not is_committed(path)
    # nothing was blessed: the staging leftovers carry no COMMIT marker
    assert not is_committed(path + ".tmp-staging")
    # and the next healthy save clears them and commits
    _multihost_save(path, tree, 4, None, None, None,
                    process_index=0, process_count=1,
                    timeout_s=10.0, poll_s=0.01)
    assert is_committed(path)
    _, step = load_checkpoint(path, tree)
    assert step == 4


def test_multihost_peer_times_out_without_rank0(tmp_path):
    """A peer whose rank 0 never stages must fail loudly, not hang."""
    from repro.checkpoint.io import _multihost_save

    with pytest.raises(TimeoutError, match="rank 0 to stage"):
        _multihost_save(str(tmp_path / "ck"), make_tree("fp32"), 1,
                        None, None, None,
                        process_index=1, process_count=2,
                        timeout_s=0.4, poll_s=0.01)
