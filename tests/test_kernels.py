"""Pallas kernel validation: shape/dtype sweeps against ref.py oracles,
all in interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_sngm.kernel import fused_sngm_update
from repro.kernels.fused_sngm.ref import sngm_update_ref
from repro.kernels.fused_lars.kernel import fused_lars_update
from repro.kernels.fused_lars.ref import lars_update_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, i=0):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# fused SNGM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(17,), (128,), (100, 37), (8, 16, 33),
                                   (1024, 128)])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_fused_sngm_shapes_dtypes(shape, gdtype):
    p = _rand(shape, i=1)
    g = _rand(shape, gdtype, i=2) * 30
    u = _rand(shape, i=3)
    inv, lr = jnp.float32(0.03), jnp.float32(0.7)
    pn, un = fused_sngm_update(p, g, u, inv, lr, beta=0.9, interpret=True)
    pr, ur = sngm_update_ref(p, g, u, inv, lr, beta=0.9)
    np.testing.assert_allclose(np.asarray(pn), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(un), np.asarray(ur), atol=1e-6)


# ---------------------------------------------------------------------------
# fused LARS
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (513, 97), (32, 32, 9)])
def test_fused_lars_shapes(shape):
    w = _rand(shape, i=4)
    g = _rand(shape, i=5) * 5
    v = _rand(shape, i=6) * 0.1
    lr = jnp.float32(0.5)
    wo, vo = fused_lars_update(w, g, v, lr, beta=0.9, wd=1e-4, interpret=True)
    wr, vr = lars_update_ref(w, g, v, lr, beta=0.9, wd=1e-4)
    np.testing.assert_allclose(np.asarray(wo), np.asarray(wr), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 256), (2, 33, 300),
                                   (16, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes_dtypes(shape, dtype):
    x = _rand(shape, dtype, i=7)
    s = _rand(shape[-1:], i=8)
    o = rmsnorm_pallas(x, s, interpret=True)
    r = rmsnorm_ref(x, s)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,K,hd", [(256, 4, 4, 64), (512, 4, 2, 64),
                                      (256, 8, 1, 128)])
@pytest.mark.parametrize("kw", [dict(causal=True),
                                dict(causal=True, window=128),
                                dict(causal=True, softcap=50.0),
                                dict(causal=False)])
def test_flash_attention_sweep(S, H, K, hd, kw):
    B = 2
    q = _rand((B, S, H, hd), i=9)
    k = _rand((B, S, K, hd), i=10)
    v = _rand((B, S, K, hd), i=11)
    o = flash_attention(q, k, v, q_blk=128, kv_blk=128, interpret=True, **kw)
    r = attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_attention_bf16():
    B, S, H, hd = 1, 256, 2, 64
    q = _rand((B, S, H, hd), jnp.bfloat16, i=12)
    k = _rand((B, S, H, hd), jnp.bfloat16, i=13)
    v = _rand((B, S, H, hd), jnp.bfloat16, i=14)
    o = flash_attention(q, k, v, q_blk=128, kv_blk=128, interpret=True)
    r = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=3e-2)


def test_flash_attention_matches_model_sdpa():
    """The kernel must agree with the model's _sdpa_seq path (the jnp
    implementation the dry-run lowers), including window+softcap."""
    from repro.models import layers
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = _rand((B, S, H, hd), i=15)
    k = _rand((B, S, K, hd), i=16)
    v = _rand((B, S, K, hd), i=17)
    o_kernel = flash_attention(q, k, v, q_blk=128, kv_blk=128, window=64,
                               softcap=30.0, interpret=True)
    o_model = layers._sdpa_seq(q, k, v, True, 64, 30.0, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def _paged_case(B, H, K, hd, bs, nbt, i):
    """Random pools + a block table of distinct non-scratch blocks."""
    nb = 1 + B * nbt + 3          # scratch + owned + spare
    q = _rand((B, H, hd), i=i)
    kp = _rand((nb, bs, K, hd), i=i + 1)
    vp = _rand((nb, bs, K, hd), i=i + 2)
    ids = np.random.RandomState(i).permutation(
        np.arange(1, nb))[:B * nbt].reshape(B, nbt).astype(np.int32)
    return q, kp, vp, jnp.asarray(ids)


@pytest.mark.parametrize("H,K", [(4, 4), (8, 2)])          # MHA and GQA
@pytest.mark.parametrize("bs,nbt", [(8, 4), (16, 2)])
def test_paged_attention_matches_ref(H, K, bs, nbt):
    B, hd = 3, 64
    q, kp, vp, bt = _paged_case(B, H, K, hd, bs, nbt, i=20)
    # frontier at a block boundary, mid-block, and the very last slot
    pos = jnp.asarray([0, bs, nbt * bs - 1], jnp.int32)
    o = paged_decode_attention(q, kp, vp, bt, pos, interpret=True)
    r = paged_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("kw", [dict(window=10), dict(softcap=30.0),
                                dict(window=7, softcap=20.0)])
def test_paged_attention_window_softcap(kw):
    B, H, K, hd, bs, nbt = 3, 8, 2, 64, 8, 4
    q, kp, vp, bt = _paged_case(B, H, K, hd, bs, nbt, i=30)
    pos = jnp.asarray([5, 17, 31], jnp.int32)
    o = paged_decode_attention(q, kp, vp, bt, pos, interpret=True, **kw)
    r = paged_attention_ref(q, kp, vp, bt, pos, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_paged_attention_bf16():
    B, H, K, hd, bs, nbt = 2, 4, 2, 64, 8, 3
    q, kp, vp, bt = _paged_case(B, H, K, hd, bs, nbt, i=40)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    pos = jnp.asarray([6, 19], jnp.int32)
    o = paged_decode_attention(q, kp, vp, bt, pos, interpret=True)
    r = paged_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=3e-2)


def test_paged_ref_matches_model_gather_path():
    """ref.py must equal the model's jnp paged decode math (_paged_gather
    + _sdpa), which is itself the bitwise-parity reference vs the dense
    engine — chaining kernel -> ref -> model -> dense."""
    from repro.models import layers
    B, H, K, hd, bs, nbt = 2, 8, 2, 64, 8, 3
    q, kp, vp, bt = _paged_case(B, H, K, hd, bs, nbt, i=50)
    pos = jnp.asarray([9, 21], jnp.int32)
    r = paged_attention_ref(q, kp, vp, bt, pos)
    kd = layers._paged_gather(kp, bt)
    vd = layers._paged_gather(vp, bt)
    valid = layers._paged_valid(pos, kd.shape[1], 0)
    mask = jnp.where(valid, 0.0, layers.NEG_INF)[:, None, None, :]
    o = layers._sdpa(q[:, None], kd, vd, mask, 0.0, hd ** -0.5)[:, 0]
    np.testing.assert_allclose(np.asarray(r), np.asarray(o), atol=2e-5)
