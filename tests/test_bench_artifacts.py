"""Canonical BENCH artifact schema + check_bench gate + sweep smoke.

The gate must enforce exactly what the old inline CI heredoc asserts
enforced (launch counts, packing ratio, residency, donation warnings),
reject schema skew loudly, and catch trend regressions against the
committed baseline.
"""
from __future__ import annotations

import copy
import json

import pytest

from benchmarks import artifact as A
from benchmarks import check_bench as C

GOOD_RESULTS = {
    "launches_per_step": {"per_leaf": 16, "multi_tensor": 2,
                          "lamb_fused": 2, "clip_sngm": 3,
                          "nesterov_sngm": 2, "sngm_clip_mid": 2,
                          "sngm_ema": 2},
    "packed_bytes_per_step": {"resident": 100, "per_step": 300,
                              "ratio": 1 / 3, "lamb_resident": 100,
                              "clip_sngm_resident": 200,
                              "nesterov_resident": 100,
                              "sngm_clip_mid_resident": 200,
                              "sngm_ema_resident": 100},
    "param_bytes_live": {"resident": 110, "raw_params": 100,
                         "legacy_two_copies": 210},
    "donation_warnings": [],
}


def write_artifact(tmp_path, name="overhead", results=None, quick=True,
                   mutate=None, fname="a.json"):
    env = A.make_envelope(name, results if results is not None
                          else copy.deepcopy(GOOD_RESULTS),
                          quick=quick, env={})
    if mutate:
        mutate(env)
    p = tmp_path / fname
    p.write_text(json.dumps(env))
    return str(p)


# --- envelope schema ---------------------------------------------------

def test_envelope_round_trip(tmp_path):
    path = A.write_bench_artifact("overhead", GOOD_RESULTS, quick=True,
                                  json_dir=str(tmp_path))
    assert path.endswith("BENCH_overhead.json")
    art = A.load_bench_artifact(path)
    assert art["schema_version"] == A.SCHEMA_VERSION
    assert art["bench"] == "overhead" and art["quick"] is True
    assert art["results"]["launches_per_step"]["multi_tensor"] == 2


def test_envelope_rejects_missing_fields():
    probs = A.validate_envelope({"schema_version": A.SCHEMA_VERSION})
    assert any("missing required field 'bench'" in p for p in probs)
    assert any("missing required field 'results'" in p for p in probs)
    assert any("missing required field 'quick'" in p for p in probs)


def test_envelope_rejects_unknown_fields_and_versions():
    env = A.make_envelope("overhead", {}, quick=False, env={})
    assert A.validate_envelope(env) == []
    bad = dict(env, extra_field=1)
    assert any("unknown field 'extra_field'" in p
               for p in A.validate_envelope(bad))
    bad = dict(env, schema_version=99)
    assert any("unknown schema_version 99" in p
               for p in A.validate_envelope(bad))
    assert A.validate_envelope([1, 2]) != []


def test_load_bench_artifact_raises_on_invalid(tmp_path):
    path = write_artifact(tmp_path,
                          mutate=lambda e: e.update(surprise=True))
    with pytest.raises(ValueError, match="surprise"):
        A.load_bench_artifact(path)


# --- threshold gate ----------------------------------------------------

def thresholds():
    with open(C.DEFAULT_THRESHOLDS) as f:
        return json.load(f)


def test_committed_thresholds_parse():
    th = C.load_thresholds(C.DEFAULT_THRESHOLDS)
    assert "overhead" in th and "sweep" in th
    # the exact guarantees the old heredoc asserts enforced
    checks = th["overhead"]["checks"]
    assert checks["launches_per_step.multi_tensor"] == {"op": "eq", "value": 2}
    assert checks["launches_per_step.lamb_fused"] == {"op": "eq", "value": 2}
    assert checks["launches_per_step.clip_sngm"] == {"op": "eq", "value": 3}
    assert "donation_warnings" in checks
    trend = th["overhead"]["trend"]
    assert any(k.startswith("launches_per_step") for k in trend)
    assert any(k.startswith("packed_bytes_per_step") for k in trend)
    assert any(k.startswith("param_bytes_live") for k in trend)


def test_gate_passes_good_artifact(tmp_path, capsys):
    path = write_artifact(tmp_path)
    assert C.main([path]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "FAIL" not in out


@pytest.mark.parametrize("break_it, broken_check", [
    (lambda r: r["launches_per_step"].update(multi_tensor=4),
     "launches_per_step.multi_tensor"),
    (lambda r: r["launches_per_step"].update(lamb_fused=5),
     "launches_per_step.lamb_fused"),
    (lambda r: r["launches_per_step"].update(clip_sngm=7),
     "launches_per_step.clip_sngm"),
    (lambda r: r["packed_bytes_per_step"].update(resident=200),
     "packed_bytes_per_step.resident"),
    (lambda r: r["packed_bytes_per_step"].update(lamb_resident=150),
     "packed_bytes_per_step.lamb_resident"),
    (lambda r: r["packed_bytes_per_step"].update(clip_sngm_resident=250),
     "packed_bytes_per_step.clip_sngm_resident"),
    (lambda r: r["param_bytes_live"].update(resident=200),
     "param_bytes_live.resident"),
    (lambda r: r.update(donation_warnings=["donated buffer not aliased"]),
     "donation_warnings"),
])
def test_gate_fails_each_regression(tmp_path, capsys, break_it,
                                    broken_check):
    results = copy.deepcopy(GOOD_RESULTS)
    break_it(results)
    path = write_artifact(tmp_path, results=results)
    assert C.main([path]) == 1
    out = capsys.readouterr().out
    assert any(broken_check in line and "FAIL" in line
               for line in out.splitlines())


def test_gate_fails_on_missing_results_key(tmp_path, capsys):
    results = copy.deepcopy(GOOD_RESULTS)
    del results["param_bytes_live"]
    path = write_artifact(tmp_path, results=results)
    assert C.main([path]) == 1
    assert "<missing>" in capsys.readouterr().out


def test_gate_rejects_unknown_op():
    with pytest.raises(C.CheckError, match="unknown threshold op"):
        C.eval_check({"x": 1}, "x", {"op": "approximately_vibes"})


def test_gate_schema_error_is_exit_2(tmp_path, capsys):
    path = write_artifact(tmp_path, mutate=lambda e: e.pop("results"))
    assert C.main([path]) == 2
    assert "ERROR" in capsys.readouterr().out


# --- trend mode --------------------------------------------------------

def test_trend_passes_on_equal_and_improved(tmp_path):
    base = write_artifact(tmp_path, fname="base.json")
    fresh = write_artifact(tmp_path, fname="fresh.json")
    assert C.main([fresh, "--trend", "--baseline", base]) == 0
    better = copy.deepcopy(GOOD_RESULTS)
    better["packed_bytes_per_step"]["resident"] = 50   # improvement is fine
    fresh2 = write_artifact(tmp_path, results=better, fname="fresh2.json")
    assert C.main([fresh2, "--trend", "--baseline", base]) == 0


@pytest.mark.parametrize("worsen, key", [
    (lambda r: r["launches_per_step"].update(multi_tensor=3),
     "launches_per_step.multi_tensor"),
    (lambda r: r["packed_bytes_per_step"].update(resident=101),
     "packed_bytes_per_step.resident"),
    (lambda r: r["param_bytes_live"].update(resident=111),
     "param_bytes_live.resident"),
])
def test_trend_fails_on_regression(tmp_path, capsys, worsen, key):
    base = write_artifact(tmp_path, fname="base.json")
    worse = copy.deepcopy(GOOD_RESULTS)
    worsen(worse)
    fresh = write_artifact(tmp_path, results=worse, fname="fresh.json")
    assert C.main([fresh, "--trend", "--baseline", base]) == 1
    out = capsys.readouterr().out
    assert any(key in line and "FAIL" in line for line in out.splitlines())


def test_trend_rejects_scale_mismatch(tmp_path, capsys):
    base = write_artifact(tmp_path, quick=True, fname="base.json")
    fresh = write_artifact(tmp_path, quick=False, fname="fresh.json")
    assert C.main([fresh, "--trend", "--baseline", base]) == 2
    assert "scales" in capsys.readouterr().out


def test_trend_requires_baseline(tmp_path):
    path = write_artifact(tmp_path)
    assert C.main([path, "--trend"]) == 2


# --- sweep record schema ----------------------------------------------

def make_sweep_record(**over):
    rec = {"name": "convnet_sngm_b16", "arch": "convnet", "family": "sngm",
           "fused": "multi_tensor", "batch": 16, "steps": 4,
           "grad_computations": 64, "budget_unit": "examples",
           "final_loss": 2.3, "test_acc": 0.1, "diverged": False,
           "wall_time_s": 1.0, "throughput": 64.0,
           "engine": {"launches_per_step": 2, "packed_bytes_per_step": 100,
                      "param_bytes_live": 100}}
    rec.update(over)
    return rec


def make_sweep_results(records):
    return {"record_schema_version": A.SWEEP_RECORD_SCHEMA_VERSION,
            "records": records}


def test_sweep_results_validation():
    assert A.validate_sweep_results(
        make_sweep_results([make_sweep_record()])) == []
    probs = A.validate_sweep_results({"records": [make_sweep_record()]})
    assert any("record_schema_version" in p for p in probs)
    probs = A.validate_sweep_results(make_sweep_results([]))
    assert any("non-empty" in p for p in probs)
    rec = make_sweep_record()
    del rec["grad_computations"]
    probs = A.validate_sweep_results(make_sweep_results([rec]))
    assert any("grad_computations" in p for p in probs)
    rec = make_sweep_record()
    del rec["engine"]["param_bytes_live"]
    probs = A.validate_sweep_results(make_sweep_results([rec]))
    assert any("param_bytes_live" in p for p in probs)


def test_sweep_gate_checks_records(tmp_path, capsys):
    good = write_artifact(tmp_path, name="sweep",
                          results=make_sweep_results([make_sweep_record()]),
                          fname="sweep.json")
    assert C.main([good]) == 0
    # a de-fused run (O(n) launches) must fail the per-record check
    bad_rec = make_sweep_record(
        engine={"launches_per_step": 16, "packed_bytes_per_step": 100,
                "param_bytes_live": 100})
    bad = write_artifact(tmp_path, name="sweep",
                         results=make_sweep_results([bad_rec]),
                         fname="sweep_bad.json")
    assert C.main([bad]) == 1
    out = capsys.readouterr().out
    assert "engine.launches_per_step" in out


# --- fast-lane sweep smoke --------------------------------------------

def test_bench_sweep_quick_record_shape(tmp_path):
    """bench_sweep --quick at micro scale: real training on the fused
    resident path, canonical artifact written, records carry the full
    schema, and the gate passes on the result."""
    from benchmarks import bench_sweep

    results = bench_sweep.run(
        quick=True, json_dir=str(tmp_path),
        convnet_batches=(16,), convnet_epochs=1, convnet_n_train=64,
        lm_batches=(8,), lm_tokens_budget=8 * 32 * 2,
        families=("sngm",))
    assert A.validate_sweep_results(results) == []
    names = [r["name"] for r in results["records"]]
    assert names == ["convnet_sngm_b16", "convnet_sngm_b16_ghost",
                     "lm_sngm_b8"]
    conv, ghost, lm = results["records"]
    assert conv["arch"] == "convnet" and conv["budget_unit"] == "examples"
    # the ghost-batch-norm axis rides the schema: same record shape,
    # ghost_batch stamped, plain rungs carry None
    assert conv["ghost_batch"] is None
    assert ghost["ghost_batch"] == 16 and not ghost["diverged"]
    assert lm["arch"] == "transformer" and lm["budget_unit"] == "tokens"
    for rec in results["records"]:
        # fused resident path: O(1) launches, finite loss, real timing
        assert rec["fused"] == "multi_tensor"
        assert rec["engine"]["launches_per_step"] == 2
        assert rec["engine"]["packed_bytes_per_step"] > 0
        assert rec["engine"]["param_bytes_live"] > 0
        assert rec["wall_time_s"] > 0
        assert rec["final_loss"] == pytest.approx(rec["final_loss"])
    assert lm["grad_computations"] == 8 * 32 * 2
    # the artifact landed in canonical form and passes the gate
    path = str(tmp_path / "BENCH_sweep.json")
    art = A.load_bench_artifact(path)
    assert art["bench"] == "sweep" and art["quick"] is True
    assert C.main([path]) == 0
