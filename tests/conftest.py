import os
import sys

# tests see ONE device (the dry-run sets its own 512-device flag in a
# separate process); keep any user XLA_FLAGS out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
