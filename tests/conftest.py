import os
import sys

import pytest

# tests see ONE device (the dry-run sets its own 512-device flag in a
# separate process); keep any user XLA_FLAGS out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too, so the benchmarks/ namespace package (bench harness,
# artifact schema, check_bench gate) is importable from the suite
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

# Parametrized cases that individually exceed ~10s on the CI CPU runner.
# Whole long-running modules carry ``pytestmark = pytest.mark.slow`` instead;
# this hook catches the heavyweight archs inside otherwise-fast sweeps so the
# tier-1 lane (``pytest -m "not slow"``) stays well under a minute.
_SLOW_PARAM_TOKENS = (
    "jamba-1.5-large-398b",
    "gemma2-27b",
    "whisper-large-v3",
    "deepseek-v2-236b",
    "deepseek-v2-lite-16b",
    "chameleon-34b",
    "mamba2-1.3b",
    "yi-9b",
    "512-4-2-64",   # longest flash-attention sweep cases
)


def pytest_collection_modifyitems(config, items):
    for item in items:
        if any(tok in item.nodeid for tok in _SLOW_PARAM_TOKENS):
            item.add_marker(pytest.mark.slow)
