"""Chunked LM loss == naive full-logits cross-entropy (value and grads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.models import layers
from repro.training.loss import lm_loss


def naive_loss(h, unembed, tokens, mask, cfg):
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        unembed.astype(jnp.float32))
    logits = layers.softcap(logits, cfg.final_softcap)
    B, S = tokens.shape
    targets = jnp.roll(tokens, -1, axis=1)
    m = mask.at[:, -1].set(0.0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    ce = (lse - picked) * m
    return jnp.sum(ce) / jnp.maximum(jnp.sum(m), 1.0)


def _setup(arch="gemma2-27b", B=2, S=32, d=64, V=128):
    cfg = smoke_variant(ARCHS[arch])        # gemma2: exercises final_softcap
    k = jax.random.PRNGKey(0)
    h = jax.random.normal(k, (B, S, d), jnp.float32)
    unembed = jax.random.normal(jax.random.fold_in(k, 1), (d, V)) * 0.1
    tokens = jax.random.randint(jax.random.fold_in(k, 2), (B, S), 0, V)
    mask = jnp.ones((B, S), jnp.float32).at[0, :5].set(0.0)
    return cfg, h, unembed, tokens, mask


@pytest.mark.slow
def test_chunked_matches_naive_value():
    cfg, h, u, t, m = _setup()
    l1, n1 = lm_loss(h, u, t, m, cfg)
    l2 = naive_loss(h, u, t, m, cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@pytest.mark.slow
def test_chunked_matches_naive_grads():
    cfg, h, u, t, m = _setup()
    g1 = jax.grad(lambda hh, uu: lm_loss(hh, uu, t, m, cfg)[0], argnums=(0, 1))(h, u)
    g2 = jax.grad(lambda hh, uu: naive_loss(hh, uu, t, m, cfg), argnums=(0, 1))(h, u)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_last_position_masked():
    cfg, h, u, t, m = _setup()
    l1, n = lm_loss(h, u, t, m, cfg)
    # token count excludes the final position and the 5 masked ones
    assert int(n) == t.shape[0] * t.shape[1] - t.shape[0] - 5
