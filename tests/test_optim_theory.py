"""Property-based tests of the paper's theoretical claims (hypothesis).

``hypothesis`` is a declared test extra (``pip install -e .[test]``); on a
bare environment the whole module skips instead of dying at collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import sngm, msgd
from repro.core.schedules import constant


@settings(max_examples=30, deadline=None)
@given(beta=st.floats(0.0, 0.99),
       seed=st.integers(0, 2**31 - 1),
       log_scale=st.floats(-8, 8))
def test_lemma4_momentum_bound(beta, seed, log_scale):
    """Lemma 4: ||u_t|| <= 1/(1-beta) for ANY gradient sequence/scale."""
    rng = np.random.RandomState(seed)
    opt = sngm(constant(0.1), beta=beta)
    p = {"w": jnp.zeros((6,))}
    state = opt.init(p)
    bound = 1.0 / (1.0 - beta) + 1e-3
    for _ in range(20):
        g = {"w": jnp.asarray(rng.randn(6) * 10.0 ** log_scale, jnp.float32)}
        p, state, stats = opt.step(g, state, p)
        assert float(stats["update_norm"]) <= bound


@settings(max_examples=20, deadline=None)
@given(beta=st.floats(0.0, 0.95), lr=st.floats(1e-4, 1.0),
       seed=st.integers(0, 2**31 - 1))
def test_sngm_step_displacement_bound(beta, lr, seed):
    """||w_{t+1} - w_t|| = lr * ||u_{t+1}|| <= lr / (1-beta):  the bounded-
    update property that lets SNGM use any positive lr (Theorem 5)."""
    rng = np.random.RandomState(seed)
    opt = sngm(constant(lr), beta=beta)
    p = {"w": jnp.asarray(rng.randn(8), jnp.float32)}
    state = opt.init(p)
    for _ in range(10):
        prev = p["w"]
        g = {"w": jnp.asarray(rng.randn(8) * 1e4, jnp.float32)}
        p, state, _ = opt.step(g, state, p)
        assert float(jnp.linalg.norm(p["w"] - prev)) <= lr / (1 - beta) + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sngm_converges_on_sharp_quadratic(seed):
    """High-curvature quadratic (large L): SNGM with lr >> 1/L still
    converges to near the optimum; MSGD with the same lr diverges.
    This is the paper's central claim (§3 vs §4) in miniature."""
    L = 1e4
    H = jnp.asarray(np.diag([L, 1.0, 10.0]), jnp.float32)
    w0 = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
    rng = np.random.RandomState(seed)

    def run(opt, steps=300):
        p = {"w": w0}
        state = opt.init(p)
        for _ in range(steps):
            noise = jnp.asarray(rng.randn(3) * 0.01, jnp.float32)
            g = {"w": H @ p["w"] + noise}
            p, state, _ = opt.step(g, state, p)
            if not np.all(np.isfinite(np.asarray(p["w"]))):
                return np.inf
        return float(0.5 * p["w"] @ H @ p["w"])

    # lr is ~500x larger than MSGD's stability limit (1-b)^2/((1+b)L);
    # SNGM (Thm 5) converges to an O(lr)-neighborhood for ANY positive lr
    from repro.core.schedules import poly_power
    lr = 0.01
    f0 = float(0.5 * w0 @ H @ w0)                 # ~5000
    f_sngm = run(sngm(poly_power(lr, 300, 1.1), beta=0.9))
    f_msgd = run(msgd(constant(lr), beta=0.9))
    assert f_sngm < 1e-3 * f0, f_sngm
    assert (not np.isfinite(f_msgd)) or f_msgd > 1e2


def test_corollary7_batch_scaling_rates():
    """Corollary 7 schedule: B=sqrt(C), eta=sqrt(B/C).  Check that the
    bound's three terms all scale as C^{-1/4} numerically."""
    def bound(C, beta=0.9, L=10.0, F0=1.0, sigma=1.0):
        B = np.sqrt(C)
        T = C / B
        eta = np.sqrt(B / C)
        kappa = (1 + beta) / (1 - beta) ** 2
        return (2 * (1 - beta) * F0 / (eta * T) + L * kappa * eta
                + 2 * sigma / np.sqrt(B))
    for C in (1e4, 1e6, 1e8):
        ratio = bound(C) / bound(C * 16)
        np.testing.assert_allclose(ratio, 2.0, rtol=0.05)  # 16^{1/4} = 2
