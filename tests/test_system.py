"""End-to-end system behaviour tests: training actually learns; the
paper's central claim holds on a real (small) model; data pipeline and
checkpointing round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.configs import ARCHS, smoke_variant
from repro.core import msgd, sngm
from repro.core.schedules import poly_power
from repro.data import SyntheticLM, synthetic_images
from repro.models import CPU_RUNTIME, model_defs
from repro.models.param import materialize
from repro.training import make_train_step


def _train(opt, cfg, steps, batch=8, seq=32, seed=0):
    params = materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    data = SyntheticLM(cfg.vocab_size, seq, batch, branching=4)
    state = opt.init_state(params)
    # donated, like the production launcher
    step = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2),
                   donate_argnums=(0,))
    losses = []
    for t in range(steps):
        state, stats = step(state, data.batch_at(t))
        losses.append(float(stats["loss"]))
    return losses


@pytest.fixture(scope="module")
def tiny_cfg():
    import dataclasses
    return dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                               vocab_size=64, compute_dtype="float32")


def test_training_learns_the_chain(tiny_cfg):
    """SNGM training must make real progress toward the bigram-chain
    entropy floor (log 4 ~ 1.386 nats) from the ~log(64) start."""
    losses = _train(sngm(poly_power(2.0, 80, 1.1), beta=0.9), tiny_cfg, 80)
    assert losses[0] > 3.8
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_sngm_stays_finite_at_any_lr(tiny_cfg):
    """Lemma 4 consequence on a real model: the SNGM update is bounded by
    lr/(1-beta) regardless of gradient scale, so even an absurd lr never
    produces NaN/inf — unlike unnormalized methods (covered analytically
    in test_optim_theory.py::test_sngm_converges_on_sharp_quadratic)."""
    losses = _train(sngm(poly_power(100.0, 15, 1.1), beta=0.9), tiny_cfg, 15)
    assert all(np.isfinite(l) for l in losses), losses


def test_stats_keys_consistent_across_n_micro(tiny_cfg):
    """Regression: the scan branch used to drop ce_loss/aux_loss/ntok
    (metrics = {}), so logged stats silently changed shape with n_micro.
    Metrics must survive accumulation with global-batch semantics:
    ce_loss combines token-weighted (a plain mean of per-micro means
    diverges when mask density is ragged), ntok sums to the total."""
    params = materialize(model_defs(tiny_cfg), jax.random.PRNGKey(0))
    data = SyntheticLM(tiny_cfg.vocab_size, 32, 8, branching=4)
    batch = dict(data.batch_at(0))
    # ragged mask density across the micro-batch split: rows 0-3 keep 1/4
    # of their tokens, rows 4-7 all of them
    mask = np.ones((8, 32), np.float32)
    mask[:4, 8:] = 0.0
    batch["loss_mask"] = jnp.asarray(mask)
    stats_by_n = {}
    for n_micro in (1, 4):
        opt = sngm(poly_power(0.1, 10, 1.1), beta=0.9)
        step = jax.jit(make_train_step(tiny_cfg, CPU_RUNTIME, opt,
                                       n_micro=n_micro))
        _, stats = step(opt.init_state(params), batch)
        stats_by_n[n_micro] = stats
    assert set(stats_by_n[1]) == set(stats_by_n[4])
    assert {"ce_loss", "aux_loss", "ntok"} <= set(stats_by_n[1])
    np.testing.assert_allclose(float(stats_by_n[1]["ce_loss"]),
                               float(stats_by_n[4]["ce_loss"]), rtol=1e-4)
    assert float(stats_by_n[1]["ntok"]) == float(stats_by_n[4]["ntok"])


def test_grad_accumulation_equals_full_batch(tiny_cfg):
    """n_micro=4 accumulated gradient == single full-batch gradient
    (the optimizer sees the SAME global-batch gradient, Algorithm 1)."""
    params = materialize(model_defs(tiny_cfg), jax.random.PRNGKey(0))
    data = SyntheticLM(tiny_cfg.vocab_size, 32, 8, branching=4)
    batch = data.batch_at(0)
    outs = []
    for n_micro in (1, 4):
        opt = sngm(poly_power(0.1, 10, 1.1), beta=0.9)
        step = jax.jit(make_train_step(tiny_cfg, CPU_RUNTIME, opt,
                                       n_micro=n_micro))
        ts, stats = step(opt.init_state(params), batch)
        outs.append((ts.params_view, float(stats["grad_norm"])))
    (pa, ga), (pb, gb) = outs
    assert abs(ga - gb) < 1e-3 * max(ga, 1.0)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flat_accumulation_bitwise_and_donation_stable(tiny_cfg):
    """The flat gradient accumulator (resident FlatOptState + n_micro>1:
    each micro-gradient packs into the dtype-bucketed buffers inside the
    scan, and the optimizer gets pre-packed FlatGrads) must be BITWISE
    the tree-accumulating jnp path — packing is a pure reshape/pad/concat
    at the bucket dtype — and bitwise stable under state donation (the
    launcher's production configuration)."""
    params = materialize(model_defs(tiny_cfg), jax.random.PRNGKey(0))
    data = SyntheticLM(tiny_cfg.vocab_size, 32, 8, branching=4)

    def run(fused, donate, steps=3, n_micro=4):
        opt = sngm(poly_power(0.5, 10, 1.1), beta=0.9, fused=fused)
        state = opt.init_state(params)
        step = make_train_step(tiny_cfg, CPU_RUNTIME, opt, n_micro=n_micro)
        step = (jax.jit(step, donate_argnums=(0,)) if donate
                else jax.jit(step))
        stats = None
        for t in range(steps):
            state, stats = step(state, data.batch_at(t))
        return state.params_view, stats

    p_tree, s_tree = run(fused=None, donate=False)
    p_flat, s_flat = run(fused="multi_tensor", donate=False)
    p_flat_d, s_flat_d = run(fused="multi_tensor", donate=True)
    for ref, got in ((p_tree, p_flat), (p_flat, p_flat_d)):
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert bool(jnp.array_equal(a, b))
    assert float(s_tree["grad_norm"]) == float(s_flat["grad_norm"]) \
        == float(s_flat_d["grad_norm"])


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_lm_deterministic():
    d1 = SyntheticLM(128, 16, 4, seed=7)
    d2 = SyntheticLM(128, 16, 4, seed=7)
    np.testing.assert_array_equal(np.asarray(d1.batch_at(3)["tokens"]),
                                  np.asarray(d2.batch_at(3)["tokens"]))
    assert not np.array_equal(np.asarray(d1.batch_at(3)["tokens"]),
                              np.asarray(d1.batch_at(4)["tokens"]))


def test_synthetic_lm_is_learnable_chain():
    d = SyntheticLM(64, 16, 4, branching=4, seed=0)
    toks = np.asarray(d.batch_at(0)["tokens"])
    table = np.asarray(d.table)
    for b in range(toks.shape[0]):
        for t in range(toks.shape[1] - 1):
            assert toks[b, t + 1] in table[toks[b, t]]


def test_synthetic_images_class_structure():
    x, y = synthetic_images(256, seed=0)
    assert x.shape == (256, 32, 32, 3)
    yn = np.asarray(y)
    x0 = np.asarray(x[yn == 0])
    x1 = np.asarray(x[yn == 1])
    if len(x0) > 1 and len(x1) > 0:
        d_in = np.linalg.norm(x0[0] - x0[1])
        d_out = np.linalg.norm(x0[0] - x1[0])
        assert d_in < d_out * 1.5


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    params = materialize(model_defs(tiny_cfg), jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path / "ck"), {"params": params}, step=17)
    restored, step = load_checkpoint(str(tmp_path / "ck"), {"params": params})
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_launcher_save_resume_loss_continuity(tmp_path):
    """End-to-end --resume: a 12-step run must equal 6 steps + save +
    resume for 6 more — including across STATE FORMS (a FlatOptState
    checkpoint resumed on the jnp path), since poly_power picks up at the
    restored t and the engine paths are bit-identical."""
    from repro.launch.train import main as train_main

    def run(extra):
        return train_main(
            ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq", "16",
             "--n-micro", "2", "--optimizer", "sngm", "--fused",
             "multi_tensor", "--lr", "0.5", "--total-steps", "12",
             "--log-every", "100"] + extra)

    full = run(["--steps", "12"])
    part1 = run(["--steps", "6", "--ckpt", str(tmp_path / "ck1")])
    part1b = run(["--steps", "6", "--ckpt", str(tmp_path / "ck2")])
    np.testing.assert_allclose(part1, full[:6], rtol=1e-6)
    np.testing.assert_allclose(part1b, part1, rtol=0)   # deterministic

    resumed = run(["--steps", "12", "--ckpt", str(tmp_path / "ck1"),
                   "--resume"])
    assert len(resumed) == 6
    np.testing.assert_allclose(resumed, full[6:], rtol=1e-5, atol=1e-6)

    # cross-form resume: FlatOptState checkpoint -> jnp (OptState) run
    resumed_jnp = run(["--steps", "12", "--ckpt", str(tmp_path / "ck2"),
                       "--resume", "--fused", "none"])
    np.testing.assert_allclose(resumed_jnp, full[6:], rtol=1e-4, atol=1e-5)


def test_lamb_fused_save_resume_loss_continuity(tmp_path):
    """--resume continuity for FUSED lamb: the Adam-moment flat slots
    survive the checkpoint (saved in ChainOptState pytree form, rebuilt
    resident on restore), so 6 + save/resume + 6 equals an uninterrupted
    12-step run — including resuming onto the interpreter path
    (--fused none), since fused lamb is bit-identical to it."""
    from repro.launch.train import main as train_main

    def run(extra):
        return train_main(
            ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq", "16",
             "--n-micro", "2", "--optimizer", "lamb", "--fused",
             "multi_tensor", "--lr", "0.05", "--weight-decay", "1e-4",
             "--total-steps", "12", "--log-every", "100"] + extra)

    full = run(["--steps", "12"])
    part1 = run(["--steps", "6", "--ckpt", str(tmp_path / "ck1")])
    part1b = run(["--steps", "6", "--ckpt", str(tmp_path / "ck2")])
    np.testing.assert_allclose(part1, full[:6], rtol=1e-6)
    np.testing.assert_allclose(part1b, part1, rtol=0)   # deterministic

    resumed = run(["--steps", "12", "--ckpt", str(tmp_path / "ck1"),
                   "--resume"])
    assert len(resumed) == 6
    np.testing.assert_allclose(resumed, full[6:], rtol=1e-5, atol=1e-6)

    # cross-form resume: ChainOptState checkpoint -> interpreter run
    resumed_interp = run(["--steps", "12", "--ckpt", str(tmp_path / "ck2"),
                          "--resume", "--fused", "none"])
    np.testing.assert_allclose(resumed_interp, full[6:], rtol=1e-4,
                               atol=1e-5)


def test_segment_plan_save_resume_loss_continuity(tmp_path):
    """--resume continuity for a SEGMENT-COMPILED chain (nesterov sngm
    with a resident EMA slot — no whole-chain match, the plan executor
    runs it): the ("chain", slots) FlatOptState is saved in ChainOptState
    pytree form and rebuilt resident on restore, so 6 + save/resume + 6
    equals an uninterrupted 12-step run — and the same checkpoint also
    resumes onto the jnp interpreter (--fused none), the fused->interp
    cross-form continuity the compiler's tolerance policy promises."""
    from repro.launch.train import main as train_main

    def run(extra):
        return train_main(
            ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq", "16",
             "--n-micro", "2", "--optimizer", "sngm", "--fused",
             "multi_tensor", "--lr", "0.5", "--nesterov", "--ema-decay",
             "0.999", "--total-steps", "12", "--log-every", "100"] + extra)

    full = run(["--steps", "12"])
    part1 = run(["--steps", "6", "--ckpt", str(tmp_path / "ck1")])
    part1b = run(["--steps", "6", "--ckpt", str(tmp_path / "ck2")])
    np.testing.assert_allclose(part1, full[:6], rtol=1e-6)
    np.testing.assert_allclose(part1b, part1, rtol=0)   # deterministic

    resumed = run(["--steps", "12", "--ckpt", str(tmp_path / "ck1"),
                   "--resume"])
    assert len(resumed) == 6
    np.testing.assert_allclose(resumed, full[6:], rtol=1e-5, atol=1e-6)

    # cross-form resume: segment-plan checkpoint -> interpreter run
    resumed_interp = run(["--steps", "12", "--ckpt", str(tmp_path / "ck2"),
                          "--resume", "--fused", "none"])
    np.testing.assert_allclose(resumed_interp, full[6:], rtol=1e-4,
                               atol=1e-5)


def test_optimizer_spec_round_trips_through_resume(tmp_path):
    """The OptimizerSpec saved in train_meta.json is the optimizer's
    identity: --resume reconstructs from it (conflicting CLI hyperparams
    are ignored), and the resumed steps are bit-identical to the
    uninterrupted run."""
    import json
    from repro.launch.train import main as train_main

    base = ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq", "16",
            "--n-micro", "2", "--total-steps", "12", "--log-every", "100"]

    full = train_main(base + ["--steps", "12", "--optimizer", "sngm",
                              "--lr", "0.5", "--weight-decay", "1e-3"])
    train_main(base + ["--steps", "6", "--optimizer", "sngm", "--lr", "0.5",
                       "--weight-decay", "1e-3",
                       "--ckpt", str(tmp_path / "ck")])

    meta = json.load(open(tmp_path / "ck" / "train_meta.json"))
    spec = meta["optimizer_spec"]
    assert spec["name"] == "sngm"
    assert spec["kwargs"]["weight_decay"] == pytest.approx(1e-3)
    assert spec["kwargs"]["schedule"] == {
        "name": "poly_power",
        "kwargs": {"lr0": 0.5, "total_steps": 12, "power": 1.1}}

    # resume with WRONG CLI hyperparams: the saved spec must win
    resumed = train_main(base + ["--steps", "12", "--lr", "999.0",
                                 "--weight-decay", "0.7",
                                 "--ckpt", str(tmp_path / "ck"), "--resume"])
    assert len(resumed) == 6
    np.testing.assert_array_equal(np.asarray(resumed),
                                  np.asarray(full[6:]))


def test_train_state_save_resume_continuity(tmp_path, tiny_cfg):
    """Save→resume THROUGH the donated TrainState, resident path: the
    launcher persists {params_view, to_pytree(opt_state)} from the live
    state; rebuilding a TrainState from the restored forms and continuing
    (donated) matches an uninterrupted donated run bitwise."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.core import to_pytree, from_pytree
    from repro.core.optim import TrainState
    from repro.core.schedules import poly_power as pp

    def mk_opt():
        return sngm(pp(0.5, 8, 1.1), beta=0.9, weight_decay=1e-4,
                    fused="multi_tensor")

    def fresh():
        return materialize(model_defs(tiny_cfg), jax.random.PRNGKey(0))

    data = SyntheticLM(tiny_cfg.vocab_size, 32, 8, branching=4)
    opt = mk_opt()
    step = jax.jit(make_train_step(tiny_cfg, CPU_RUNTIME, opt, n_micro=2),
                   donate_argnums=(0,))

    # uninterrupted 8-step donated run
    ts_full = opt.init_state(fresh())
    for t in range(8):
        ts_full, _ = step(ts_full, data.batch_at(t))

    # 4 steps, checkpoint from the LIVE TrainState, rebuild, 4 more
    ts = opt.init_state(fresh())
    for t in range(4):
        ts, _ = step(ts, data.batch_at(t))
    assert ts.params is None          # resident: flats own the params
    save_checkpoint(str(tmp_path / "ck"),
                    {"params": ts.params_view,
                     "opt": to_pytree(ts.opt_state)}, step=4)

    like = {"params": fresh(), "opt": to_pytree(mk_opt().init(fresh()))}
    restored, t0 = load_checkpoint(str(tmp_path / "ck"), like)
    assert t0 == 4
    ts2 = TrainState(params=None,
                     opt_state=from_pytree(restored["opt"],
                                           restored["params"]))
    for t in range(4, 8):
        ts2, _ = step(ts2, data.batch_at(t))

    for a, b in zip(jax.tree.leaves(ts_full), jax.tree.leaves(ts2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_rejects_torn_checkpoint(tmp_path):
    """A torn checkpoint directory (no COMMIT marker AND no complete
    legacy meta/shard pair — what an interrupted legacy-writer save
    leaves) must be refused by --resume rather than half-loaded; a
    markerless-but-complete legacy checkpoint still resumes."""
    import os
    from repro.launch.train import main as train_main

    args = ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq", "16",
            "--n-micro", "2", "--optimizer", "sngm", "--lr", "0.5",
            "--total-steps", "8", "--log-every", "100"]
    train_main(args + ["--steps", "4", "--ckpt", str(tmp_path / "ck")])
    # markerless but complete == pre-marker legacy save: must resume
    os.remove(tmp_path / "ck" / "COMMIT")
    legacy = train_main(args + ["--steps", "8", "--ckpt",
                                str(tmp_path / "ck"), "--resume"])
    assert len(legacy) == 4
    # torn: no marker AND the meta sidecar never landed
    os.remove(tmp_path / "ck" / "COMMIT")
    os.remove(tmp_path / "ck" / "meta.json")
    with pytest.raises(SystemExit, match="COMMIT"):
        train_main(args + ["--steps", "8", "--ckpt", str(tmp_path / "ck"),
                           "--resume"])
