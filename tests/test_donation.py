"""Donation-safety of the unified ``TrainState``.

The guarantees under test:
  * donated (``donate_argnums``) and undonated optimizer steps produce
    BIT-identical results for every fused kind (sngm global/per-tensor,
    msgd, lars, fused lamb, clip-prefixed sngm), fp32 and bf16 — the
    in-place ``input_output_aliases`` on the kernels and XLA's buffer
    reuse must never change numerics;
  * the resident ``TrainState`` holds ~1x parameter bytes (the flat
    buffers are the single owner; no duplicate pytree copy), and the
    compiled donated step actually aliases the state (memory_analysis);
  * executing a donated step emits no "donated buffer" warnings — every
    donated buffer is consumed;
  * the full (model fwd/bwd + optimizer) donated train step matches the
    undonated one.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile_chain, lamb, lars, msgd, sngm
from repro.core import transform as T
from repro.core.multi_tensor import FlatOptState
from repro.core.optim import TrainState, init_train_state
from repro.core.schedules import constant

KEY = jax.random.PRNGKey(0)
SHAPES = [(300, 17), (1025,), (), (4,), (2000,), (64, 64), (1024,)]


def make_tree(seed, dtype=jnp.float32, scale=1.0):
    k = jax.random.fold_in(KEY, seed)
    return {f"p{i}": (scale * jax.random.normal(jax.random.fold_in(k, i), s)
                      ).astype(dtype)
            for i, s in enumerate(SHAPES)}


def _clip_sngm(**kw):
    tx = T.chain(T.clip_by_global_norm(1.0), T.add_decayed_weights(1e-4),
                 T.normalize_by_global_norm(), T.trace(0.9),
                 T.scale_by_schedule(constant(0.3)))
    return compile_chain(tx, **kw)


OPTIMIZERS = {
    "sngm": lambda **kw: sngm(constant(0.3), beta=0.9, weight_decay=1e-4,
                              **kw),
    "sngm_per_tensor": lambda **kw: sngm(constant(0.3), beta=0.9,
                                         norm_mode="per_tensor", **kw),
    "msgd": lambda **kw: msgd(constant(0.3), beta=0.9, weight_decay=1e-4,
                              **kw),
    "lars": lambda **kw: lars(constant(0.3), beta=0.9, weight_decay=1e-4,
                              **kw),
    "lamb": lambda **kw: lamb(constant(0.05), weight_decay=1e-4, **kw),
    "clip_sngm": _clip_sngm,
}


def tree_bitwise_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_donated_step_bitwise_equal_to_undonated(name, dtype):
    """The acceptance bar: donating the TrainState through jit (which
    lets XLA take the kernels' input_output_aliases in place) is
    bit-identical to the copy-on-write undonated path, every fused kind,
    fp32 and bf16, multi-step."""
    opt = OPTIMIZERS[name](fused="multi_tensor")
    grads = make_tree(1, dtype, scale=3.0)
    # DISJOINT param copies: a donated buffer is deleted after the call,
    # so the two runs must not share leaves
    ts_d = opt.init_state(make_tree(0, dtype))
    ts_u = opt.init_state(make_tree(0, dtype))
    assert isinstance(ts_d.opt_state, FlatOptState)
    assert ts_d.params is None            # flats are the single owner
    step_d = jax.jit(opt.step_state, donate_argnums=(1,))
    step_u = jax.jit(opt.step_state)
    for _ in range(3):
        ts_d, st_d = step_d(grads, ts_d)
        ts_u, st_u = step_u(grads, ts_u)
    assert tree_bitwise_equal(ts_d, ts_u)
    for k in st_d:
        assert bool(jnp.array_equal(st_d[k], st_u[k])), k
    # the gradients were NOT donated and stay usable
    assert not any(l.is_deleted() for l in jax.tree.leaves(grads))


def test_resident_state_holds_params_once_and_aliases():
    """Memory shape of the resident path: the TrainState's parameter
    bytes are ~1x the raw parameter bytes (chunk padding only, no
    duplicate pytree copy), and the compiled donated step aliases the
    whole state in place (memory_analysis.alias_size covers it).  Uses a
    model-sized tree so the fixed chunk/tile padding is the only (small)
    overhead — on the tiny shared tree padding would swamp the ratio."""
    k = jax.random.PRNGKey(7)
    big_shapes = [(1024, 1024), (1024, 1024), (513, 513), (2000,), (7,)]
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(k, i), s)
              for i, s in enumerate(big_shapes)}
    grads = {f"w{i}": 3.0 * jax.random.normal(jax.random.fold_in(k, 99 + i),
                                              s)
             for i, s in enumerate(big_shapes)}
    param_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(params))
    opt = OPTIMIZERS["sngm"](fused="multi_tensor")
    ts = opt.init_state(params)

    # single-owner invariant: parameter bytes in the state == p_flats once
    state_param_bytes = sum(f.size * f.dtype.itemsize
                            for f in ts.opt_state.p_flats)
    assert ts.params is None
    assert state_param_bytes < 1.05 * param_bytes, (
        state_param_bytes, param_bytes)   # ~1x: chunk padding only

    step = jax.jit(opt.step_state, donate_argnums=(1,))
    compiled = step.lower(grads, ts).compile()
    ma = compiled.memory_analysis()
    state_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(ts))
    # all donated state buffers must be aliased into the outputs
    assert ma.alias_size_in_bytes >= state_bytes, (
        ma.alias_size_in_bytes, state_bytes)


@pytest.mark.parametrize("name", ["sngm", "lamb"])
def test_donated_step_emits_no_donation_warnings(name):
    """Every donated buffer must actually be consumed: an 'unused
    donation' warning means the step re-materialized a copy somewhere
    and the in-place residency regressed."""
    opt = OPTIMIZERS[name](fused="multi_tensor")
    ts = opt.init_state(make_tree(0))
    grads = make_tree(1, scale=3.0)
    step = jax.jit(opt.step_state, donate_argnums=(1,))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ts, _ = step(grads, ts)
        jax.block_until_ready(ts)
    donation_warnings = [str(x.message) for x in w
                         if "donat" in str(x.message).lower()]
    assert donation_warnings == [], donation_warnings


def test_full_train_step_donated_matches_undonated():
    """End-to-end (model forward/backward + fused optimizer in ONE jit):
    the donated train step matches the undonated one.  sngm (the paper's
    optimizer) is bitwise; msgd is compared to the documented XLA-CPU
    interpret-mode tolerance (donation changes the whole-graph fusion
    context around the inlined kernels, which can flip last-ulp FMA
    contraction — bitwise on real TPU where kernels compile in
    isolation; same drift class as the clip-chain policy in README)."""
    import dataclasses
    from repro.configs import ARCHS, smoke_variant
    from repro.data import SyntheticLM
    from repro.models import CPU_RUNTIME, model_defs
    from repro.models.param import materialize
    from repro.training import make_train_step

    cfg = dataclasses.replace(smoke_variant(ARCHS["gemma-2b"]),
                              vocab_size=64, compute_dtype="float32")
    data = SyntheticLM(cfg.vocab_size, 16, 4, branching=4)

    def fresh():
        return materialize(model_defs(cfg), jax.random.PRNGKey(0))

    for name, bitwise in (("sngm", True), ("msgd", False)):
        opt = OPTIMIZERS[name](fused="multi_tensor")
        ts_d = opt.init_state(fresh())
        ts_u = opt.init_state(fresh())
        step_d = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2),
                         donate_argnums=(0,))
        step_u = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2))
        for t in range(2):
            ts_d, st_d = step_d(ts_d, data.batch_at(t))
            ts_u, st_u = step_u(ts_u, data.batch_at(t))
        assert float(st_d["loss"]) == pytest.approx(float(st_u["loss"]),
                                                    rel=1e-6)
        if bitwise:
            assert tree_bitwise_equal(ts_d, ts_u)
        else:
            for a, b in zip(jax.tree.leaves(ts_d), jax.tree.leaves(ts_u)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=5e-4, atol=1e-6)


def test_resident_state_fed_to_jnp_path_materializes():
    """Robustness: a TrainState whose params were dropped (resident) but
    whose optimizer runs a non-engine path materializes the view and
    continues in pytree form — still one live parameter copy."""
    from repro.core.optim import init_flat_state  # noqa: F401 (doc import)
    opt_fused = OPTIMIZERS["sngm"](fused="multi_tensor")
    opt_jnp = OPTIMIZERS["sngm"]()
    grads = make_tree(1, scale=3.0)
    ts = opt_fused.init_state(make_tree(0))       # resident, params=None
    ts2, _ = jax.jit(opt_jnp.step_state)(grads, ts)
    assert ts2.params is not None                 # pytree form now
    # numbers match the all-pytree route
    ts_ref = opt_jnp.init_state(make_tree(0))
    ts_ref, _ = jax.jit(opt_jnp.step_state)(grads, ts_ref)
    assert tree_bitwise_equal(ts2.params, ts_ref.params)
