"""Multi-tensor fused optimizer engine (core/multi_tensor + kernels/multi_tensor).

The headline guarantees under test:
  * flatten/unflatten is a lossless round trip for any pytree;
  * the fused path is BIT-identical to the pure-jnp optimizer paths
    (params, momentum, and stats) for sngm / sngm[per_tensor] / msgd /
    lars, fp32 and bf16, across multiple steps — and with fused init now
    returning a flat-buffer-resident FlatOptState, those asserts cover
    the RESIDENT path;
  * the resident path is bit-identical to the per-step (OptState) fused
    path and packs only gradient-sized buffers in steady state;
  * per-segment norms from the single reduction pass match
    jnp.linalg.norm per tensor;
  * the engine issues O(1) kernel launches per step vs O(n_leaves) for
    the per-leaf path.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lars, msgd, sngm
from repro.core.multi_tensor import (
    CHUNK, FlatOptState, build_layout, count_packed_bytes, flatten,
    init_flat_state, leaf_sumsq, multi_tensor_step, unflatten,
    _fold_sum, _segment_sums)
from repro.core.optim import OptState, from_pytree, to_pytree
from repro.core.schedules import constant
from repro.kernels import count_pallas_launches
from repro.kernels.multi_tensor import ops as mt_ops
from repro.kernels.multi_tensor import ref as mt_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)

# odd sizes, scalars, exact chunk multiples, one-past-chunk, >1 tile
SHAPES = [(300, 17), (1025,), (), (4,), (2000,), (64, 64), (3, 5, 7), (1024,)]


def make_tree(seed, dtype=jnp.float32, scale=1.0, shapes=SHAPES):
    k = jax.random.fold_in(KEY, seed)
    return {f"p{i}": (scale * jax.random.normal(jax.random.fold_in(k, i), s)
                      ).astype(dtype)
            for i, s in enumerate(shapes)}


def tree_bitwise_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# flatten / unflatten round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flatten_unflatten_roundtrip(dtype):
    tree = make_tree(0, dtype)
    layout = build_layout(tree)
    assert tree_bitwise_equal(unflatten(flatten(tree, layout), layout), tree)


def test_roundtrip_mixed_dtypes():
    tree = make_tree(1)
    tree.update({f"b{i}": v.astype(jnp.bfloat16)
                 for i, v in enumerate(make_tree(2).values())})
    layout = build_layout(tree)
    assert len(layout.buckets) == 2
    assert tree_bitwise_equal(unflatten(flatten(tree, layout), layout), tree)
    # momentum convention: f32 buffers regardless of param dtype
    mom = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), tree)
    flats = flatten(mom, layout, cast_to=jnp.float32)
    assert all(f.dtype == jnp.float32 for f in flats)
    assert tree_bitwise_equal(unflatten(flats, layout, keep_dtype=True), mom)


def test_layout_segments_chunk_aligned():
    layout = build_layout(make_tree(0))
    for b in layout.buckets:
        assert b.n_elems % CHUNK == 0
        for s in b.segments:
            assert s.offset % CHUNK == 0
            assert s.chunk_hi * CHUNK >= s.offset + s.size


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(shapes=st.lists(
        st.lists(st.integers(1, 40), min_size=0, max_size=3), min_size=1,
        max_size=6),
        bf16_mask=st.integers(0, 63))
    def test_roundtrip_property(shapes, bf16_mask):
        """Any tree of shapes/dtypes survives flatten->unflatten bitwise."""
        tree = {
            f"p{i}": (jax.random.normal(jax.random.fold_in(KEY, i + 1),
                                        tuple(s))
                      .astype(jnp.bfloat16 if (bf16_mask >> i) & 1
                              else jnp.float32))
            for i, s in enumerate(shapes)}
        layout = build_layout(tree)
        assert tree_bitwise_equal(unflatten(flatten(tree, layout), layout),
                                  tree)


# ---------------------------------------------------------------------------
# norms: fold_sum, segment sums, kernel vs ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 3, 64, 129])
def test_fold_sum_matches_numpy(n):
    x = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
    np.testing.assert_allclose(float(_fold_sum(x)), float(np.sum(np.asarray(x), dtype=np.float64)),
                               rtol=1e-6)


def test_segment_norms_match_linalg():
    """One reduction pass over the flat buffer == per-tensor jnp.linalg.norm."""
    tree = make_tree(3, scale=2.5)
    layout = build_layout(tree)
    (flat,) = flatten(tree, layout)
    parts = mt_ops.chunk_sumsq(flat)
    leaves = jax.tree_util.tree_leaves(tree)
    for b in layout.buckets:
        for s, sq in zip(b.segments, _segment_sums(parts, b)):
            ref = jnp.linalg.norm(leaves[s.index].astype(jnp.float32).ravel())
            np.testing.assert_allclose(float(jnp.sqrt(sq)), float(ref),
                                       rtol=1e-6)
            # and bit-identical to the canonical chunked leaf reduction
            assert bool(jnp.array_equal(sq, leaf_sumsq(leaves[s.index])))


# NB: the ref side is jitted because bitwise parity requires the same
# compilation context — eager op-by-op execution skips the FMA contraction
# XLA applies inside a jit, which moves the last ulp.

@pytest.mark.parametrize("wd", [0.0, 1e-4])
def test_chunk_sumsq_kernel_matches_ref(wd):
    layout = build_layout(make_tree(4))
    (g,) = flatten(make_tree(5, scale=3.0), layout)
    (p,) = flatten(make_tree(4), layout)
    out_k = mt_ops.chunk_sumsq(g, p, wd=wd)                 # pallas interpret
    out_r = jax.jit(partial(mt_ref.chunk_sumsq_ref, wd=wd))(g, p)
    assert bool(jnp.array_equal(out_k, out_r))


@pytest.mark.parametrize("cast_g_first", [False, True])
def test_fused_update_kernel_matches_ref(cast_g_first):
    layout = build_layout(make_tree(4))
    (p,) = flatten(make_tree(4), layout)
    (g,) = flatten(make_tree(5, scale=3.0), layout)
    (u,) = flatten(make_tree(6), layout, cast_to=jnp.float32)
    a = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 9),
                                  (p.size // CHUNK,)))
    c = jnp.float32(0.7)
    outs_k = mt_ops.fused_update(p, g, u, a, c, beta=0.9, wd=1e-4,
                                 cast_g_first=cast_g_first)
    outs_r = jax.jit(partial(mt_ref.fused_update_ref, beta=0.9, wd=1e-4,
                             cast_g_first=cast_g_first))(p, g, u, a, c)
    for k, r in zip(outs_k, outs_r):
        assert bool(jnp.array_equal(k, r)) and k.dtype == r.dtype


@pytest.mark.parametrize("wd", [0.0, 1e-4])
def test_adam_update_kernel_matches_ref(wd):
    """The LAMB Adam-moment pass: Pallas (interpret) == jnp oracle,
    bitwise, for all six outputs (moments, direction, three partial sets),
    at the extreme t=1 bias correction."""
    layout = build_layout(make_tree(4))
    (p,) = flatten(make_tree(4), layout)
    (g,) = flatten(make_tree(5, scale=3.0), layout)
    (m,) = flatten(make_tree(6), layout, cast_to=jnp.float32)
    (v,) = flatten(jax.tree.map(jnp.abs, make_tree(7, scale=0.1)), layout,
                   cast_to=jnp.float32)
    bc1, bc2 = jnp.float32(1 - 0.9), jnp.float32(1 - 0.999)   # t = 1
    outs_k = mt_ops.adam_update(p, g, m, v, bc1, bc2, b1=0.9, b2=0.999,
                                eps=1e-6, wd=wd)
    outs_r = jax.jit(partial(mt_ref.adam_update_ref, b1=0.9, b2=0.999,
                             eps=1e-6, wd=wd))(p, g, m, v, bc1, bc2)
    for k, r in zip(outs_k, outs_r):
        assert bool(jnp.array_equal(k, r)) and k.dtype == r.dtype


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lane_pad_bitwise_identical(dtype):
    """The real-TPU lane-width padding flag (coefficient/partial blocks
    widened from (rows, 1) to (rows, 128)) must not change a single bit:
    the coefficient is lane-replicated on the host, partials are
    broadcast-stored and lane 0 sliced back out."""
    from repro.kernels.multi_tensor import kernel as mt_kernel
    layout = build_layout(make_tree(4, dtype))
    (p,) = flatten(make_tree(4, dtype), layout)
    (g,) = flatten(make_tree(5, dtype, scale=3.0), layout)
    (u,) = flatten(make_tree(6), layout, cast_to=jnp.float32)
    (m,) = flatten(make_tree(7), layout, cast_to=jnp.float32)
    (v,) = flatten(jax.tree.map(jnp.abs, make_tree(8, scale=0.1)), layout,
                   cast_to=jnp.float32)
    a = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 13),
                                  (p.size // CHUNK,)))
    c = jnp.float32(0.7)
    bc1, bc2 = jnp.float32(1 - 0.9), jnp.float32(1 - 0.999)
    outs = {}
    for lp in (False, True):
        kw = dict(interpret=True, lane_pad=lp)
        outs[lp] = (
            (mt_kernel.chunk_sumsq(g, p, wd=1e-4, **kw),)
            + mt_kernel.fused_update(p, g, u, a, c, beta=0.9, wd=1e-4, **kw)
            + mt_kernel.adam_update(p, g.astype(dtype), m, v, bc1, bc2,
                                    b1=0.9, b2=0.999, eps=1e-6, wd=1e-4,
                                    **kw)
            + mt_kernel.scale_apply(p, u, a, c, **kw))
    for off, on in zip(outs[False], outs[True]):
        assert bool(jnp.array_equal(off, on)) and off.dtype == on.dtype


def test_lane_pad_env_default(monkeypatch):
    from repro.kernels.multi_tensor import kernel as mt_kernel
    monkeypatch.delenv("REPRO_MT_LANE_PAD", raising=False)
    assert mt_kernel._lane_pad_default() is False
    monkeypatch.setenv("REPRO_MT_LANE_PAD", "1")
    assert mt_kernel._lane_pad_default() is True
    monkeypatch.setenv("REPRO_MT_LANE_PAD", "0")
    assert mt_kernel._lane_pad_default() is False


def test_scale_apply_kernel_matches_ref():
    """The LAMB apply pass: Pallas (interpret) == jnp oracle, bitwise."""
    layout = build_layout(make_tree(4))
    (p,) = flatten(make_tree(4), layout)
    (g,) = flatten(make_tree(5, scale=0.5), layout, cast_to=jnp.float32)
    a = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 11),
                                  (p.size // CHUNK,)))
    outs_k = mt_ops.scale_apply(p, g, a, jnp.float32(0.7))
    outs_r = jax.jit(mt_ref.scale_apply_ref)(p, g, a, jnp.float32(0.7))
    for k, r in zip(outs_k, outs_r):
        assert bool(jnp.array_equal(k, r)) and k.dtype == r.dtype


def test_adam_update_preserves_zero_padding():
    """Zero pads map to zero moments AND zero direction (eps > 0), the
    invariant that keeps the resident Adam buffers equal to re-flattened
    pytree views."""
    tree = {"w": jnp.ones((100,))}          # 924 pad elements in the chunk
    layout = build_layout(tree)
    (p,) = flatten(tree, layout)
    (g,) = flatten({"w": 2.0 * jnp.ones((100,))}, layout)
    z = jnp.zeros_like(p)
    mo, vo, ud, *_ = mt_ops.adam_update(p, g, z, z, jnp.float32(0.1),
                                        jnp.float32(0.001), b1=0.9,
                                        b2=0.999, eps=1e-6, wd=1e-4)
    for buf in (mo, vo, ud):
        assert bool(jnp.array_equal(buf[100:], jnp.zeros((buf.size - 100,))))


# ---------------------------------------------------------------------------
# numerics equality: multi-tensor vs per-leaf vs pure jnp
# ---------------------------------------------------------------------------

OPTIMIZERS = {
    "sngm": lambda **kw: sngm(constant(0.3), beta=0.9, weight_decay=1e-4, **kw),
    "sngm_wd0": lambda **kw: sngm(constant(0.3), beta=0.9, **kw),
    "sngm_per_tensor": lambda **kw: sngm(constant(0.3), beta=0.9,
                                         weight_decay=1e-4,
                                         norm_mode="per_tensor", **kw),
    "msgd": lambda **kw: msgd(constant(0.3), beta=0.9, weight_decay=1e-4, **kw),
    "lars": lambda **kw: lars(constant(0.3), beta=0.9, weight_decay=1e-4, **kw),
}


def _run_steps(opt, params, grads, n=2):
    state = opt.init(params)
    step = jax.jit(opt.step)
    stats = None
    for _ in range(n):
        params, state, stats = step(grads, state, params)
    return params, state, stats


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_multi_tensor_bit_identical_to_jnp(name, dtype):
    """The acceptance bar: fused engine == jnp path, bitwise, every output."""
    params = make_tree(0, dtype)
    grads = make_tree(1, dtype, scale=3.0)
    p_r, s_r, st_r = _run_steps(OPTIMIZERS[name](), params, grads)
    p_m, s_m, st_m = _run_steps(OPTIMIZERS[name](fused="multi_tensor"),
                                params, grads)
    assert tree_bitwise_equal(p_r, p_m)
    assert tree_bitwise_equal(s_r.momentum, s_m.momentum)
    for k in st_r:
        assert bool(jnp.array_equal(st_r[k], st_m[k])), k


def test_use_pallas_routes_to_multi_tensor_bit_identical():
    """sngm(use_pallas=True) now IS the multi-tensor engine."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    p_r, s_r, _ = _run_steps(OPTIMIZERS["sngm"](), params, grads)
    p_p, s_p, _ = _run_steps(OPTIMIZERS["sngm"](use_pallas=True),
                             params, grads)
    assert tree_bitwise_equal(p_r, p_p)
    assert tree_bitwise_equal(s_r.momentum, s_p.momentum)


@pytest.mark.slow
def test_multi_tensor_matches_per_leaf_kernels():
    """Engine == the original one-kernel-per-tensor path (sngm and lars)."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    for name in ("sngm", "lars"):
        p_l, s_l, _ = _run_steps(OPTIMIZERS[name](fused="per_leaf"),
                                 params, grads)
        p_m, s_m, _ = _run_steps(OPTIMIZERS[name](fused="multi_tensor"),
                                 params, grads)
        for a, b in zip(jax.tree.leaves(p_l), jax.tree.leaves(p_m)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-6)


def test_multi_tensor_mixed_dtype_tree():
    params = make_tree(0)
    params.update({f"b{i}": v.astype(jnp.bfloat16)
                   for i, v in enumerate(make_tree(2).values())})
    grads = jax.tree.map(
        lambda p: (3.0 * jax.random.normal(
            jax.random.fold_in(KEY, p.size), p.shape)).astype(p.dtype), params)
    p_r, s_r, st_r = _run_steps(OPTIMIZERS["sngm"](), params, grads)
    p_m, s_m, st_m = _run_steps(OPTIMIZERS["sngm"](fused="multi_tensor"),
                                params, grads)
    assert tree_bitwise_equal(p_r, p_m)
    assert tree_bitwise_equal(s_r.momentum, s_m.momentum)
    assert bool(jnp.array_equal(st_r["grad_norm"], st_m["grad_norm"]))


def test_multi_tensor_ref_backend_bit_identical():
    """backend='ref' (pure jnp oracle, zero pallas calls) == backend='pallas'."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    kw = dict(lr=jnp.float32(0.3), beta=0.9, weight_decay=1e-4)
    outs = {}
    for backend in ("pallas", "ref"):
        outs[backend] = jax.jit(
            lambda p, g, u: multi_tensor_step("sngm_global", p, g, u,
                                              backend=backend, **kw)
        )(params, grads, mom)
    (p_a, u_a, st_a), (p_b, u_b, st_b) = outs["pallas"], outs["ref"]
    assert tree_bitwise_equal(p_a, p_b) and tree_bitwise_equal(u_a, u_b)
    assert bool(jnp.array_equal(st_a["grad_norm"], st_b["grad_norm"]))


def test_multi_tensor_rejects_unknown_kind():
    params = make_tree(0)
    mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    with pytest.raises(ValueError):
        multi_tensor_step("adamw", params, params, mom, lr=0.1, beta=0.9)


def test_multi_tensor_rejects_grad_dtype_mismatch():
    """fp32 grads over bf16 params must fail loudly, not silently truncate
    to the bf16 bucket dtype (the jnp path promotes to f32 instead)."""
    params = make_tree(0, jnp.bfloat16)
    grads = make_tree(1, jnp.float32, scale=3.0)
    mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    with pytest.raises(ValueError, match="match the parameter dtype"):
        multi_tensor_step("sngm_global", params, grads, mom, lr=0.1, beta=0.9)


# ---------------------------------------------------------------------------
# flat-buffer residency: FlatOptState vs per-step path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_resident_state_bit_identical_to_per_step(name, dtype):
    """FlatOptState (flatten grads only, buffers carried across steps)
    == OptState into the same fused optimizer (re-pack p+g+u each step),
    bitwise, for every optimizer kind, fp32 and bf16, multi-step."""
    params = make_tree(0, dtype)
    grads = make_tree(1, dtype, scale=3.0)
    opt = OPTIMIZERS[name](fused="multi_tensor")
    s_flat = opt.init(params)
    assert isinstance(s_flat, FlatOptState)
    s_tree = to_pytree(s_flat)
    assert isinstance(s_tree, OptState)
    step = jax.jit(opt.step)
    pf, pt = params, params
    for _ in range(3):
        pf, s_flat, st_f = step(grads, s_flat, pf)
        pt, s_tree, st_t = step(grads, s_tree, pt)
    assert isinstance(s_flat, FlatOptState) and isinstance(s_tree, OptState)
    assert tree_bitwise_equal(pf, pt)
    assert tree_bitwise_equal(s_flat.momentum, s_tree.momentum)
    for k in st_f:
        assert bool(jnp.array_equal(st_f[k], st_t[k])), k


def test_resident_params_view_matches_loop_params():
    """state.p_flats are authoritative; the pytree view handed back for
    loss_fn must stay bit-equal to them every step."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    opt = OPTIMIZERS["sngm"](fused="multi_tensor")
    state = opt.init(params)
    step = jax.jit(opt.step)
    for _ in range(2):
        params, state, _ = step(grads, state, params)
        assert tree_bitwise_equal(params, state.params)


def test_state_form_conversion_lossless():
    """to_pytree / from_pytree round-trip bitwise (incl. zero padding),
    on a mixed fp32+bf16 tree with non-zero momentum."""
    params = make_tree(0)
    params.update({f"b{i}": v.astype(jnp.bfloat16)
                   for i, v in enumerate(make_tree(2).values())})
    grads = jax.tree.map(
        lambda p: (3.0 * jax.random.normal(
            jax.random.fold_in(KEY, p.size), p.shape)).astype(p.dtype), params)
    opt = OPTIMIZERS["sngm"](fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    back = from_pytree(to_pytree(state), params)
    assert back.layout == state.layout
    assert tree_bitwise_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert tree_bitwise_equal(tuple(back.u_flats), tuple(state.u_flats))


def test_flat_state_accepted_by_jnp_path():
    """State-form dispatch: a FlatOptState fed to the pure-jnp optimizer
    materializes its momentum view and produces the same numbers."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    opt_jnp = OPTIMIZERS["sngm"]()
    flat = init_flat_state(params)
    p_a, s_a, _ = jax.jit(opt_jnp.step)(grads, flat, params)
    p_b, s_b, _ = jax.jit(opt_jnp.step)(grads, opt_jnp.init(params), params)
    assert isinstance(s_a, OptState)
    assert tree_bitwise_equal(p_a, p_b)
    assert tree_bitwise_equal(s_a.momentum, s_b.momentum)


def test_resident_path_packs_only_gradients():
    """The residency win: steady-state steps pack gradient-sized buffers
    only — exactly 1/3 of the per-step path on an all-fp32 tree."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    opt = OPTIMIZERS["sngm"](fused="multi_tensor")
    s_flat = opt.init(params)
    s_tree = to_pytree(s_flat)

    def packed(state):
        with count_packed_bytes() as c:
            # fresh lambda: a cached jit would skip tracing and recording
            jax.jit(lambda g, s, p: opt.step(g, s, p)).lower(
                grads, state, params)
        return c["bytes"]

    n_bytes = sum(b.n_elems * 4 for b in s_flat.layout.buckets)
    assert packed(s_flat) == n_bytes           # grads only
    assert packed(s_tree) == 3 * n_bytes       # params + grads + momentum


def test_resident_rejects_grad_dtype_mismatch():
    params = make_tree(0, jnp.bfloat16)
    grads = make_tree(1, jnp.float32, scale=3.0)
    opt = OPTIMIZERS["sngm"](fused="multi_tensor")
    with pytest.raises(ValueError, match="match the parameter dtype"):
        opt.step(grads, opt.init(params), params)


# ---------------------------------------------------------------------------
# launch counts: the reason the engine exists
# ---------------------------------------------------------------------------

def _launches_per_step(opt, params, grads):
    state = opt.init(params)
    with count_pallas_launches() as c:
        jax.jit(opt.step).lower(grads, state, params)
    return c["launches"]


def test_engine_launches_O1_per_leaf_launches_On():
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    n_leaves = len(jax.tree.leaves(params))
    mt = _launches_per_step(OPTIMIZERS["sngm"](fused="multi_tensor"),
                            params, grads)
    pl = _launches_per_step(OPTIMIZERS["sngm"](fused="per_leaf"),
                            params, grads)
    # one norm pass + one update pass for the single f32 bucket
    assert mt == 2, mt
    assert pl == n_leaves, (pl, n_leaves)
    # lars: two raw-norm passes + one update pass per bucket
    assert _launches_per_step(OPTIMIZERS["lars"](fused="multi_tensor"),
                              params, grads) == 3
    # launches stay O(buckets) when the tree grows
    big = {f"x{i}": jnp.ones((65, 3)) for i in range(40)}
    gbig = {k: 2.0 * v for k, v in big.items()}
    assert _launches_per_step(OPTIMIZERS["sngm"](fused="multi_tensor"),
                              big, gbig) == 2


# ---------------------------------------------------------------------------
# shard-padded layouts + FlatGrads (fast lane for the distributed engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shard_padded_layout_roundtrip_and_norm(dtype):
    """A layout built for 4 shards (padding every bucket to a multiple of
    4 tiles) must round-trip and fold norms bitwise like the shards=1
    layout — shard padding is zeros and the canonical per-segment fold
    never sees it."""
    from repro.core.multi_tensor import flat_squared_norm, tree_squared_norm
    tree = make_tree(0, dtype)
    lo1 = build_layout(tree, shards=1)
    lo4 = build_layout(tree, shards=4)
    for b in lo4.buckets:
        assert b.n_elems % 4 == 0
    f1, f4 = flatten(tree, lo1), flatten(tree, lo4)
    assert tree_bitwise_equal(unflatten(f4, lo4), tree)
    n_ref = tree_squared_norm(tree)
    assert bool(jnp.array_equal(flat_squared_norm(f1, lo1), n_ref))
    assert bool(jnp.array_equal(flat_squared_norm(f4, lo4), n_ref))


@pytest.mark.parametrize("name", ["sngm_global", "msgd"])
def test_shard_padded_resident_state_bit_identical(name):
    """An optimizer stepping a shards=4 FlatOptState WITHOUT a mesh (the
    restored-on-fewer-devices fallback) is bitwise the shards=1 run."""
    import dataclasses

    from repro.core.multi_tensor import init_flat_state, resident_step

    params = make_tree(1)
    grads = [make_tree(10 + t, scale=3.0) for t in range(2)]
    kw = dict(lr=0.3, beta=0.9, weight_decay=1e-4)

    st1 = init_flat_state(params)
    st4 = init_flat_state(params)
    lo4 = build_layout(params, shards=4)
    st4 = FlatOptState(step=st4.step, p_flats=tuple(flatten(params, lo4)),
                       u_flats=tuple(jnp.zeros((b.n_elems,), jnp.float32)
                                     for b in lo4.buckets), layout=lo4)
    for g in grads:
        p1, st1, s1 = resident_step(name, g, st1, **kw)
        p4, st4, s4 = resident_step(name, g, st4, **kw)
        assert tree_bitwise_equal(p1, p4)
        for key in ("grad_norm", "update_norm"):
            if key in s1:
                assert bool(jnp.array_equal(s1[key], s4[key])), key


@pytest.mark.parametrize("name", ["sngm_global", "msgd"])
def test_flat_grads_input_bit_identical_to_tree(name):
    """Pre-packed FlatGrads (what the flat-accumulating train step hands
    the engine) must step bitwise like the same gradients as a pytree."""
    from repro.core.multi_tensor import FlatGrads, init_flat_state, \
        resident_step

    params = make_tree(2)
    kw = dict(lr=0.3, beta=0.9, weight_decay=1e-4)
    st_t = init_flat_state(params)
    st_f = init_flat_state(params)
    for t in range(2):
        g = make_tree(20 + t, scale=3.0)
        gf = FlatGrads(tuple(flatten(g, st_f.layout)), st_f.layout)
        p_t, st_t, s_t = resident_step(name, g, st_t, **kw)
        p_f, st_f, s_f = resident_step(name, gf, st_f, **kw)
        assert tree_bitwise_equal(p_t, p_f)
        for key in ("grad_norm", "update_norm"):
            if key in s_t:
                assert bool(jnp.array_equal(s_t[key], s_f[key])), key


def test_flat_grads_layout_mismatch_rejected():
    """FlatGrads packed against a different layout (wrong shard padding)
    must be rejected loudly, not silently mis-sliced."""
    from repro.core.multi_tensor import FlatGrads, init_flat_state, \
        resident_step

    params = make_tree(3)
    st = init_flat_state(params)                 # shards=1 layout
    lo4 = build_layout(params, shards=4)
    g = make_tree(30, scale=3.0)
    gf = FlatGrads(tuple(flatten(g, lo4)), lo4)
    with pytest.raises(ValueError, match="different TreeLayout"):
        resident_step("sngm_global", gf, st, lr=0.3, beta=0.9)
