"""Decode-path correctness: step-by-step decoding with a KV cache must
reproduce teacher-forced prefill logits exactly (up to numerics).

This exercises every cache type end-to-end:
  * full-attention k/v cache           (yi-9b)
  * sliding-window ring buffer         (gemma2 local layers / long-context)
  * MLA compressed cache + absorbed decode (deepseek-v2)
  * Mamba2 conv tail + SSM state       (mamba2, jamba)
  * whisper cross-attention cache
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.configs import ARCHS, smoke_variant
from repro.models import CPU_RUNTIME, forward, model_defs
from repro.models.param import materialize
from repro.serving.engine import pad_cache

CASES = ["yi-9b", "gemma2-27b", "deepseek-v2-lite-16b", "mamba2-1.3b",
         "jamba-1.5-large-398b", "whisper-large-v3", "chameleon-34b"]


def _setup(arch, long_ctx=False, dtype="float32"):
    # float32 compute: the test verifies ALGORITHMIC equivalence of the
    # cache paths; bf16 reassociation noise (e.g. absorbed-MLA) is checked
    # separately with a loose tolerance.  MoE capacity is raised so no
    # token drops: drop PATTERNS legitimately differ between a length-S+i
    # prefill and incremental decode (different total token counts).
    cfg = dataclasses.replace(smoke_variant(ARCHS[arch]), compute_dtype=dtype)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    if long_ctx:
        cfg = cfg.for_long_context()
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _consistency(cfg, params, B=2, S=24, n_extra=4, atol=3e-3):
    # 3e-3: SSD chunked-scan vs recurrent-decode reassociation is ~1e-3 in
    # f32 (mamba/jamba); attention-only paths agree to ~1e-6
    """prefill(t[:, :S]) then decode t[S], ... ; each decode step's logits
    must match prefill(t[:, :S+i+1]) last-position logits."""
    total = S + n_extra
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, total), 0,
                                cfg.vocab_size, jnp.int32)
    enc = (jax.random.normal(jax.random.PRNGKey(4),
                             (B, cfg.encoder_len, cfg.d_model))
           if cfg.is_encoder_decoder else None)

    logits, cache, _ = forward(params, cfg, CPU_RUNTIME, tokens[:, :S],
                               mode="prefill", encoder_embeds=enc)
    cache = pad_cache(cache, n_extra)
    for i in range(n_extra):
        pos = jnp.full((B,), S + i, jnp.int32)
        step_logits, cache, _ = forward(params, cfg, CPU_RUNTIME,
                                        tokens[:, S + i:S + i + 1],
                                        mode="decode", cache=cache, pos=pos)
        ref_logits, _, _ = forward(params, cfg, CPU_RUNTIME,
                                   tokens[:, :S + i + 1], mode="prefill",
                                   encoder_embeds=enc)
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(ref_logits[:, 0]),
                                   atol=atol, rtol=1e-2)


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_prefill(arch):
    cfg, params = _setup(arch)
    _consistency(cfg, params)


def test_decode_sliding_window_ring_buffer():
    """Long-context variant: windowed layers keep an O(W) ring buffer; the
    decode must still match teacher forcing while S+steps > window."""
    cfg, params = _setup("yi-9b", long_ctx=True)
    assert cfg.window == 64
    # prompt shorter than window, decode past nothing-dropped region is
    # covered above; here prompt+steps stays <= W so ring==full semantics
    _consistency(cfg, params, S=24, n_extra=4)


def test_ring_cache_rotation_equivalence():
    """Directly check ring_cache: prefill at S>W must keep exactly the
    last W positions, slot-addressed by pos %% W."""
    from repro.models import layers
    S, W = 13, 8
    k = jnp.arange(S, dtype=jnp.float32)[None, :, None, None]  # (1,S,1,1)
    out = layers.ring_cache({"k": k}, S, W)
    sp = np.asarray(out["slot_pos"][0])
    kv = np.asarray(out["k"][0, :, 0, 0])
    for slot in range(W):
        pos = sp[slot]
        assert pos >= S - W and pos % W == slot
        assert kv[slot] == float(pos)


def test_mla_absorbed_decode_equals_decompressed():
    """The MLA decode path (absorbed, latent-space attention) must agree
    with the train-path decompressed attention."""
    cfg, params = _setup("deepseek-v2-236b")  # q_lora path included
    _consistency(cfg, params, S=16, n_extra=3)


def test_decode_bf16_within_tolerance():
    """bf16 end-to-end decode stays within loose numeric tolerance of
    teacher forcing (reassociation noise only, no drift)."""
    cfg, params = _setup("deepseek-v2-lite-16b", dtype="bfloat16")
    _consistency(cfg, params, S=16, n_extra=2, atol=0.15)
