"""Multi-device integration (subprocess: 8 host devices).

Checks that the distributed execution paths — pjit with the production
sharding rules, expert-parallel all_to_all MoE, gradient accumulation —
produce the SAME numbers as single-device execution.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCHS, smoke_variant
    from repro.core import sngm
    from repro.core.schedules import constant
    from repro.models import model_defs, forward
    from repro.models.param import materialize
    from repro.models.runtime import Runtime, CPU_RUNTIME
    from repro.sharding import param_shardings, batch_spec
    from repro.training import make_train_step
    from repro.core.optim import OptState, TrainState

    # f32 so single- vs multi-device results are comparable tightly;
    # capacity_factor=16 so no token drops: EP computes capacity per shard,
    # so at low cf drop PATTERNS legitimately differ from single-device
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-v2-lite-16b"]),
                              compute_dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    B, S = 8, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "loss_mask": jnp.ones((B, S), jnp.float32)}

    opt = sngm(constant(0.01), beta=0.9, weight_decay=1e-4)

    # --- single device reference ---
    step_ref = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2))
    ts_ref, stats_ref = step_ref(opt.init_state(params), batch)

    # --- 4x2 mesh (data=4 with EP, model=2 TP) ---
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rt = Runtime(mesh=mesh, data_axes=("data",), remat=True)
    psh = param_shardings(defs, mesh)
    params_sharded = jax.device_put(params, psh)
    ts_sh = TrainState(params=psh,
                       opt_state=OptState(step=NamedSharding(mesh, P()),
                                          momentum=psh))
    step_dist = jax.jit(make_train_step(cfg, rt, opt, n_micro=2),
                        in_shardings=(ts_sh,
                                      {k: NamedSharding(mesh, batch_spec(mesh, v.ndim))
                                       for k, v in batch.items()}),
                        out_shardings=(ts_sh, None))
    ts_dist, stats_dist = step_dist(opt.init_state(params_sharded), batch)

    l1, l2 = float(stats_ref["loss"]), float(stats_dist["loss"])
    g1, g2 = float(stats_ref["grad_norm"]), float(stats_dist["grad_norm"])
    print("LOSS", l1, l2, "GNORM", g1, g2)
    assert abs(l1 - l2) < 1e-4 * max(1, abs(l1)), (l1, l2)
    assert abs(g1 - g2) < 1e-3 * max(1, abs(g1)), (g1, g2)
    # parameters agree after one update
    for a, b in zip(jax.tree.leaves(ts_ref.params_view),
                    jax.tree.leaves(ts_dist.params_view)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)),
                                   atol=5e-5)
    print("MULTIDEVICE-OK")
""")

MOE_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import MoEConfig, ModelConfig
    from repro.models import moe
    from repro.models.param import materialize
    from repro.models.runtime import Runtime, CPU_RUNTIME

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                      compute_dtype="float32",
                      moe=MoEConfig(n_experts=8, top_k=2, d_expert=64,
                                    capacity_factor=8.0))
    p = materialize(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64), jnp.float32)

    y_ref, aux_ref = moe.moe_ref(p, x, cfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rt = Runtime(mesh=mesh, data_axes=("data",))
    y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg, rt))(p, x)
    print("AUX", float(aux_ref), float(aux_ep))
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), atol=1e-4)

    # allreduce mode: batch=2 tokens, not divisible by data=4
    x2 = x[:2, :1]
    y_ref2, _ = moe.moe_ref(p, x2, cfg)
    y_ep2, _ = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg, rt))(p, x2)
    np.testing.assert_allclose(np.asarray(y_ep2), np.asarray(y_ref2), atol=1e-4)
    print("MOE-EP-OK")
""")


FUSED_OPT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sngm
    from repro.core.schedules import constant

    # a transformer-ish tree: 2D matrices shard over the mesh, 1D stay
    # replicated — the multi-tensor engine must give the same numbers as
    # the jnp path when the flat buffers are built from sharded leaves
    k = jax.random.PRNGKey(0)
    shapes = {"wq": (256, 128), "wk": (256, 128), "scale": (256,),
              "emb": (1000, 64), "bias": (7,)}
    params = {n: jax.random.normal(jax.random.fold_in(k, i), s)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    grads = {n: 3.0 * jax.random.normal(jax.random.fold_in(k, 100 + i), s)
             for i, (n, s) in enumerate(sorted(shapes.items()))}

    mesh = jax.make_mesh((8,), ("data",))
    shard = {n: NamedSharding(mesh, P("data") if len(s) == 2 else P())
             for n, s in shapes.items()}
    params_s = jax.device_put(params, shard)
    grads_s = jax.device_put(grads, shard)

    outs = {}
    for fused in (None, "multi_tensor"):
        opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4, fused=fused)
        state = opt.init(params_s)
        step = jax.jit(opt.step)
        p, s = params_s, state
        for _ in range(2):
            p, s, stats = step(grads_s, s, p)
        outs[fused] = (p, s, stats)
    (p_r, s_r, st_r), (p_m, s_m, st_m) = outs[None], outs["multi_tensor"]
    for a, b in zip(jax.tree.leaves(p_r), jax.tree.leaves(p_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    for a, b in zip(jax.tree.leaves(s_r.momentum),
                    jax.tree.leaves(s_m.momentum)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    np.testing.assert_allclose(float(st_r["grad_norm"]),
                               float(st_m["grad_norm"]), rtol=1e-6)
    print("FUSED-SHARDED-OK")
""")


RESIDENT_BF16_SHARDED_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sngm
    from repro.core.multi_tensor import FlatOptState
    from repro.core.optim import to_pytree
    from repro.core.schedules import constant
    from repro.checkpoint import load_checkpoint, save_checkpoint

    def bit_eq(a, b):
        return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # bf16 params sharded over the mesh (2D leaves), replicated 1D leaves
    k = jax.random.PRNGKey(0)
    shapes = {"wq": (256, 128), "wk": (256, 128), "scale": (256,),
              "emb": (1000, 64), "bias": (7,)}
    params = {n: jax.random.normal(jax.random.fold_in(k, i), s)
                 .astype(jnp.bfloat16)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    grads = {n: (3.0 * jax.random.normal(jax.random.fold_in(k, 100 + i), s))
                .astype(jnp.bfloat16)
             for i, (n, s) in enumerate(sorted(shapes.items()))}
    mesh = jax.make_mesh((8,), ("data",))
    shard = {n: NamedSharding(mesh, P("data") if len(s) == 2 else P())
             for n, s in shapes.items()}
    params_s = jax.device_put(params, shard)
    grads_s = jax.device_put(grads, shard)

    opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor")
    opt_jnp = sngm(constant(0.3), beta=0.9, weight_decay=1e-4)

    s_res = opt.init(params_s)
    assert isinstance(s_res, FlatOptState)
    s_per = to_pytree(s_res)
    s_ref = opt_jnp.init(params_s)
    step, step_ref = jax.jit(opt.step), jax.jit(opt_jnp.step)
    p_res = p_per = p_ref = params_s
    for _ in range(2):
        p_res, s_res, st_res = step(grads_s, s_res, p_res)
        p_per, s_per, st_per = step(grads_s, s_per, p_per)
        p_ref, s_ref, st_ref = step_ref(grads_s, s_ref, p_ref)

    # resident == per-step fused == jnp, bitwise, on sharded bf16 params
    assert bit_eq(p_res, p_per)
    assert bit_eq(s_res.momentum, s_per.momentum)
    assert bit_eq(p_res, p_ref), "resident vs jnp params differ"
    assert bit_eq(s_res.momentum, s_ref.momentum)
    assert bool(jnp.array_equal(st_res["grad_norm"], st_ref["grad_norm"]))
    print("RESIDENT-SHARDED-BF16-OK")

    # sharded bf16 checkpoint round-trip, both state forms
    for tag, state in (("flat", s_res), ("tree", s_per)):
        d = tempfile.mkdtemp()
        save_checkpoint(d, {"params": p_res, "opt": state}, step=2)
        like = {"params": params_s, "opt": opt.init(params_s) if tag == "flat"
                else to_pytree(opt.init(params_s))}
        restored, t = load_checkpoint(d, like, shardings=None)
        assert t == 2
        assert bit_eq(restored["params"], p_res)
        assert bit_eq(restored["opt"], state)
    print("SHARDED-CKPT-OK")
""")


TWO_LEVEL_NORM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np

    from repro.core.multi_tensor import (_chunk_sumsq, _engine_mesh,
                                         _leaf_values, build_layout, flatten,
                                         flat_squared_norm, mesh_shards,
                                         place_flat_state, tree_squared_norm,
                                         init_flat_state)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    S = mesh_shards(mesh)
    k = jax.random.PRNGKey(0)

    # both dtype buckets: 2D f32 leaves + bf16 leaves + a ragged 1D leaf
    tree = {
        "a": jax.random.normal(jax.random.fold_in(k, 0), (300, 170)),
        "b": jax.random.normal(jax.random.fold_in(k, 1), (999,)),
        "c": (7.0 * jax.random.normal(jax.random.fold_in(k, 2), (128, 256))
              ).astype(jnp.bfloat16),
        "d": jax.random.normal(jax.random.fold_in(k, 3), (64, 64)
              ).astype(jnp.bfloat16),
    }
    layout = build_layout(tree, shards=S)
    assert layout.shards == S and _engine_mesh(layout, mesh) is mesh
    flats = flatten(tree, layout)
    st = place_flat_state(init_flat_state(tree, mesh=mesh), mesh)
    flats_sh = st.p_flats  # placed flat buffers (values untouched)

    # (a) two-level norm, level 1: per-shard Pallas partials + tiled
    # gather must reproduce the unsharded partial vector BITWISE, per
    # bucket — fp32 and bf16 buckets alike
    parts_un, parts_sh = [], []
    for i, (f_un, f_sh) in enumerate(zip(flats, flats_sh)):
        pu = _chunk_sumsq(f_un, backend="pallas", mesh=None)
        ps = jax.jit(
            lambda f: _chunk_sumsq(f, backend="pallas", mesh=mesh))(f_sh)
        assert bool(jnp.array_equal(pu, ps)), f"bucket {i} partials"
        parts_un.append(pu)
        parts_sh.append(ps)
    print("TWO-LEVEL-PARTIALS-OK")

    # level 2: the canonical per-segment fold of the gathered partials ==
    # the fold of the unsharded partials == the tree reduction, bitwise
    n_tree = tree_squared_norm(tree)
    for parts in (parts_un, parts_sh):
        n = sum(_leaf_values(parts, layout))
        assert bool(jnp.array_equal(n, n_tree)), (n, n_tree)

    # and the zero-launch jnp flat norm agrees on unsharded AND sharded
    # (placed) buffers — the global-norm numerics contract end to end
    n_flat = flat_squared_norm(flats, layout)
    assert bool(jnp.array_equal(n_flat, n_tree)), (n_flat, n_tree)
    n_flat_sh = jax.jit(lambda fs: flat_squared_norm(fs, layout))(flats_sh)
    assert bool(jnp.array_equal(n_flat_sh, n_tree)), (n_flat_sh, n_tree)
    print("TWO-LEVEL-NORM-OK")
""")


SHARDED_RESIDENT_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np

    from repro.core import lamb, msgd, sngm
    from repro.core.multi_tensor import FlatOptState, mesh_shards, unflatten
    from repro.core.schedules import constant
    from repro.tracker.counters import (capture_donation_warnings,
                                        launches_per_step)

    def state_trees(st):
        # unflatten against the state's OWN layout: shard padding differs
        # between shards=1 and shards=4 buffers, but the segment contents
        # (params + every slot) must be bitwise identical
        lo = st.layout
        slots = [st.p_flats, st.u_flats, st.m_flats, st.v_flats]
        return [unflatten(f, lo, keep_dtype=True) for f in slots if f]

    def assert_bitwise(st_a, st_b, tag):
        for ta, tb in zip(state_trees(st_a), state_trees(st_b)):
            for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
                assert bool(jnp.array_equal(a, b)), tag

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    k = jax.random.PRNGKey(0)
    shapes = {"wq": (256, 128), "wk": (256, 128), "scale": (256,),
              "emb": (1000, 64), "bias": (7,)}
    params = {n: jax.random.normal(jax.random.fold_in(k, i), s)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    grads3 = [{n: 3.0 * jax.random.normal(jax.random.fold_in(k, 100 + 10*t + i), s)
               for i, (n, s) in enumerate(sorted(shapes.items()))}
              for t in range(3)]

    BUILDERS = {
        "sngm": lambda **kw: sngm(constant(0.3), beta=0.9,
                                  weight_decay=1e-4,
                                  fused="multi_tensor", **kw),
        "msgd": lambda **kw: msgd(constant(0.1), beta=0.9,
                                  fused="multi_tensor", **kw),
        "lamb": lambda **kw: lamb(constant(0.05), weight_decay=1e-4,
                                  fused="multi_tensor", **kw),
    }
    EXPECT_LAUNCHES = {"sngm": 2, "msgd": 2, "lamb": 2}

    for name, mk in BUILDERS.items():
        # single-device reference: UNDONATED steps — the canonical
        # numerics.  (Donation on the unsharded path can shift msgd by
        # one ulp via XLA fusion re-association; the sharded shard_map
        # path below is donation-stable and must match the canonical.)
        opt_1 = mk()
        st_1 = opt_1.init(params)
        step_1 = jax.jit(opt_1.step)
        for g in grads3:
            _, st_1, stats_1 = step_1(g, st_1, None)

        # sharded resident: same optimizer built WITH the mesh
        opt_s = mk(mesh=mesh)
        st_s = opt_s.init(params)
        assert isinstance(st_s, FlatOptState)
        assert st_s.layout.shards == mesh_shards(mesh) == 4
        # every flat slot actually sharded over all mesh axes
        for f in st_s.p_flats:
            spec = f.sharding.spec
            assert tuple(spec) == (("data", "model"),), spec
        step_s = jax.jit(opt_s.step, donate_argnums=(1,))
        # zero donation warnings under sharding
        (_, st_s, stats_s), msgs = capture_donation_warnings(
            step_s, grads3[0], st_s, None)
        assert not msgs, msgs
        for g in grads3[1:]:
            _, st_s, stats_s = step_s(g, st_s, None)

        # bitwise fp32 parity: params AND every slot AND stats
        assert_bitwise(st_1, st_s, name)
        for key in ("grad_norm", "update_norm"):
            if key in stats_1:
                assert bool(jnp.array_equal(stats_1[key], stats_s[key])), \
                    (name, key)

        # launch counts unchanged under sharding
        n1 = launches_per_step(opt_1, grads3[0], opt_1.init(params), None)
        ns = launches_per_step(opt_s, grads3[0], opt_s.init(params), None)
        assert n1 == ns == EXPECT_LAUNCHES[name], (name, n1, ns)
        print(name, "OK launches", ns)

    # clip_sngm: the 3-launch clip-prefixed chain, sharded vs single
    from repro.core import transform as T
    def mk_clip(mesh=None):
        tx = T.chain(T.clip_by_global_norm(1.0),
                     T.add_decayed_weights(1e-4),
                     T.normalize_by_global_norm(),
                     T.trace(0.9),
                     T.scale_by_schedule(constant(0.3)))
        return T.compile_chain(tx, fused="multi_tensor", mesh=mesh)
    opt_1, opt_s = mk_clip(), mk_clip(mesh)
    st_1, st_s = opt_1.init(params), opt_s.init(params)
    s1 = jax.jit(opt_1.step)                       # canonical reference
    ss = jax.jit(opt_s.step, donate_argnums=(1,))
    for g in grads3:
        _, st_1, stats_1 = s1(g, st_1, None)
        _, st_s, stats_s = ss(g, st_s, None)
    assert_bitwise(st_1, st_s, "clip_sngm")
    n1 = launches_per_step(opt_1, grads3[0], opt_1.init(params), None)
    ns = launches_per_step(opt_s, grads3[0], opt_s.init(params), None)
    assert n1 == ns == 3, (n1, ns)
    print("clip_sngm OK launches", ns)
    print("SHARDED-RESIDENT-PARITY-OK")
""")


LAUNCHER_MESH_RESUME_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import main as train_main

    tmp = tempfile.mkdtemp()

    def run(extra):
        # the CI multi-process smoke lane: the launcher end to end on a
        # 2x2 data x model mesh (host devices), multi-process flags routed
        # through init_distributed (single-process no-op here)
        return train_main(
            ["--arch", "gemma-2b", "--reduced", "--batch", "4",
             "--seq", "16", "--n-micro", "2", "--optimizer", "sngm",
             "--fused", "multi_tensor", "--lr", "0.5",
             "--data-axis", "2", "--model-axis", "2",
             "--num-processes", "0", "--process-id", "-1",
             "--total-steps", "8", "--log-every", "100"] + extra)

    full = run(["--steps", "8"])
    part = run(["--steps", "4", "--ckpt", os.path.join(tmp, "ck")])
    np.testing.assert_allclose(part, full[:4], rtol=1e-6)
    print("LAUNCHER-MESH-OK")

    # --resume re-packs the resident FlatOptState at the mesh's shard
    # count and continues bitwise-continuously with the full run
    resumed = run(["--steps", "8", "--ckpt", os.path.join(tmp, "ck"),
                   "--resume"])
    assert len(resumed) == 4, len(resumed)
    np.testing.assert_allclose(resumed, full[4:], rtol=1e-5, atol=1e-6)
    print("LAUNCHER-MESH-RESUME-OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)


def test_distributed_train_step_matches_single_device():
    r = _run(SCRIPT)
    assert "MULTIDEVICE-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_moe_expert_parallel_matches_oracle():
    r = _run(MOE_EP_SCRIPT)
    assert "MOE-EP-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_multi_tensor_engine_matches_jnp_on_sharded_params():
    r = _run(FUSED_OPT_SCRIPT)
    assert "FUSED-SHARDED-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_resident_state_bitwise_and_checkpoint_on_sharded_bf16():
    r = _run(RESIDENT_BF16_SHARDED_SCRIPT)
    assert "RESIDENT-SHARDED-BF16-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
    assert "SHARDED-CKPT-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]


def test_two_level_norm_sharded_matches_canonical_fold_bitwise():
    r = _run(TWO_LEVEL_NORM_SCRIPT)
    assert "TWO-LEVEL-PARTIALS-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
    assert "TWO-LEVEL-NORM-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]


def test_sharded_resident_steps_bitwise_with_launch_counts():
    r = _run(SHARDED_RESIDENT_PARITY_SCRIPT)
    assert "SHARDED-RESIDENT-PARITY-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]


def test_launcher_mesh_e2e_and_resume():
    r = _run(LAUNCHER_MESH_RESUME_SCRIPT)
    assert "LAUNCHER-MESH-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
    assert "LAUNCHER-MESH-RESUME-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
