"""Multi-device integration (subprocess: 8 host devices).

Checks that the distributed execution paths — pjit with the production
sharding rules, expert-parallel all_to_all MoE, gradient accumulation —
produce the SAME numbers as single-device execution.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCHS, smoke_variant
    from repro.core import sngm
    from repro.core.schedules import constant
    from repro.models import model_defs, forward
    from repro.models.param import materialize
    from repro.models.runtime import Runtime, CPU_RUNTIME
    from repro.sharding import param_shardings, batch_spec
    from repro.training import make_train_step
    from repro.core.optim import OptState, TrainState

    # f32 so single- vs multi-device results are comparable tightly;
    # capacity_factor=16 so no token drops: EP computes capacity per shard,
    # so at low cf drop PATTERNS legitimately differ from single-device
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-v2-lite-16b"]),
                              compute_dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    defs = model_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    B, S = 8, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "loss_mask": jnp.ones((B, S), jnp.float32)}

    opt = sngm(constant(0.01), beta=0.9, weight_decay=1e-4)

    # --- single device reference ---
    step_ref = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2))
    ts_ref, stats_ref = step_ref(opt.init_state(params), batch)

    # --- 4x2 mesh (data=4 with EP, model=2 TP) ---
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rt = Runtime(mesh=mesh, data_axes=("data",), remat=True)
    psh = param_shardings(defs, mesh)
    params_sharded = jax.device_put(params, psh)
    ts_sh = TrainState(params=psh,
                       opt_state=OptState(step=NamedSharding(mesh, P()),
                                          momentum=psh))
    step_dist = jax.jit(make_train_step(cfg, rt, opt, n_micro=2),
                        in_shardings=(ts_sh,
                                      {k: NamedSharding(mesh, batch_spec(mesh, v.ndim))
                                       for k, v in batch.items()}),
                        out_shardings=(ts_sh, None))
    ts_dist, stats_dist = step_dist(opt.init_state(params_sharded), batch)

    l1, l2 = float(stats_ref["loss"]), float(stats_dist["loss"])
    g1, g2 = float(stats_ref["grad_norm"]), float(stats_dist["grad_norm"])
    print("LOSS", l1, l2, "GNORM", g1, g2)
    assert abs(l1 - l2) < 1e-4 * max(1, abs(l1)), (l1, l2)
    assert abs(g1 - g2) < 1e-3 * max(1, abs(g1)), (g1, g2)
    # parameters agree after one update
    for a, b in zip(jax.tree.leaves(ts_ref.params_view),
                    jax.tree.leaves(ts_dist.params_view)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)),
                                   atol=5e-5)
    print("MULTIDEVICE-OK")
""")

MOE_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import MoEConfig, ModelConfig
    from repro.models import moe
    from repro.models.param import materialize
    from repro.models.runtime import Runtime, CPU_RUNTIME

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                      compute_dtype="float32",
                      moe=MoEConfig(n_experts=8, top_k=2, d_expert=64,
                                    capacity_factor=8.0))
    p = materialize(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64), jnp.float32)

    y_ref, aux_ref = moe.moe_ref(p, x, cfg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rt = Runtime(mesh=mesh, data_axes=("data",))
    y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg, rt))(p, x)
    print("AUX", float(aux_ref), float(aux_ep))
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), atol=1e-4)

    # allreduce mode: batch=2 tokens, not divisible by data=4
    x2 = x[:2, :1]
    y_ref2, _ = moe.moe_ref(p, x2, cfg)
    y_ep2, _ = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg, rt))(p, x2)
    np.testing.assert_allclose(np.asarray(y_ep2), np.asarray(y_ref2), atol=1e-4)
    print("MOE-EP-OK")
""")


FUSED_OPT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sngm
    from repro.core.schedules import constant

    # a transformer-ish tree: 2D matrices shard over the mesh, 1D stay
    # replicated — the multi-tensor engine must give the same numbers as
    # the jnp path when the flat buffers are built from sharded leaves
    k = jax.random.PRNGKey(0)
    shapes = {"wq": (256, 128), "wk": (256, 128), "scale": (256,),
              "emb": (1000, 64), "bias": (7,)}
    params = {n: jax.random.normal(jax.random.fold_in(k, i), s)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    grads = {n: 3.0 * jax.random.normal(jax.random.fold_in(k, 100 + i), s)
             for i, (n, s) in enumerate(sorted(shapes.items()))}

    mesh = jax.make_mesh((8,), ("data",))
    shard = {n: NamedSharding(mesh, P("data") if len(s) == 2 else P())
             for n, s in shapes.items()}
    params_s = jax.device_put(params, shard)
    grads_s = jax.device_put(grads, shard)

    outs = {}
    for fused in (None, "multi_tensor"):
        opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4, fused=fused)
        state = opt.init(params_s)
        step = jax.jit(opt.step)
        p, s = params_s, state
        for _ in range(2):
            p, s, stats = step(grads_s, s, p)
        outs[fused] = (p, s, stats)
    (p_r, s_r, st_r), (p_m, s_m, st_m) = outs[None], outs["multi_tensor"]
    for a, b in zip(jax.tree.leaves(p_r), jax.tree.leaves(p_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    for a, b in zip(jax.tree.leaves(s_r.momentum),
                    jax.tree.leaves(s_m.momentum)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    np.testing.assert_allclose(float(st_r["grad_norm"]),
                               float(st_m["grad_norm"]), rtol=1e-6)
    print("FUSED-SHARDED-OK")
""")


RESIDENT_BF16_SHARDED_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sngm
    from repro.core.multi_tensor import FlatOptState
    from repro.core.optim import to_pytree
    from repro.core.schedules import constant
    from repro.checkpoint import load_checkpoint, save_checkpoint

    def bit_eq(a, b):
        return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # bf16 params sharded over the mesh (2D leaves), replicated 1D leaves
    k = jax.random.PRNGKey(0)
    shapes = {"wq": (256, 128), "wk": (256, 128), "scale": (256,),
              "emb": (1000, 64), "bias": (7,)}
    params = {n: jax.random.normal(jax.random.fold_in(k, i), s)
                 .astype(jnp.bfloat16)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    grads = {n: (3.0 * jax.random.normal(jax.random.fold_in(k, 100 + i), s))
                .astype(jnp.bfloat16)
             for i, (n, s) in enumerate(sorted(shapes.items()))}
    mesh = jax.make_mesh((8,), ("data",))
    shard = {n: NamedSharding(mesh, P("data") if len(s) == 2 else P())
             for n, s in shapes.items()}
    params_s = jax.device_put(params, shard)
    grads_s = jax.device_put(grads, shard)

    opt = sngm(constant(0.3), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor")
    opt_jnp = sngm(constant(0.3), beta=0.9, weight_decay=1e-4)

    s_res = opt.init(params_s)
    assert isinstance(s_res, FlatOptState)
    s_per = to_pytree(s_res)
    s_ref = opt_jnp.init(params_s)
    step, step_ref = jax.jit(opt.step), jax.jit(opt_jnp.step)
    p_res = p_per = p_ref = params_s
    for _ in range(2):
        p_res, s_res, st_res = step(grads_s, s_res, p_res)
        p_per, s_per, st_per = step(grads_s, s_per, p_per)
        p_ref, s_ref, st_ref = step_ref(grads_s, s_ref, p_ref)

    # resident == per-step fused == jnp, bitwise, on sharded bf16 params
    assert bit_eq(p_res, p_per)
    assert bit_eq(s_res.momentum, s_per.momentum)
    assert bit_eq(p_res, p_ref), "resident vs jnp params differ"
    assert bit_eq(s_res.momentum, s_ref.momentum)
    assert bool(jnp.array_equal(st_res["grad_norm"], st_ref["grad_norm"]))
    print("RESIDENT-SHARDED-BF16-OK")

    # sharded bf16 checkpoint round-trip, both state forms
    for tag, state in (("flat", s_res), ("tree", s_per)):
        d = tempfile.mkdtemp()
        save_checkpoint(d, {"params": p_res, "opt": state}, step=2)
        like = {"params": params_s, "opt": opt.init(params_s) if tag == "flat"
                else to_pytree(opt.init(params_s))}
        restored, t = load_checkpoint(d, like, shardings=None)
        assert t == 2
        assert bit_eq(restored["params"], p_res)
        assert bit_eq(restored["opt"], state)
    print("SHARDED-CKPT-OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)


def test_distributed_train_step_matches_single_device():
    r = _run(SCRIPT)
    assert "MULTIDEVICE-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_moe_expert_parallel_matches_oracle():
    r = _run(MOE_EP_SCRIPT)
    assert "MOE-EP-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_multi_tensor_engine_matches_jnp_on_sharded_params():
    r = _run(FUSED_OPT_SCRIPT)
    assert "FUSED-SHARDED-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_resident_state_bitwise_and_checkpoint_on_sharded_bf16():
    r = _run(RESIDENT_BF16_SHARDED_SCRIPT)
    assert "RESIDENT-SHARDED-BF16-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
    assert "SHARDED-CKPT-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
