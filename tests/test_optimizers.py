"""Unit tests for the optimizer family (SNGM + baselines)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sngm, sngd, msgd, lars, lamb, make_optimizer, global_norm
from repro.core.schedules import constant, poly_power, step_decay, warmup, cosine


def params():
    return {"w": jnp.full((4, 8), 2.0), "b": jnp.zeros((8,))}


def grads(scale=1.0):
    return {"w": jnp.full((4, 8), scale), "b": jnp.full((8,), scale)}


def test_sngm_matches_hand_computed():
    opt = sngm(constant(0.5), beta=0.0)
    st = opt.init(params())
    p, st, stats = opt.step(grads(3.0), st, params())
    gn = float(np.sqrt(40 * 9.0))
    np.testing.assert_allclose(stats["grad_norm"], gn, rtol=1e-6)
    # u = g/||g||, w' = w - 0.5*u
    np.testing.assert_allclose(np.asarray(p["w"]), 2.0 - 0.5 * 3.0 / gn, rtol=1e-6)


def test_sngm_scale_invariance():
    """Normalization makes the update invariant to gradient magnitude."""
    opt = sngm(constant(0.1), beta=0.9)
    outs = []
    for scale in (1e-6, 1.0, 1e6):
        st = opt.init(params())
        p, _, _ = opt.step(grads(scale), st, params())
        outs.append(np.asarray(p["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-5)


def test_msgd_not_scale_invariant():
    opt = msgd(constant(0.1), beta=0.9)
    st = opt.init(params())
    p1, _, _ = opt.step(grads(1.0), st, params())
    p2, _, _ = opt.step(grads(100.0), opt.init(params()), params())
    assert not np.allclose(np.asarray(p1["w"]), np.asarray(p2["w"]))


def test_sngd_equals_sngm_beta0():
    o1, o2 = sngd(constant(0.2)), sngm(constant(0.2), beta=0.0)
    p1, _, _ = o1.step(grads(5.0), o1.init(params()), params())
    p2, _, _ = o2.step(grads(5.0), o2.init(params()), params())
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]))


def test_sngm_per_tensor_mode():
    opt = sngm(constant(0.1), beta=0.0, norm_mode="per_tensor")
    st = opt.init(params())
    g = {"w": jnp.full((4, 8), 100.0), "b": jnp.full((8,), 1e-3)}
    p, st, _ = opt.step(g, st, params())
    # both tensors get unit-norm updates despite 1e5 scale difference
    dw = np.asarray(params()["w"] - p["w"])
    db = np.asarray(params()["b"] - p["b"])
    np.testing.assert_allclose(np.linalg.norm(dw), 0.1, rtol=1e-4)
    np.testing.assert_allclose(np.linalg.norm(db), 0.1, rtol=1e-4)


def test_lars_trust_ratio():
    opt = lars(constant(1.0), beta=0.0, weight_decay=0.0, trust=0.01)
    st = opt.init(params())
    p, _, _ = opt.step(grads(1.0), st, params())
    w, g = params()["w"], grads()["w"]
    local = 0.01 * np.linalg.norm(np.asarray(w).ravel()) / np.linalg.norm(np.asarray(g).ravel())
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(w) - local * 1.0,
                               rtol=1e-5)


def test_weight_decay_coupled():
    """wd adds wd*w to the gradient BEFORE normalization (paper setup)."""
    opt = sngm(constant(0.1), beta=0.0, weight_decay=0.5)
    st = opt.init(params())
    g = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    p, _, stats = opt.step(g, st, params())
    # g_eff = 0.5*w -> normalized direction = w/||w||
    assert float(stats["grad_norm"]) > 0
    assert np.all(np.asarray(p["w"]) < 2.0)


def test_lamb_runs_and_is_finite():
    opt = lamb(constant(0.01), weight_decay=0.01)
    st = opt.init(params())
    p, st, _ = opt.step(grads(10.0), st, params())
    assert np.all(np.isfinite(np.asarray(p["w"])))


def test_lamb_reports_full_stats_with_canonical_norms():
    """lamb is a chain now: it must report {grad_norm, lr, update_norm}
    like the rest of the family, with grad_norm from the canonical
    leaf_sumsq reduction (bit-identical to global_norm) instead of the
    old jnp.linalg.norm per-leaf path."""
    from repro.core import global_norm
    opt = lamb(constant(0.01), weight_decay=0.01)
    st = opt.init(params())
    g = grads(10.0)
    p, st, stats = opt.step(g, st, params())
    assert {"grad_norm", "lr", "update_norm"} <= set(stats)
    assert bool(jnp.array_equal(stats["grad_norm"], global_norm(g)))
    assert np.isfinite(float(stats["update_norm"]))
    # two steps: the Adam bias correction advances with the chain state
    p, st, stats2 = opt.step(g, st, p)
    assert int(st.step) == 2


def test_make_optimizer_registry():
    for name in ("sngm", "sngd", "msgd", "lars", "lamb"):
        opt = make_optimizer(name, constant(0.1))
        assert opt.step is not None
    with pytest.raises(KeyError):
        make_optimizer("adamw", constant(0.1))


def test_sngm_pallas_path_matches_jnp():
    o_ref = sngm(constant(0.3), beta=0.9, weight_decay=1e-4)
    o_pal = sngm(constant(0.3), beta=0.9, weight_decay=1e-4, use_pallas=True)
    st_r, st_p = o_ref.init(params()), o_pal.init(params())
    p_r, p_p = params(), params()
    for i in range(3):
        g = jax.tree.map(lambda x: x * (i + 1) * 7.0, grads(1.0))
        p_r, st_r, _ = o_ref.step(g, st_r, p_r)
        p_p, st_p, _ = o_pal.step(g, st_p, p_p)
    for a, b in zip(jax.tree.leaves(p_r), jax.tree.leaves(p_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_poly_power():
    s = poly_power(1.6, 100, 1.1)
    assert float(s(jnp.int32(0))) == pytest.approx(1.6)
    assert float(s(jnp.int32(100))) == pytest.approx(0.0)
    assert 0 < float(s(jnp.int32(50))) < 1.6


def test_step_decay():
    s = step_decay(0.1, [80, 120])
    assert float(s(jnp.int32(10))) == pytest.approx(0.1)
    assert float(s(jnp.int32(80))) == pytest.approx(0.01)
    assert float(s(jnp.int32(121))) == pytest.approx(0.001, rel=1e-5)


def test_warmup_then_base():
    s = warmup(constant(2.4), 5, init_lr=0.1)
    assert float(s(jnp.int32(0))) == pytest.approx(0.1)
    assert float(s(jnp.int32(5))) == pytest.approx(2.4)
    assert 0.1 < float(s(jnp.int32(2))) < 2.4


def test_cosine():
    s = cosine(1.0, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
