"""Gradient-transform algebra, the chain -> multi-tensor compiler, and
OptimizerSpec serialization.

The headline guarantees under test:
  * the chain-built optimizers (sngm global/per_tensor, msgd, lars) are
    BIT-identical to the pre-redesign monolithic implementations — a
    frozen golden copy of the old jnp closures lives in this file — in
    every execution mode (jnp, multi_tensor, FlatOptState-resident),
    fp32 and bf16, across multiple steps, params AND state AND stats;
  * the generic jnp interpreter agrees with the compiled kinds;
  * a novel chain matching no fused kind trains end-to-end through
    ``make_train_step`` (and issues zero Pallas launches);
  * ``compile_chain`` maps exactly the canonical shapes onto kinds and
    warns when a fused request must fall back;
  * ``OptimizerSpec`` round-trips through JSON and rebuilds an optimizer
    whose steps are bit-identical to the directly-built one.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChainOptState, FlatOptState, OptState, OptimizerSpec, as_optimizer,
    chain, compile_chain, global_norm, lamb, lars, leaf_sumsq, make_optimizer,
    msgd, sngd, sngm, to_pytree)
from repro.core import transform as T
from repro.core.optim import builder_accepts, optimizer_names
from repro.core.schedules import constant, poly_power
from repro.kernels import count_pallas_launches

KEY = jax.random.PRNGKey(0)
SHAPES = [(300, 17), (1025,), (), (4,), (64, 64), (3, 5, 7)]


def make_tree(seed, dtype=jnp.float32, scale=1.0):
    k = jax.random.fold_in(KEY, seed)
    return {f"p{i}": (scale * jax.random.normal(jax.random.fold_in(k, i), s)
                      ).astype(dtype)
            for i, s in enumerate(SHAPES)}


def tree_bitwise_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) and x.dtype == y.dtype
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# frozen golden: the pre-redesign monolithic jnp optimizer steps, verbatim.
# The chain-built optimizers must reproduce these bit-for-bit forever.
# ---------------------------------------------------------------------------

def _golden_step(kind, grads, momentum, params, *, lr, beta, wd,
                 eps=1e-12, trust=0.001):
    if kind == "lars":
        def upd(v, g, w):
            g = g.astype(jnp.float32)
            wn = jnp.sqrt(leaf_sumsq(w))
            gn = jnp.sqrt(leaf_sumsq(g))
            local = trust * wn / (gn + wd * wn + eps)
            local = jnp.where(wn > 0, local, 1.0)
            return beta * v + lr * local * (g + wd * w)

        new_u = jax.tree.map(upd, momentum, grads, params)
        new_p = jax.tree.map(lambda w, v: (w - v).astype(w.dtype),
                             params, new_u)
        gnorm = global_norm(grads)
    else:
        g = (grads if wd == 0.0 else
             jax.tree.map(lambda gi, w: gi + wd * w, grads, params))
        gnorm = global_norm(g)
        if kind == "sngm_global":
            inv = 1.0 / (gnorm + eps)
            new_u = jax.tree.map(
                lambda u, gi: beta * u + gi.astype(jnp.float32) * inv,
                momentum, g)
        elif kind == "sngm_per_tensor":
            def upd(u, gi):
                n = jnp.sqrt(leaf_sumsq(gi))
                return beta * u + gi.astype(jnp.float32) * (1.0 / (n + eps))
            new_u = jax.tree.map(upd, momentum, g)
        else:  # msgd
            new_u = jax.tree.map(
                lambda v, gi: beta * v + gi.astype(jnp.float32), momentum, g)
        new_p = jax.tree.map(lambda w, u: (w - lr * u).astype(w.dtype),
                             params, new_u)
    return new_p, new_u, {"grad_norm": gnorm, "lr": lr,
                          "update_norm": global_norm(new_u)}


def _golden_run(kind, params, grads, schedule, n=3, **kw):
    momentum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    step = jax.jit(lambda g, u, p, lr: _golden_step(kind, g, u, p, lr=lr,
                                                    **kw))
    stats = None
    for t in range(n):
        params, momentum, stats = step(grads, momentum, params,
                                       schedule(jnp.int32(t)))
    return params, momentum, stats


SCHED = poly_power(0.3, 10, 1.1)   # lr varies per step: exercises counters

CASES = {
    "sngm_global": (
        lambda **kw: sngm(SCHED, beta=0.9, weight_decay=1e-4, **kw),
        dict(beta=0.9, wd=1e-4)),
    "sngm_per_tensor": (
        lambda **kw: sngm(SCHED, beta=0.9, weight_decay=1e-4,
                          norm_mode="per_tensor", **kw),
        dict(beta=0.9, wd=1e-4)),
    "msgd": (
        lambda **kw: msgd(SCHED, beta=0.9, weight_decay=1e-4, **kw),
        dict(beta=0.9, wd=1e-4)),
    "lars": (
        lambda **kw: lars(SCHED, beta=0.9, weight_decay=1e-4, **kw),
        dict(beta=0.9, wd=1e-4)),
}


def _run(opt, params, grads, state, n=3):
    step = jax.jit(opt.step)
    stats = None
    for _ in range(n):
        params, state, stats = step(grads, state, params)
    return params, state, stats


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["jnp", "multi_tensor", "resident"])
@pytest.mark.parametrize("kind", sorted(CASES))
def test_chain_built_bit_equal_to_golden(kind, mode, dtype):
    """The acceptance bar: chain builders == pre-redesign monoliths,
    bitwise, in every execution mode."""
    params = make_tree(0, dtype)
    grads = make_tree(1, dtype, scale=3.0)
    build, kw = CASES[kind]
    p_g, u_g, st_g = _golden_run(kind, params, grads, SCHED, **kw)

    opt = build(fused=None if mode == "jnp" else "multi_tensor")
    state = opt.init(params)
    if mode == "multi_tensor":
        state = to_pytree(state)         # force the per-step packing path
    p_c, s_c, st_c = _run(opt, params, grads, state)
    if mode == "resident":
        assert isinstance(s_c, FlatOptState)
    assert opt.kind == kind
    assert tree_bitwise_equal(p_g, p_c)
    assert tree_bitwise_equal(u_g, s_c.momentum)
    for k in st_g:
        assert bool(jnp.array_equal(st_g[k], st_c[k])), (k, st_g[k], st_c[k])


@pytest.mark.parametrize("kind", ["sngm_global", "msgd"])
def test_interpreter_bit_identical_for_matched_shapes(kind):
    """compile_chain(interpret=True) runs the raw transforms; for the
    sngm/msgd shapes the interpreter's expression graphs are the same as
    the kind implementations', so even the fallback is bit-exact."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    build, _ = CASES[kind]
    opt_c = build()
    tx = (T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                  T.trace(0.9), T.scale_by_schedule(SCHED))
          if kind == "sngm_global" else
          T.chain(T.add_decayed_weights(1e-4), T.trace(0.9),
                  T.scale_by_schedule(SCHED)))
    opt_i = compile_chain(tx, interpret=True)
    p_c, s_c, st_c = _run(opt_c, params, grads, opt_c.init(params))
    p_i, s_i, st_i = _run(opt_i, params, grads, opt_i.init(params))
    assert isinstance(s_i, ChainOptState)
    assert tree_bitwise_equal(p_c, p_i)
    # grad_norm: the msgd-shaped chain has no norm-emitting stage, so the
    # interpreter's default reports the RAW gradient norm where the kind
    # implementation reports the coupled-decayed one — a documented
    # fallback-semantics difference; everything else must agree bitwise.
    keys = set(st_c) - ({"grad_norm"} if kind == "msgd" else set())
    for k in keys:
        assert bool(jnp.array_equal(st_c[k], st_i[k])), k


def test_interpreter_close_for_lars_lamb_shapes():
    """lars/lamb associate the lr product differently in the interpreter;
    they still agree to float tolerance (bit-exactness for the named
    builders comes from the compiled kinds, asserted above)."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    opt_c = CASES["lars"][0]()
    tx = T.chain(T.trust_ratio(0.001, 1e-4, 1e-12),
                 T.scale_by_schedule(SCHED), T.trace(0.9))
    opt_i = compile_chain(tx, interpret=True)
    p_c, _, _ = _run(opt_c, params, grads, opt_c.init(params))
    p_i, _, _ = _run(opt_i, params, grads, opt_i.init(params))
    for a, b in zip(jax.tree.leaves(p_c), jax.tree.leaves(p_i)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)


# ---------------------------------------------------------------------------
# frozen golden: LAMB, expression-for-expression the interpreter chain that
# defined the reference numerics when lamb was interpreter-only (PR 3).
# The fused engine kind must reproduce it bit-for-bit forever.
# ---------------------------------------------------------------------------

def _golden_lamb_step(grads, count, m, v, params, *, lr, b1=0.9, b2=0.999,
                      wd=1e-4, eps=1e-6, trust_eps=0.0):
    t = count.astype(jnp.float32) + 1.0
    new_m = jax.tree.map(
        lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), m, grads)
    new_v = jax.tree.map(
        lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        v, grads)
    u = jax.tree.map(
        lambda mm, vv: (mm / (1 - b1 ** t)) / (jnp.sqrt(vv / (1 - b2 ** t))
                                               + eps), new_m, new_v)
    if wd != 0.0:
        u = jax.tree.map(lambda g, w: g + wd * w, u, params)

    def rescale(uu, w):
        wn = jnp.sqrt(leaf_sumsq(w))
        un = jnp.sqrt(leaf_sumsq(uu))
        ratio = jnp.where((wn > 0) & (un > 0), wn / (un + trust_eps), 1.0)
        return ratio * uu.astype(jnp.float32)

    u = jax.tree.map(rescale, u, params)
    update_norm = global_norm(u)
    u = jax.tree.map(lambda x: lr * x, u)
    new_p = jax.tree.map(lambda w, x: (w - x).astype(w.dtype), params, u)
    return new_p, new_m, new_v, {"grad_norm": global_norm(grads), "lr": lr,
                                 "update_norm": update_norm}


def _golden_lamb_run(params, grads, schedule, n=3, **kw):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m, v = zeros, zeros
    step = jax.jit(lambda g, c, m, v, p, lr: _golden_lamb_step(
        g, c, m, v, p, lr=lr, **kw))
    stats = None
    for t in range(n):
        params, m, v, stats = step(grads, jnp.int32(t), m, v, params,
                                   schedule(jnp.int32(t)))
    return params, m, v, stats


def _lamb_edge_tree(dtype):
    """Trust-ratio edge cases alongside regular leaves: a zero-norm param
    leaf (ratio -> 1), and a leaf whose gradient will be zero (zero-norm
    Adam update at every t => ratio -> 1)."""
    tree = make_tree(0, dtype)
    tree["zero_w"] = jnp.zeros((37,), dtype)
    tree["zero_g"] = (1.0 + jnp.arange(12, dtype=jnp.float32)
                      ).astype(dtype).reshape(3, 4)
    return tree


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["jnp", "resident"])
def test_lamb_bit_equal_to_golden(mode, dtype):
    """Fused LAMB == the frozen interpreter-chain numerics, bitwise
    (params, both moments, stats), fp32 AND bf16, across steps that
    include t=1 (the extreme bias-correction step) and the zero-norm
    trust-ratio edge cases."""
    params = _lamb_edge_tree(dtype)
    grads = make_tree(1, dtype, scale=3.0)
    grads["zero_w"] = (0.1 * jnp.ones((37,))).astype(dtype)
    grads["zero_g"] = jnp.zeros((3, 4), dtype)
    for n in (1, 3):                      # n=1 isolates the t=1 correction
        p_g, m_g, v_g, st_g = _golden_lamb_run(params, grads, SCHED, n=n)
        opt = lamb(SCHED, weight_decay=1e-4,
                   fused=None if mode == "jnp" else "multi_tensor")
        assert opt.kind == "lamb"
        p_c, s_c, st_c = _run(opt, params, grads, opt.init(params), n=n)
        if mode == "resident":
            assert isinstance(s_c, FlatOptState)
            m_c, v_c = s_c.moments
        else:
            assert isinstance(s_c, ChainOptState)
            adam = s_c.inner[0]
            m_c, v_c = adam.m, adam.v
            assert int(adam.count) == n
        assert tree_bitwise_equal(p_g, p_c)
        assert tree_bitwise_equal(m_g, m_c)
        assert tree_bitwise_equal(v_g, v_c)
        for k in st_g:
            assert bool(jnp.array_equal(st_g[k], st_c[k])), k


def test_lamb_state_forms_interconvert_losslessly():
    """to_pytree(flat lamb state) is the interpreter's ChainOptState;
    from_pytree rebuilds the flat form bitwise — the conversions --resume
    relies on when switching execution modes."""
    from repro.core.optim import from_pytree
    params = make_tree(0)
    grads = make_tree(1, scale=3.0)
    opt = lamb(SCHED, weight_decay=1e-4, fused="multi_tensor")
    params, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    chain_view = to_pytree(state)
    assert isinstance(chain_view, ChainOptState)
    back = from_pytree(chain_view, params)
    assert back.form == state.form
    assert tree_bitwise_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert tree_bitwise_equal(tuple(back.m_flats), tuple(state.m_flats))
    assert tree_bitwise_equal(tuple(back.v_flats), tuple(state.v_flats))
    # and the chain view IS what the interpreter would have produced
    opt_i = lamb(SCHED, weight_decay=1e-4)
    params_i = make_tree(0)
    _, s_i, _ = jax.jit(opt_i.step)(make_tree(1, scale=3.0),
                                    opt_i.init(params_i), params_i)
    assert jax.tree_util.tree_structure(chain_view) == \
        jax.tree_util.tree_structure(s_i)


def test_from_pytree_rejects_stateful_noncanonical_chain_state():
    """A ChainOptState whose mid-chain stages carry state (trace momentum,
    EMA shadows) has no flat form — from_pytree must refuse rather than
    silently dropping that state (which would corrupt a resumed run)."""
    from repro.core.optim import from_pytree
    params = make_tree(0)
    tx = T.chain(T.scale_by_adam(0.9, 0.999, 1e-6), T.trace(0.9),
                 T.scale_by_schedule(SCHED))
    opt = compile_chain(tx, interpret=True)
    state = opt.init(params)
    with pytest.raises(TypeError, match="canonical"):
        from_pytree(state, params)


# ---------------------------------------------------------------------------
# the compiler: what matches, what falls back
# ---------------------------------------------------------------------------

def test_compile_chain_kind_assignment():
    assert sngm(constant(0.1)).kind == "sngm_global"
    assert sngm(constant(0.1), norm_mode="per_tensor").kind == \
        "sngm_per_tensor"
    assert sngd(constant(0.1)).kind == "sngm_global"    # beta=0 sngm
    assert msgd(constant(0.1)).kind == "msgd"
    assert lars(constant(0.1)).kind == "lars"
    assert lamb(constant(0.1)).kind == "lamb"           # fused since PR 4
    # clip-prefixed canonical chains compile too (two-round norm pass)
    clip_sngm = T.chain(T.clip_by_global_norm(1.0),
                        T.normalize_by_global_norm(), T.trace(0.9),
                        T.scale_by_schedule(constant(0.1)))
    assert compile_chain(clip_sngm).kind == "sngm_global"
    clip_lamb = T.chain(T.clip_by_global_norm(1.0),
                        T.scale_by_adam(0.9, 0.999, 1e-6),
                        T.scale_by_trust_ratio(),
                        T.scale_by_schedule(constant(0.1)))
    assert compile_chain(clip_lamb).kind == "lamb"
    # adam eps <= 0 would break the engine's zero-pad invariance: falls
    # back to the interpreter rather than computing 0/0 in the padding
    eps0 = T.chain(T.scale_by_adam(0.9, 0.999, 0.0), T.scale_by_trust_ratio(),
                   T.scale_by_schedule(constant(0.1)))
    assert T.match_chain(eps0) is None


def test_chain_without_decay_matches_with_wd0():
    """add_decayed_weights is optional in the patterns: omitting it
    compiles to the kind with weight_decay=0."""
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    tx = T.chain(T.normalize_by_global_norm(), T.trace(0.9),
                 T.scale_by_schedule(SCHED))
    opt = compile_chain(tx)
    assert opt.kind == "sngm_global"
    ref = sngm(SCHED, beta=0.9, weight_decay=0.0)
    p_a, _, _ = _run(opt, params, grads, opt.init(params))
    p_b, _, _ = _run(ref, params, grads, ref.init(params))
    assert tree_bitwise_equal(p_a, p_b)


def test_nesterov_trace_matches_as_kind_variant():
    """Since the segment compiler, trace(nesterov=True) is a fused kind
    parameter, not a de-fusing novelty."""
    tx = T.chain(T.normalize_by_global_norm(), T.trace(0.9, nesterov=True),
                 T.scale_by_schedule(constant(0.1)))
    kind, kp = T.match_chain(tx)
    assert kind == "sngm_global" and kp["nesterov"] is True
    opt = compile_chain(tx)
    assert opt.kind == "sngm_global"


def test_fused_request_on_novel_chain_warns_and_falls_back():
    # scale_by_adam followed by trace matches no kind (Adam feeds the
    # trust-ratio grammar, not the momentum one) and adam is a stateful
    # mid-chain stage the planner cannot interleave as jnp
    tx = T.chain(T.scale_by_adam(0.9, 0.999, 1e-8), T.trace(0.9),
                 T.scale_by_schedule(constant(0.1)))
    with pytest.warns(UserWarning, match="does not match any fused kind"):
        opt = compile_chain(tx, fused="multi_tensor")
    assert opt.kind is None
    params, grads = make_tree(0), make_tree(1)
    p, s, st = jax.jit(opt.step)(grads, opt.init(params), params)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in jax.tree.leaves(p))


def test_defusion_warning_names_blocking_stage():
    """Satellite guarantee: the fallback warning is actionable — it names
    the exact stage (index + transform) that broke the segment and shows
    the degenerate plan."""
    tx = T.chain(T.scale_by_adam(0.9, 0.999, 1e-8), T.trace(0.9),
                 T.scale_by_schedule(constant(0.1)))
    plan = T.plan_chain(tx)
    assert plan.kind is None
    assert plan.blocker == (0, "scale_by_adam")
    with pytest.warns(UserWarning,
                      match=r"stage 0 \('scale_by_adam'\)") as rec:
        compile_chain(tx, fused="multi_tensor")
    assert "interp:scale_by_adam" in str(rec[0].message)


# ---------------------------------------------------------------------------
# the segment planner: plan shapes, launch accounting, mixed jnp/fused plans
# ---------------------------------------------------------------------------

def test_plan_chain_clip_mid_compiles_with_jnp_prefix():
    """clip at a non-prefix position: the planner peels the stateless
    stages before the matchable tail into jnp nodes and folds the clip
    into the engine tail's coefficient round — 2 launches/bucket, same
    as unclipped msgd."""
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.clip_by_global_norm(5.0), T.trace(0.9),
                 T.scale_by_schedule(SCHED))
    assert T.match_chain(tx) is None          # not a whole-chain shape
    plan = T.plan_chain(tx)
    assert plan.kind == "msgd"
    assert [n.op for n in plan.nodes] == ["jnp", "jnp", "fused"]
    assert plan.fused.arg("clip") == 5.0
    assert plan.launches_per_bucket() == 2
    opt = compile_chain(tx, fused="multi_tensor")
    assert opt.kind == "msgd" and opt.plan.kind == "msgd"


def test_plan_chain_suffix_clip_defers_apply():
    """A trailing clip after the schedule compiles as the deferred-apply
    third pass (3 launches/bucket for sngm)."""
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.trace(0.9), T.scale_by_schedule(SCHED),
                 T.clip_by_global_norm(0.01))
    plan = T.plan_chain(tx)
    assert plan.kind == "sngm_global"
    assert plan.fused.arg("suffix_clip") == 0.01
    assert plan.launches_per_bucket() == 3


def test_plan_chain_ema_becomes_resident_slot():
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.trace(0.9), T.scale_by_schedule(SCHED), T.ema_params(0.99))
    plan = T.plan_chain(tx)
    assert plan.kind == "sngm_global"
    assert plan.slots == ("empty", "empty", "trace", "sched", "ema")
    assert [n.op for n in plan.nodes] == ["fused", "ema"]
    assert plan.launches_per_bucket() == 2    # EMA is elementwise, no launch
    opt = compile_chain(tx, fused="multi_tensor")
    state = opt.init(make_tree(0))
    assert isinstance(state, FlatOptState)
    assert state.form == ("chain", plan.slots)
    assert len(state.e_flats) == 1


def test_plan_launch_accounting_matches_trace():
    """SegmentPlan's static launch annotation == the traced launch count,
    for mixed jnp/fused plans — the IR never drifts from reality."""
    from repro.tracker.counters import launches_per_step, \
        plan_launches_per_step
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    chains = {
        "clip_mid": T.chain(T.add_decayed_weights(1e-4),
                            T.normalize_by_global_norm(),
                            T.clip_by_global_norm(5.0), T.trace(0.9),
                            T.scale_by_schedule(SCHED)),
        "nesterov": T.chain(T.normalize_by_global_norm(),
                            T.trace(0.9, nesterov=True),
                            T.scale_by_schedule(SCHED)),
        "ema": T.chain(T.normalize_by_global_norm(), T.trace(0.9),
                       T.scale_by_schedule(SCHED), T.ema_params(0.99)),
        "suffix_clip": T.chain(T.normalize_by_global_norm(), T.trace(0.9),
                               T.scale_by_schedule(SCHED),
                               T.clip_by_global_norm(0.01)),
    }
    for name, tx in chains.items():
        opt = compile_chain(tx, fused="multi_tensor")
        state = opt.init(params)
        planned = plan_launches_per_step(opt, params)
        traced = launches_per_step(opt, grads, state, params)
        assert planned == traced, (name, planned, traced)


def test_plan_optimizer_rejects_mismatched_chain_form():
    """A FlatOptState restored against a different chain must be refused,
    not silently misinterpreted."""
    tx_a = T.chain(T.normalize_by_global_norm(), T.trace(0.9),
                   T.scale_by_schedule(SCHED), T.ema_params(0.99))
    tx_b = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                   T.clip_by_global_norm(5.0), T.trace(0.9),
                   T.scale_by_schedule(SCHED))
    opt_a = compile_chain(tx_a, fused="multi_tensor")
    opt_b = compile_chain(tx_b, fused="multi_tensor")
    params, grads = make_tree(0), make_tree(1)
    with pytest.raises(TypeError, match="form"):
        opt_b.step(grads, opt_a.init(params), params)


def test_plan_chain_state_interconverts_losslessly():
    """to_pytree on a ('chain', slots) FlatOptState yields the
    interpreter's ChainOptState (momentum + EMA slots in place);
    from_pytree rebuilds the flat form bitwise."""
    from repro.core.optim import from_pytree
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    tx = T.chain(T.normalize_by_global_norm(), T.trace(0.9),
                 T.scale_by_schedule(SCHED), T.ema_params(0.99))
    opt = compile_chain(tx, fused="multi_tensor")
    p, state, _ = jax.jit(opt.step)(grads, opt.init(params), params)
    view = to_pytree(state)
    assert isinstance(view, ChainOptState)
    assert [type(s).__name__ for s in view.inner] == [
        "EmptyState", "TraceState", "ScaleByScheduleState", "EmaParamsState"]
    assert int(view.inner[2].count) == 1
    back = from_pytree(view, p)
    assert back.form == state.form
    assert tree_bitwise_equal(tuple(back.p_flats), tuple(state.p_flats))
    assert tree_bitwise_equal(tuple(back.u_flats), tuple(state.u_flats))
    for ea, eb in zip(back.e_flats, state.e_flats):
        assert tree_bitwise_equal(tuple(ea), tuple(eb))
    # and the interpreter continues from the converted state: the fused
    # optimizer accepts the ChainOptState directly (interpreter fallback)
    p2, s2, _ = opt.step(grads, view, p)
    assert isinstance(s2, ChainOptState)


def test_per_leaf_restricted_to_kinds_with_kernels():
    with pytest.raises(ValueError, match="per_leaf"):
        msgd(constant(0.1), fused="per_leaf")
    with pytest.raises(ValueError, match="norm_mode='global' only"):
        sngm(constant(0.1), norm_mode="per_tensor", fused="per_leaf")


def test_use_pallas_deprecated_but_still_routes():
    with pytest.deprecated_call():
        opt = sngm(constant(0.1), use_pallas=True)
    assert isinstance(opt.init(make_tree(0)), FlatOptState)


# ---------------------------------------------------------------------------
# individual transforms
# ---------------------------------------------------------------------------

def test_clip_by_global_norm_clips_only_above_threshold():
    clip = T.clip_by_global_norm(1.0)
    big = {"w": jnp.full((8,), 10.0)}
    small = {"w": jnp.full((8,), 1e-3)}
    out_b, _, st = clip.update(big, clip.init(big), big)
    np.testing.assert_allclose(float(global_norm(out_b)), 1.0, rtol=1e-6)
    assert float(st["grad_norm"]) > 1.0
    out_s, _, _ = clip.update(small, clip.init(small), small)
    assert tree_bitwise_equal(out_s, small)    # untouched below the bound


def test_nesterov_trace_differs_from_plain():
    g = {"w": jnp.ones((4,))}
    plain, nest = T.trace(0.9), T.trace(0.9, nesterov=True)
    o_p, s_p, _ = plain.update(g, plain.init(g), g)
    o_n, s_n, _ = nest.update(g, nest.init(g), g)
    assert tree_bitwise_equal(s_p.momentum, s_n.momentum)   # same state
    assert not np.allclose(np.asarray(o_p["w"]), np.asarray(o_n["w"]))
    np.testing.assert_allclose(np.asarray(o_n["w"]), 0.9 * 1.0 + 1.0)


def test_decay_coupling_is_positional():
    """Before normalize = coupled (decay gets normalized too); after =
    decoupled (pure shrinkage added to the unit-norm direction)."""
    params = {"w": jnp.full((4,), 100.0)}
    grads = {"w": jnp.full((4,), 1e-3)}
    coupled = T.chain(T.add_decayed_weights(0.1),
                      T.normalize_by_global_norm())
    decoupled = T.chain(T.normalize_by_global_norm(),
                        T.add_decayed_weights(0.1))
    u_c, _, _ = coupled.update(grads, coupled.init(params), params)
    u_d, _, _ = decoupled.update(grads, decoupled.init(params), params)
    # coupled: wd*w dominates the gradient, then everything is normalized
    np.testing.assert_allclose(float(global_norm(u_c)), 1.0, rtol=1e-5)
    # decoupled: unit direction PLUS wd*w => norm ~ ||0.1*100*ones(4)||
    assert float(global_norm(u_d)) > 10.0


def test_ema_params_tracks_parameters():
    ema = T.ema_params(0.5)
    params = {"w": jnp.full((3,), 4.0)}
    grads = {"w": jnp.ones((3,))}
    state = ema.init(params)
    out, state, _ = ema.update(grads, state, params)
    assert tree_bitwise_equal(out, grads)               # passthrough
    np.testing.assert_allclose(np.asarray(state.ema["w"]), 4.0)
    out, state, _ = ema.update(grads, state, {"w": jnp.zeros((3,))})
    np.testing.assert_allclose(np.asarray(state.ema["w"]), 2.0)


def test_chain_flattens_nested_chains():
    tx = T.chain(T.chain(T.add_decayed_weights(1e-4),
                         T.normalize_by_global_norm()),
                 T.chain(T.trace(0.9), T.scale_by_schedule(SCHED)))
    assert tuple(p.name for p in tx.parts) == (
        "add_decayed_weights", "normalize_by_global_norm", "trace",
        "scale_by_schedule")
    assert compile_chain(tx).kind == "sngm_global"


# ---------------------------------------------------------------------------
# novel chain end-to-end through make_train_step (jnp fallback)
# ---------------------------------------------------------------------------

def test_novel_chain_trains_end_to_end():
    from repro.configs import ARCHS, smoke_variant
    from repro.data import SyntheticLM
    from repro.models import CPU_RUNTIME, model_defs
    from repro.models.param import materialize
    from repro.training import make_train_step

    cfg = dataclasses.replace(smoke_variant(ARCHS["gemma-2b"]),
                              vocab_size=64, compute_dtype="float32")
    # clip AFTER normalize is not the canonical prefix position, so this
    # stays a novel (interpreter-run) composition even now that
    # clip-PREFIXED chains compile onto the engine
    tx = chain(T.normalize_by_global_norm(), T.clip_by_global_norm(1.0),
               T.trace(0.9), T.scale_by_schedule(constant(0.5)))
    assert T.match_chain(tx) is None
    opt = as_optimizer(tx)
    assert opt.kind is None
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    state = opt.init(params)
    assert isinstance(state, ChainOptState)

    from repro.core.optim import TrainState
    ts = TrainState(params=params, opt_state=state)
    with count_pallas_launches() as c:
        # the interpreter is pure jnp: zero kernel launches; donated like
        # the production launcher (ChainOptState donates fine too)
        step = jax.jit(make_train_step(cfg, CPU_RUNTIME, tx, n_micro=2),
                       donate_argnums=(0,))
        data = SyntheticLM(cfg.vocab_size, 16, 4, branching=4)
        losses = []
        for t in range(4):
            ts, stats = step(ts, data.batch_at(t))
            losses.append(float(stats["loss"]))
    assert c["launches"] == 0
    assert all(np.isfinite(l) for l in losses), losses
    assert {"grad_norm", "lr", "update_norm", "loss"} <= set(stats)
    assert float(stats["lr"]) == 0.5
    assert int(ts.step) == 4


# ---------------------------------------------------------------------------
# OptimizerSpec serialization
# ---------------------------------------------------------------------------

def test_optimizer_spec_json_round_trip_bit_identical():
    spec = OptimizerSpec("sngm", {
        "beta": 0.9, "weight_decay": 1e-4,
        "schedule": {"name": "poly_power",
                     "kwargs": {"lr0": 0.3, "total_steps": 10,
                                "power": 1.1}}})
    rebuilt = OptimizerSpec.from_json(json.loads(json.dumps(spec.to_json())))
    opt_a = make_optimizer(rebuilt)
    opt_b = sngm(poly_power(0.3, 10, 1.1), beta=0.9, weight_decay=1e-4)
    params, grads = make_tree(0), make_tree(1, scale=3.0)
    p_a, _, _ = _run(opt_a, params, grads, opt_a.init(params))
    p_b, _, _ = _run(opt_b, params, grads, opt_b.init(params))
    assert opt_a.kind == opt_b.kind == "sngm_global"
    assert tree_bitwise_equal(p_a, p_b)


def test_optimizer_spec_validates():
    with pytest.raises(KeyError, match="unknown optimizer"):
        OptimizerSpec("adamw", {"schedule": {"name": "constant",
                                             "kwargs": {"lr": 0.1}}})
    with pytest.raises(ValueError, match="schedule"):
        OptimizerSpec("sngm", {"beta": 0.9})
    with pytest.raises(TypeError, match="no extra arguments"):
        make_optimizer(OptimizerSpec("sngm", {
            "schedule": {"name": "constant", "kwargs": {"lr": 0.1}}}),
            constant(0.1))


def test_registry_and_builder_introspection():
    assert optimizer_names() == ("lamb", "lars", "msgd", "sngd", "sngm")
    assert builder_accepts("sngm", "beta")
    assert not builder_accepts("sngd", "beta")      # pinned to 0 by design
    assert not builder_accepts("lamb", "beta")      # b1/b2 instead
    assert builder_accepts("lamb", "fused")         # accepted, warns+falls back
