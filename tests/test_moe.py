"""MoE correctness: capacity dispatch vs the dense drop-free oracle,
router modes, aux loss, and capacity-drop semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, ModelConfig
from repro.models import moe
from repro.models.param import materialize
from repro.models.runtime import CPU_RUNTIME


def make_cfg(router_mode="softmax_topk", cf=8.0, n_shared=0, E=4, k=2):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=128,
        moe=MoEConfig(n_experts=E, top_k=k, d_expert=96, n_shared=n_shared,
                      capacity_factor=cf, router_mode=router_mode))


def setup(cfg, B=2, S=16, seed=0):
    p = materialize(moe.moe_defs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (B, S, cfg.d_model), jnp.float32)
    return p, x


@pytest.mark.parametrize("router_mode", ["softmax_topk", "topk_softmax"])
@pytest.mark.parametrize("n_shared", [0, 1])
def test_capacity_dispatch_matches_dense_oracle(router_mode, n_shared):
    """With capacity_factor high enough that nothing drops, the scatter/
    gather dispatch must equal computing every expert densely."""
    cfg = make_cfg(router_mode, cf=8.0, n_shared=n_shared)
    p, x = setup(cfg)
    y, aux = moe.moe_apply(p, x, cfg, CPU_RUNTIME)
    yr, auxr = moe.moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(float(aux), float(auxr), rtol=1e-5)


def test_router_weights_normalized_topk_softmax():
    cfg = make_cfg("topk_softmax")
    logits = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.moe.n_experts))
    w, ids, aux = moe.route(logits, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


def test_router_softmax_topk_weights_below_one():
    cfg = make_cfg("softmax_topk")
    logits = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.moe.n_experts))
    w, ids, aux = moe.route(logits, cfg)
    assert np.all(np.asarray(w.sum(-1)) <= 1.0 + 1e-6)
    # ids are distinct per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == len(row)


def test_aux_loss_balanced_is_one():
    """Perfectly uniform router -> switch aux loss == n_experts * (1/E) = 1."""
    cfg = make_cfg()
    logits = jnp.zeros((64, cfg.moe.n_experts))
    _, _, aux = moe.route(logits, cfg)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_capacity_drops_zero_contribution():
    """cf tiny -> dropped tokens contribute 0 from routed experts; the
    output must stay finite and bounded by the no-drop output."""
    cfg = make_cfg(cf=0.05)
    p, x = setup(cfg)
    y, _ = moe.moe_apply(p, x, cfg, CPU_RUNTIME)
    assert np.all(np.isfinite(np.asarray(y)))
    # some token-expert pairs must actually have been dropped
    y_full, _ = moe.moe_ref(p, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y_full))


def test_moe_grads_flow():
    cfg = make_cfg()
    p, x = setup(cfg)

    def loss(p):
        y, aux = moe.moe_apply(p, x, cfg, CPU_RUNTIME)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gnorms = {k: float(jnp.linalg.norm(v)) for k, v in
              jax.tree_util.tree_flatten_with_path(g)[0] and
              [(str(path), jnp.linalg.norm(leaf)) for path, leaf in
               jax.tree_util.tree_flatten_with_path(g)[0]]}
    assert all(np.isfinite(v) for v in gnorms.values())
    assert gnorms["(DictKey(key='router'),)"] > 0  # router receives gradient
