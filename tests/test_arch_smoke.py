"""Per-architecture smoke tests: a REDUCED variant of each assigned arch
(2 layers, d_model<=256, <=4 experts) runs one forward + one train step on
CPU; output shapes and finiteness asserted.  Decode consistency (prefill
vs step-by-step with every cache type) is covered in test_decode.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, input_specs, smoke_variant
from repro.core import sngm
from repro.core.schedules import constant
from repro.models import CPU_RUNTIME, forward, model_defs
from repro.models.param import materialize
from repro.training import make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(ARCHS[name])
            defs = model_defs(cfg)
            params = materialize(defs, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]
    return get


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(built, arch):
    cfg, params = built(arch)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    h, cache, aux = forward(params, cfg, CPU_RUNTIME, batch["tokens"],
                            mode="train",
                            encoder_embeds=batch.get("encoder_embeds"))
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    assert cache is None
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(built, arch):
    cfg, params = built(arch)
    batch = _batch(cfg)
    opt = sngm(constant(0.01), beta=0.9, weight_decay=1e-4)
    state = opt.init_state(params)
    step = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=2))
    new_state, stats = step(state, batch)
    assert np.isfinite(float(stats["loss"]))
    assert float(stats["grad_norm"]) > 0
    assert int(new_state.step) == 1
    # at least one parameter must actually change
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_state.params_view)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_logits_shape(built, arch):
    cfg, params = built(arch)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, cache, _ = forward(params, cfg, CPU_RUNTIME, batch["tokens"],
                               mode="prefill",
                               encoder_embeds=batch.get("encoder_embeds"))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert cache is not None
    assert np.all(np.isfinite(np.asarray(logits)))


def test_smoke_variant_limits():
    for name, cfg in ARCHS.items():
        s = smoke_variant(cfg)
        assert s.d_model <= 512
        assert s.n_layers <= 8
        if s.moe:
            assert s.moe.n_experts <= 4
        # the reduced variant must preserve the family
        assert s.family == cfg.family


def test_input_specs_all_combos():
    for name, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
            if cfg.is_encoder_decoder:
                assert specs["encoder_embeds"].shape[1] == cfg.encoder_len
