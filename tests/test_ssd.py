"""Mamba2 SSD: the chunked training scan must equal the naive recurrence,
and the O(1) decode step must continue a prefix exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.configs import ARCHS, smoke_variant
from repro.models.mamba import ssd_chunked, mamba_block, mamba_defs
from repro.models.param import materialize

KEY = jax.random.PRNGKey(0)


def naive_ssd(x, dt, A, B_, C_):
    """Token-by-token linear recurrence oracle:
    h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T ;  y_t = C_t . h_t"""
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)
    h = jnp.zeros((Bb, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)                    # (B,H)
        xdt = x[:, t] * dt[:, t][..., None]           # (B,H,P)
        h = h * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, Bh[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 48)])
def test_chunked_equals_naive(S, chunk):
    Bb, H, P, G, N = 2, 4, 8, 1, 16
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 2), (Bb, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 3), (H,)) * 0.5)
    B_ = jax.random.normal(jax.random.fold_in(KEY, 4), (Bb, S, G, N))
    C_ = jax.random.normal(jax.random.fold_in(KEY, 5), (Bb, S, G, N))
    y, h = ssd_chunked(x, dt, A, B_, C_, chunk)
    yr, hr = naive_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_block_decode_continues_prefill():
    """mamba_block: run S tokens full, then decode token S with the cache —
    the decode output must equal running S+1 tokens full."""
    cfg = smoke_variant(ARCHS["mamba2-1.3b"])
    p = materialize(mamba_defs(cfg), KEY)
    Bb, S = 2, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (Bb, S + 1, cfg.d_model),
                          jnp.float32)
    y_full, _ = mamba_block(p, x, cfg)
    y_pre, cache = mamba_block(p, x[:, :S], cfg)
    y_dec, _ = mamba_block(p, x[:, S:S + 1], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0], np.float32),
                               np.asarray(y_full[:, S], np.float32), atol=3e-2)


def test_ssd_state_carries_across_chunks():
    """Final state from chunked == state after processing all tokens."""
    Bb, S, H, P, G, N = 1, 64, 2, 4, 1, 8
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (Bb, S, H, P))
    dt = jnp.full((Bb, S, H), 0.1)
    A = -jnp.ones((H,))
    B_ = jax.random.normal(jax.random.fold_in(KEY, 8), (Bb, S, G, N))
    C_ = jax.random.normal(jax.random.fold_in(KEY, 9), (Bb, S, G, N))
    _, h8 = ssd_chunked(x, dt, A, B_, C_, 8)
    _, h16 = ssd_chunked(x, dt, A, B_, C_, 16)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h16), atol=1e-5)
