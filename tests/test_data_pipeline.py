"""Streaming data pipeline (fast lane): pack format, sharded loader,
prefetch, loader-state checkpointing, retention/symlinks, async saves.

The headline guarantees under test:

  * exact-batch deterministic resume — interrupt at step k, save the
    ``LoaderState`` with the checkpoint, resume: batches and losses for
    steps k..n are BITWISE identical to an uninterrupted run, with and
    without prefetch, for fp32 and bf16 resident states;
  * the prefetcher's ``state`` stays exact under run-ahead (it is the
    cursor of the next batch the CONSUMER will see, not the loader's);
  * async saves never block on commit I/O (verified with a delayed
    commit thread) and re-raise background failures;
  * retention prunes only committed ``step_*`` siblings and never a
    symlink target.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, is_committed,
                              load_checkpoint, load_loader_state,
                              resolve_checkpoint, save_checkpoint, step_dir)
from repro.data import (DataPackWriter, DiskShardedSource, LoaderState,
                        MemorySource, PrefetchIterator, StreamingLoader,
                        SyntheticLM, n_examples, pack_dataset)


def _arrays(n, seq=8, seed=0):
    rng = np.random.RandomState(seed)
    return {"tokens": rng.randint(0, 100, size=(n, seq)).astype(np.int32),
            "loss_mask": np.ones((n, seq), np.float32)}


def _batches(loader, k):
    return [next(loader) for _ in range(k)]


def _assert_batch_equal(a, b):
    assert sorted(a) == sorted(b)
    for f in a:
        np.testing.assert_array_equal(np.asarray(a[f]), np.asarray(b[f]))


# ---------------------------------------------------------------- format

def test_pack_roundtrip_including_extension_dtypes(tmp_path):
    arrays = _arrays(40)
    arrays["emb"] = np.asarray(
        jnp.arange(40 * 3, dtype=jnp.bfloat16).reshape(40, 3))
    path = str(tmp_path / "ds")
    pack_dataset(path, arrays, shard_size=16, meta={"kind": "test"})
    src = DiskShardedSource(path)
    assert src.shard_lengths() == (16, 16, 8)
    assert n_examples(src) == 40
    assert src.meta["kind"] == "test"
    assert set(src.fields) == {"tokens", "loss_mask", "emb"}
    got = src.read(1, 4, 10)
    assert got["emb"].dtype == jnp.bfloat16      # dtype sidecar view-back
    for f in arrays:
        np.testing.assert_array_equal(np.asarray(got[f]),
                                      np.asarray(arrays[f][20:30]))
    src.close()


def test_index_is_the_commit_marker(tmp_path):
    path = str(tmp_path / "ds")
    pack_dataset(path, _arrays(8), shard_size=4)
    os.remove(os.path.join(path, "dataset.json"))
    with pytest.raises(FileNotFoundError, match="not a packed dataset"):
        DiskShardedSource(path)


def test_pack_refuses_existing_dataset(tmp_path):
    path = str(tmp_path / "ds")
    pack_dataset(path, _arrays(8), shard_size=4)
    with pytest.raises(ValueError):
        DataPackWriter(path, shard_size=4)


# ---------------------------------------------------------------- loader

def test_loader_deterministic_and_seed_sensitive(tmp_path):
    src = MemorySource(_arrays(48), shard_size=8)
    a = _batches(StreamingLoader(src, 8, seed=1), 10)
    b = _batches(StreamingLoader(src, 8, seed=1), 10)
    c = _batches(StreamingLoader(src, 8, seed=2), 10)
    for x, y in zip(a, b):
        _assert_batch_equal(x, y)
    assert any(not np.array_equal(x["tokens"], y["tokens"])
               for x, y in zip(a, c))


def test_loader_seek_is_bitwise(tmp_path):
    src = MemorySource(_arrays(48), shard_size=8)
    loader = StreamingLoader(src, 8, seed=3)
    states, batches = [], []
    for _ in range(12):                     # crosses an epoch boundary
        states.append(loader.state)
        batches.append(next(loader))
    for k in (0, 3, 7, 11):
        replay = StreamingLoader(src, 8, seed=3, state=states[k])
        for want in batches[k:]:
            _assert_batch_equal(next(replay), want)


def test_loader_state_serializes(tmp_path):
    st = LoaderState(epoch=2, shard_cursor=5, offset=3, key=(7, 9))
    rt = LoaderState.from_dict(json.loads(json.dumps(st.to_dict())))
    assert rt == st
    with pytest.raises(ValueError):
        LoaderState.from_dict({"epoch": 0})


def test_loader_drops_epoch_tail_and_bounds_epochs():
    src = MemorySource(_arrays(10), shard_size=5)
    loader = StreamingLoader(src, 4, shuffle=False, max_epochs=1)
    assert loader.batches_per_epoch() == 2
    got = _batches(loader, 2)
    assert all(b["tokens"].shape == (4, 8) for b in got)
    with pytest.raises(StopIteration):      # 2 full batches, tail dropped
        next(loader)


def test_loader_per_process_sharding_covers_globally():
    arrays = _arrays(32)
    src = MemorySource(arrays, shard_size=4)   # 8 shards, round-robin
    parts = [StreamingLoader(src, 8, shuffle=False,
                             process_index=p, process_count=2)
             for p in (0, 1)]
    assert all(lo.local_batch == 4 for lo in parts)
    seen = []
    for _ in range(4):                      # one epoch = 32/8 batches
        for lo in parts:
            seen.append(next(lo)["tokens"])
    seen = np.concatenate(seen, axis=0)
    # global coverage: every example exactly once per epoch
    want = arrays["tokens"]
    assert seen.shape == want.shape
    seen_sorted = seen[np.lexsort(seen.T[::-1])]
    want_sorted = want[np.lexsort(want.T[::-1])]
    np.testing.assert_array_equal(seen_sorted, want_sorted)


def test_loader_validates_shape_contract():
    src = MemorySource(_arrays(16), shard_size=4)
    with pytest.raises(ValueError):         # global batch % P != 0
        StreamingLoader(src, 5, process_index=0, process_count=2)
    with pytest.raises(ValueError):         # epoch smaller than local batch
        StreamingLoader(MemorySource(_arrays(4), shard_size=4), 8)


# -------------------------------------------------------------- prefetch

def test_prefetch_bitwise_and_state_exact():
    src = MemorySource(_arrays(48), shard_size=8)
    sync = StreamingLoader(src, 8, seed=5)
    sync_batches, sync_states = [], []
    for _ in range(9):
        sync_batches.append(next(sync))
        sync_states.append(sync.state)      # cursor AFTER consuming t
    with PrefetchIterator(StreamingLoader(src, 8, seed=5),
                          depth=3, place=None) as pf:
        for t in range(9):
            _assert_batch_equal(next(pf), sync_batches[t])
            # run-ahead must not leak into the exposed cursor
            assert pf.state == sync_states[t]
        c = pf.counters()
    assert c["prefetch_batches"] == 9
    assert c["prefetch_depth"] == 3


def test_prefetch_propagates_source_errors():
    class Exploding:
        def shard_lengths(self):
            return (16,)

        def read(self, shard, start, count):
            if start >= 8:
                raise RuntimeError("disk on fire")
            return _arrays(count)

    pf = PrefetchIterator(StreamingLoader(Exploding(), 4, shuffle=False),
                          depth=2, place=None)
    got = _batches(pf, 2)
    assert len(got) == 2
    with pytest.raises(RuntimeError, match="disk on fire"):
        for _ in range(4):
            next(pf)
    pf.close()


class _ExplodeNow:
    """Source whose very first read raises — the worker dies before
    delivering a single batch."""

    def shard_lengths(self):
        return (16,)

    def read(self, shard, start, count):
        raise RuntimeError("disk on fire")


def test_prefetch_close_surfaces_undelivered_failure_exactly_once():
    """close() before the consumer saw the worker's error: the drain used
    to throw the _Failure away with the buffered batches.  It must now
    re-raise it exactly once; a second close() is a no-op and next()
    terminates instead of hanging."""
    pf = PrefetchIterator(StreamingLoader(_ExplodeNow(), 4, shuffle=False),
                          depth=2, place=None)
    pf._thread.join(timeout=10)          # worker parks the failure and dies
    assert not pf._thread.is_alive()
    with pytest.raises(RuntimeError, match="disk on fire"):
        pf.close()
    pf.close()                           # idempotent: no second raise
    with pytest.raises(StopIteration):   # and no hang on the dead queue
        next(pf)


def test_prefetch_next_never_hangs_after_close():
    """A consumer that keeps iterating after close() must get a clean
    StopIteration promptly (the old blocking get() hung forever once the
    worker was gone and the queue empty)."""
    src = MemorySource(_arrays(32), shard_size=8)
    pf = PrefetchIterator(StreamingLoader(src, 8, seed=5), depth=2,
                          place=None)
    next(pf)
    pf.close()
    t0 = time.perf_counter()
    with pytest.raises(StopIteration):
        next(pf)
    assert time.perf_counter() - t0 < 5.0
    pf.close()                           # still idempotent


def test_prefetch_error_raised_via_next_not_raised_again_by_close():
    """When next() already delivered the worker's error, close() must not
    raise it a second time."""
    pf = PrefetchIterator(StreamingLoader(_ExplodeNow(), 4, shuffle=False),
                          depth=2, place=None)
    with pytest.raises(RuntimeError, match="disk on fire"):
        _batches(pf, 4)
    pf.close()                           # error already surfaced: no raise
    with pytest.raises(StopIteration):
        next(pf)


# ------------------------------------------- loader state in checkpoints

def test_checkpoint_carries_loader_state(tmp_path):
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    st = LoaderState(epoch=1, shard_cursor=2, offset=7, key=(3, 4))
    path = str(tmp_path / "ck")
    save_checkpoint(path, tree, step=9, loader_state=st)
    meta = json.load(open(os.path.join(path, "meta.json")))
    assert meta["format"] == 3
    assert LoaderState.from_dict(load_loader_state(path)) == st


def test_checkpoint_without_loader_state_reports_none(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": jnp.zeros(2)}, step=1)
    assert load_loader_state(path) is None  # format-2-era behavior


# -------------------------------------------- retention/symlinks/resolve

def test_retention_prunes_only_committed_step_dirs(tmp_path):
    base = str(tmp_path)
    tree = {"w": jnp.arange(3, dtype=jnp.float32)}
    os.makedirs(tmp_path / "not_a_ckpt")    # innocent sibling
    (tmp_path / "not_a_ckpt" / "data.txt").write_text("keep me")
    for s in (1, 2, 3, 4):
        save_checkpoint(step_dir(base, s), tree, s, keep_last_n=2)
    names = sorted(os.listdir(base))
    assert "not_a_ckpt" in names
    steps = [n for n in names if n.startswith("step_")]
    assert steps == ["step_00000003", "step_00000004"]
    assert os.readlink(os.path.join(base, "latest")) == "step_00000004"


def test_best_symlink_tracks_lowest_metric_and_survives_pruning(tmp_path):
    base = str(tmp_path)
    tree = {"w": jnp.arange(3, dtype=jnp.float32)}
    for s, m in [(1, 3.0), (2, 1.5), (3, 2.0), (4, 1.9), (5, 1.8)]:
        save_checkpoint(step_dir(base, s), tree, s, keep_last_n=2, metric=m)
    assert os.readlink(os.path.join(base, "best")) == "step_00000002"
    steps = sorted(n for n in os.listdir(base) if n.startswith("step_"))
    # newest two plus the (older) best target survive
    assert steps == ["step_00000002", "step_00000004", "step_00000005"]
    assert json.load(open(os.path.join(
        base, "step_00000002", "meta.json")))["metric"] == 1.5


def test_resolve_checkpoint_layouts(tmp_path):
    tree = {"w": jnp.zeros(2)}
    direct = str(tmp_path / "direct")
    save_checkpoint(direct, tree)
    assert resolve_checkpoint(direct) == direct
    base = str(tmp_path / "family")
    save_checkpoint(step_dir(base, 3), tree, 3, keep_last_n=0)
    save_checkpoint(step_dir(base, 7), tree, 7, keep_last_n=0)
    assert resolve_checkpoint(base) == os.path.join(base, "step_00000007")
    os.remove(os.path.join(base, "latest"))  # no symlink: newest committed
    assert resolve_checkpoint(base) == os.path.join(base, "step_00000007")
    missing = str(tmp_path / "nope")
    assert resolve_checkpoint(missing) == missing


# ------------------------------------------------------------ async save

def test_async_save_never_blocks_on_commit(tmp_path):
    """The commit thread is artificially delayed; save() must still
    return in device->host-copy time, and the checkpoint must not be
    committed until the background thread finishes."""
    tree = {"w": jnp.arange(1024, dtype=jnp.float32)}
    path = str(tmp_path / "ck")
    with AsyncCheckpointer(commit_delay_s=0.4) as ac:
        t0 = time.perf_counter()
        ac.save(path, tree, step=5)
        assert time.perf_counter() - t0 < 0.2   # not the 0.4s commit
        assert not is_committed(path)
        ac.wait()
        assert is_committed(path)
    restored, step = load_checkpoint(path, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_async_save_reraises_background_failure(tmp_path):
    bad = tmp_path / "not_ckpt"
    bad.mkdir()
    (bad / "something.txt").write_text("user data")
    ac = AsyncCheckpointer()
    ac.save(str(bad), {"w": jnp.zeros(2)})      # will refuse to clobber
    with pytest.raises(ValueError, match="refusing to overwrite"):
        ac.wait()
    ac.close()
    assert (bad / "something.txt").read_text() == "user data"


def test_async_saves_commit_in_order(tmp_path):
    base = str(tmp_path)
    with AsyncCheckpointer() as ac:
        for s in (1, 2, 3):
            ac.save(step_dir(base, s), {"w": jnp.full((2,), float(s))},
                    step=s, keep_last_n=0)
    assert os.readlink(os.path.join(base, "latest")) == "step_00000003"


# ------------------------------------------------------- run_steps shape

def test_run_steps_accepts_iterator_and_step_hook():
    from repro.training import run_steps

    def step_fn(state, batch):
        return state + batch, {"loss": float(batch)}

    hooks = []
    out = run_steps(step_fn, 0, iter([1, 2, 3, 4]), 10,
                    step_hook=lambda t, s: hooks.append((t, s)))
    assert out == 10                # stopped at exhaustion, not n_steps
    assert hooks == [(0, 1), (1, 3), (2, 6), (3, 10)]

    out = run_steps(step_fn, 0, lambda t: t, 4)   # batch_at form unchanged
    assert out == 6


# -------------------------------- exact-batch bitwise resume (tentpole)

def _toy_setup(dtype):
    """A tiny embedding model on the resident fused path: enough to make
    'bitwise resume' a statement about the REAL TrainState machinery."""
    from repro.core import sngm
    from repro.core.schedules import poly_power

    opt = sngm(poly_power(0.5, 16, 1.1), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor")
    params = {"emb": (jax.random.normal(jax.random.PRNGKey(0), (100, 8))
                      .astype(dtype))}

    def loss_fn(p, batch):
        h = p["emb"][batch["tokens"]].astype(jnp.float32)
        return jnp.mean(h * batch["loss_mask"][..., None])

    grad = jax.value_and_grad(loss_fn)

    def step(ts, batch):
        l, g = grad(ts.params_view, batch)
        ts, stats = opt.step_state(g, ts)
        return ts, {**stats, "loss": l}

    return opt, params, jax.jit(step, donate_argnums=(0,))


def _toy_batches(prefetch):
    loader = StreamingLoader(MemorySource(_arrays(64), shard_size=8),
                             8, seed=11)
    if prefetch:
        return PrefetchIterator(loader, depth=prefetch, place=None)
    return loader


@pytest.mark.parametrize("prefetch", [0, 2], ids=["sync", "prefetch"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
def test_exact_batch_resume_is_bitwise(tmp_path, prefetch, dtype):
    """Interrupt at step 4 of 8, checkpoint {state, loader cursor},
    resume: losses 4..8 and the final params must be BITWISE equal to an
    uninterrupted run — resident fused state, fp32 and bf16."""
    from repro.core import TrainState, from_pytree, to_pytree

    opt, params, step = _toy_setup(dtype)
    n, k = 8, 4
    path = str(tmp_path / "ck")

    def fresh_ts():
        # the launcher idiom: opt.init + TrainState.wrap (resident flats
        # take ownership of the params on the fused path)
        p = jax.tree.map(jnp.copy, params)
        return TrainState.wrap(p, opt.init(p))

    # uninterrupted reference
    it = _toy_batches(prefetch)
    ts = fresh_ts()
    ref_losses = []
    for _ in range(n):
        ts, stats = step(ts, next(it))
        ref_losses.append(float(stats["loss"]))
    ref_emb = np.asarray(jax.device_get(ts.params_view["emb"]))

    # interrupted at k: save state + the iterator's post-step cursor
    it = _toy_batches(prefetch)
    ts = fresh_ts()
    for _ in range(k):
        ts, stats = step(ts, next(it))
    save_checkpoint(path, {"params": ts.params_view,
                           "opt": to_pytree(ts.opt_state)},
                    step=k, loader_state=it.state)
    if prefetch:
        it.close()

    # resume: restore both, re-seek, run k..n
    p0 = jax.tree.map(jnp.copy, params)
    like = {"params": p0, "opt": to_pytree(opt.init(p0))}
    restored, got_k = load_checkpoint(path, like)
    assert got_k == k
    ls = LoaderState.from_dict(load_loader_state(path))
    loader = StreamingLoader(MemorySource(_arrays(64), shard_size=8),
                             8, seed=11, state=ls)
    it = (PrefetchIterator(loader, depth=prefetch, place=None)
          if prefetch else loader)
    ts = TrainState.wrap(restored["params"],
                         from_pytree(restored["opt"], restored["params"]))
    res_losses = []
    for _ in range(k, n):
        ts, stats = step(ts, next(it))
        res_losses.append(float(stats["loss"]))
    if prefetch:
        it.close()
    assert res_losses == ref_losses[k:]     # bitwise, not approx
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(ts.params_view["emb"])), ref_emb)


def test_launcher_disk_resume_bitwise_with_async_family(tmp_path):
    """End-to-end --data-dir + --prefetch + --save-every + --async-save
    + --keep-last-n: the resumed segment's losses equal the
    uninterrupted run's BITWISE, resume resolves the step family via
    `latest`, and retention holds."""
    from repro.configs import get_config, smoke_variant
    from repro.launch.train import main as train_main

    cfg = smoke_variant(get_config("gemma-2b"))
    src = SyntheticLM(cfg.vocab_size, 16, 1, epoch_examples=256, n_shards=4)
    ds = str(tmp_path / "ds")
    with DataPackWriter(ds, shard_size=64,
                        meta={"vocab_size": cfg.vocab_size,
                              "seq_len": 16}) as w:
        for s in range(4):
            w.add(src.read(s, 0, 64))

    def run(extra):
        return train_main(
            ["--arch", "gemma-2b", "--reduced", "--batch", "4", "--seq",
             "16", "--n-micro", "1", "--optimizer", "sngm", "--fused",
             "multi_tensor", "--lr", "0.5", "--total-steps", "8",
             "--log-every", "100", "--data-dir", ds, "--prefetch", "2"]
            + extra)

    full = run(["--steps", "8"])
    base = str(tmp_path / "ck")
    part1 = run(["--steps", "4", "--ckpt", base, "--save-every", "2",
                 "--keep-last-n", "2", "--async-save"])
    assert part1 == full[:4]                     # bitwise
    assert os.readlink(os.path.join(base, "latest")) == "step_00000004"

    resumed = run(["--steps", "8", "--ckpt", base, "--resume"])
    assert resumed == full[4:]                   # bitwise across the seam
    steps = sorted(n for n in os.listdir(base) if n.startswith("step_"))
    assert steps[-1] == "step_00000008"          # joined the family
