"""Serving-layer behaviour: greedy generation, cache padding, and the
continuous-batching scheduler (launch/serve.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.configs import ARCHS, smoke_variant
from repro.models import CPU_RUNTIME, forward, model_defs
from repro.models.param import materialize
from repro.serving import greedy_generate


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                              compute_dtype="float32")
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_greedy_generate_matches_manual_argmax(setup):
    """Greedy generation must equal manually re-running teacher-forced
    prefills and taking argmax each step."""
    cfg, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size, jnp.int32)
    out = greedy_generate(cfg, CPU_RUNTIME, params, prompt, max_new=4)
    seq = prompt
    for i in range(4):
        logits, _, _ = forward(params, cfg, CPU_RUNTIME, seq, mode="prefill")
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, i]), np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_continuous_batcher_outputs_match_sequential(setup):
    """Slot-spliced continuous batching must produce the same tokens as
    serving each request alone."""
    from repro.launch.serve import ContinuousBatcher, Request
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 8)), jnp.int32)
               for _ in range(3)]
    max_new = 4

    # reference: each alone
    refs = [np.asarray(greedy_generate(cfg, CPU_RUNTIME, params, p,
                                       max_new=max_new))[0]
            for p in prompts]

    b = ContinuousBatcher(cfg, params, n_slots=2, ctx_len=8 + max_new)
    queue = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    done = {}
    guard = 0
    while (queue or any(s is not None for s in b.slots)) and guard < 50:
        guard += 1
        for s in b.free_slots():
            if queue:
                b._admit(queue.pop(0), s)
        if any(s is not None for s in b.slots):
            before = [(i, r) for i, r in enumerate(b.slots) if r]
            b.decode_step()
            for i, r in before:
                if r.done:
                    done[r.rid] = r.out[:max_new]
    assert len(done) == 3, done.keys()
    for rid, ref in enumerate(refs):
        np.testing.assert_array_equal(np.asarray(done[rid]), ref,
                                      err_msg=f"request {rid}")
