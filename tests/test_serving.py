"""Serving-layer behaviour: greedy generation, cache padding, and the
continuous-batching scheduler (launch/serve.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the tier-1 fast lane

from repro.configs import ARCHS, smoke_variant
from repro.models import CPU_RUNTIME, forward, model_defs
from repro.models.param import materialize
from repro.serving import greedy_generate


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                              compute_dtype="float32")
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_greedy_generate_matches_manual_argmax(setup):
    """Greedy generation must equal manually re-running teacher-forced
    prefills and taking argmax each step."""
    cfg, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size, jnp.int32)
    out = greedy_generate(cfg, CPU_RUNTIME, params, prompt, max_new=4)
    seq = prompt
    for i in range(4):
        logits, _, _ = forward(params, cfg, CPU_RUNTIME, seq, mode="prefill")
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, i]), np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_continuous_batcher_outputs_match_sequential(setup):
    """Slot-spliced continuous batching must produce the same tokens as
    serving each request alone."""
    from repro.launch.serve import ContinuousBatcher, Request
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 8)), jnp.int32)
               for _ in range(3)]
    max_new = 4

    # reference: each alone
    refs = [np.asarray(greedy_generate(cfg, CPU_RUNTIME, params, p,
                                       max_new=max_new))[0]
            for p in prompts]

    b = ContinuousBatcher(cfg, params, n_slots=2, ctx_len=8 + max_new)
    queue = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    done = {}
    guard = 0
    while (queue or any(s is not None for s in b.slots)) and guard < 50:
        guard += 1
        for s in b.free_slots():
            if queue:
                b._admit(queue.pop(0), s)
        if any(s is not None for s in b.slots):
            before = [(i, r) for i, r in enumerate(b.slots) if r]
            b.decode_step()
            for i, r in before:
                if r.done:
                    done[r.rid] = r.out[:max_new]
    assert len(done) == 3, done.keys()
    for rid, ref in enumerate(refs):
        np.testing.assert_array_equal(np.asarray(done[rid]), ref,
                                      err_msg=f"request {rid}")


# ---------------------------------------------------------------------------
# paged KV cache: bitwise parity with the dense engine
# ---------------------------------------------------------------------------

# full attention, GQA+window+softcap, MLA, and hybrid Mamba2+attention
PAGED_ZOO = ["deepseek-7b", "yi-9b", "gemma2-27b", "deepseek-v2-lite-16b",
             "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch", PAGED_ZOO)
def test_paged_decode_bitwise_matches_dense(arch):
    """Step-by-step decode logits through the paged cache must be
    BITWISE equal to the dense engine's at matched geometry (dense
    context == gathered length nbmax*block_size): the gathered view is
    position-ordered like the unrotated dense cache and masked entries
    contribute exactly 0 after exp underflow.  Mamba2 state rides along
    unpaged and must stay bitwise too."""
    from repro.serving.engine import make_prefill_step, make_serve_step, pad_cache
    from repro.serving import paged_cache as pc
    cfg = dataclasses.replace(smoke_variant(ARCHS[arch]),
                              compute_dtype="float32")
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    prefill = make_prefill_step(cfg, CPU_RUNTIME)
    step = make_serve_step(cfg, CPU_RUNTIME)
    rng = np.random.RandomState(0)
    B, S0, max_new, bs = 2, 9, 7, 4
    prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S0)), jnp.int32)
    nbmax = pc.n_blocks_for(S0 + max_new, bs)
    T = nbmax * bs

    logits, dense = prefill(params, prompt)
    dense = pad_cache(dense, T - S0)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    dense_logits = []
    pos = jnp.full((B,), S0, jnp.int32)
    for _ in range(max_new - 1):
        tok, lg, dense = step(params, dense, tok[:, None], pos)
        dense_logits.append(lg)
        pos = pos + 1

    paged = pc.paged_cache_init(cfg, B, bs, n_blocks=32, nbmax=nbmax)
    alloc = pc.BlockAllocator(32, bs)
    _, dense2 = prefill(params, prompt)
    for row in range(B):
        ids = [alloc.alloc() for _ in range(nbmax)]
        paged = pc.set_block_table(paged, row, ids)
        paged = pc.splice_prefill(paged, dense2, row, row, ids)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((B,), S0, jnp.int32)
    for i in range(max_new - 1):
        tok, lg, paged = step(params, paged, tok[:, None], pos)
        np.testing.assert_array_equal(np.asarray(lg),
                                      np.asarray(dense_logits[i]),
                                      err_msg=f"{arch} step {i}")
        pos = pos + 1


def test_cache_batch_axes_structural():
    """Explicit batch-axis metadata must locate the request axis on every
    leaf — including stacked-period and Mamba state leaves where the old
    first-size-1-axis sniffing could guess wrong."""
    from repro.serving.engine import cache_abstract, cache_batch_axes
    for arch in ["gemma2-27b", "jamba-1.5-large-398b"]:
        cfg = smoke_variant(ARCHS[arch])
        axes = cache_batch_axes(cfg)
        ab = cache_abstract(cfg, 5, 4)
        def chk(l, ax):
            assert l.shape[ax] == 5, (l.shape, ax)
        jax.tree.map(chk, ab, axes)


# ---------------------------------------------------------------------------
# scheduler: end-to-end tokens, preemption, determinism
# ---------------------------------------------------------------------------


def test_scheduler_tokens_match_greedy(setup):
    """Scheduler output (bucket-padded group prefill + chunked decode +
    COW sharing) must equal per-request greedy generation exactly."""
    from repro.serving.scheduler import PagedScheduler, ServeRequest
    cfg, params = setup
    rng = np.random.RandomState(0)
    max_new, ctx_max = 7, 32
    prompts = [rng.randint(0, cfg.vocab_size,
                           (rng.randint(4, 10),)).astype(np.int32)
               for _ in range(5)]
    prompts.append(prompts[0].copy())        # identical prompt: COW path
    refs = {i: np.asarray(greedy_generate(
                cfg, CPU_RUNTIME, params, jnp.asarray(p)[None],
                max_new=ctx_max - len(p)))[0][:max_new]
            for i, p in enumerate(prompts)}

    sched = PagedScheduler(cfg, params, CPU_RUNTIME, n_slots=3, block_size=4,
                           n_blocks=64, ctx_max=ctx_max, decode_chunk=3,
                           buckets=[8, 16, 32])
    for i, p in enumerate(prompts):
        sched.submit(ServeRequest(rid=i, prompt=p, max_new=max_new))
    finished = sched.run()
    assert sorted(r.rid for r in finished) == list(range(6))
    for r in finished:
        np.testing.assert_array_equal(np.asarray(r.out), refs[r.rid],
                                      err_msg=f"request {r.rid}")
    # bounded compiles: one prefill per bucket, one decode shape
    assert sched.compile_counts()["prefill"] <= len({8, 16, 32})
    assert sched.compile_counts()["decode"] == 1
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0      # no leaked blocks


def test_scheduler_preemption_requeues_and_recovers(setup):
    """With a pool too small for all requests at once, the scheduler
    must preempt (release + requeue), still produce exact greedy tokens
    for every request, and leak nothing."""
    from repro.serving.scheduler import PagedScheduler, ServeRequest
    cfg, params = setup
    rng = np.random.RandomState(0)
    max_new, ctx_max = 24, 32
    prompts = [rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(4)]
    refs = [np.asarray(greedy_generate(cfg, CPU_RUNTIME, params,
                                       jnp.asarray(p)[None],
                                       max_new=max_new))[0]
            for p in prompts]
    # 4 requests need 8 blocks each at full length; give only 20
    sched = PagedScheduler(cfg, params, CPU_RUNTIME, n_slots=4, block_size=4,
                           n_blocks=21, ctx_max=ctx_max, decode_chunk=4)
    for i, p in enumerate(prompts):
        sched.submit(ServeRequest(rid=i, prompt=p, max_new=max_new))
    finished = sched.run()
    assert sched.stats["preemptions"] > 0
    assert sorted(r.rid for r in finished) == list(range(4))
    for r in finished:
        np.testing.assert_array_equal(np.asarray(r.out), refs[r.rid],
                                      err_msg=f"request {r.rid}")
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0


def test_scheduler_sampling_deterministic_under_seed(setup):
    from repro.serving.scheduler import PagedScheduler, ServeRequest
    cfg, params = setup
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(4)]

    def run(seed):
        s = PagedScheduler(cfg, params, CPU_RUNTIME, n_slots=2, block_size=4,
                           n_blocks=32, ctx_max=16, decode_chunk=2,
                           temperature=0.8, top_k=20, seed=seed)
        for i, p in enumerate(prompts):
            s.submit(ServeRequest(rid=i, prompt=p, max_new=6))
        return {r.rid: list(r.out) for r in s.run()}

    assert run(7) == run(7)
    assert run(7) != run(8)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def test_serve_step_temperature_zero_is_bitwise_greedy(setup):
    """temperature=0 must reproduce the historical greedy step exactly,
    rng or not."""
    from repro.serving.engine import make_prefill_step, make_serve_step, pad_cache
    cfg, params = setup
    prefill = make_prefill_step(cfg, CPU_RUNTIME)
    greedy = make_serve_step(cfg, CPU_RUNTIME)
    tempered = make_serve_step(cfg, CPU_RUNTIME, temperature=0.0, top_k=5)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                cfg.vocab_size, jnp.int32)
    logits, cache = prefill(params, prompt)
    cache = pad_cache(cache, 4)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    t1, l1, _ = greedy(params, cache, tok[:, None], pos)
    t2, l2, _ = tempered(params, cache, tok[:, None], pos,
                         jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_sample_logits_top_k_membership_and_determinism():
    from repro.serving.engine import sample_logits
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 3
    topk = jax.lax.top_k(logits, 5)[1]
    for i in range(8):
        s = sample_logits(logits, jax.random.PRNGKey(i), temperature=0.9,
                          top_k=5)
        for b in range(4):
            assert int(s[b]) in np.asarray(topk[b])
    a = sample_logits(logits, jax.random.PRNGKey(1), 0.7, 10)
    b = sample_logits(logits, jax.random.PRNGKey(1), 0.7, 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
