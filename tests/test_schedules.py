"""Schedule edge cases (fast lane) + the declarative schedule registry.

The boundary/clamping behaviours here are the ones the training loop
actually hits: the first post-warm-up step, milestone-free step decay,
and schedules evaluated at or past their horizon (which --resume with a
shorter remaining segment does every run).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedules import (
    SCHEDULES, constant, cosine, make_schedule, poly_power, schedule_names,
    step_decay, warmup)


# ---------------------------------------------------------------------------
# warmup boundary
# ---------------------------------------------------------------------------

def test_warmup_boundary_hands_off_exactly_at_warmup_steps():
    """step == warmup_steps must evaluate the BASE schedule (the where()
    branch flips), and agree bit-exactly with the warm ramp's endpoint —
    no lr discontinuity at the hand-off."""
    base = poly_power(2.4, 100, 1.1)
    s = warmup(base, 5, init_lr=0.1)
    at = float(s(jnp.int32(5)))
    assert at == float(base(jnp.int32(5)))
    # one step before: still on the ramp, strictly between init and target
    before = float(s(jnp.int32(4)))
    assert 0.1 < before < at or 0.1 > before > at


def test_warmup_zero_steps_is_base_everywhere():
    base = constant(1.3)
    s = warmup(base, 0, init_lr=0.0)
    for t in (0, 1, 7):
        assert float(s(jnp.int32(t))) == pytest.approx(1.3)


def test_warmup_step_zero_starts_at_init_lr():
    s = warmup(constant(2.0), 10, init_lr=0.25)
    assert float(s(jnp.int32(0))) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# step_decay
# ---------------------------------------------------------------------------

def test_step_decay_empty_milestones_is_constant():
    s = step_decay(0.1, [])
    for t in (0, 1, 1000):
        assert float(s(jnp.int32(t))) == pytest.approx(0.1)


def test_step_decay_at_milestone_applies_factor():
    s = step_decay(1.0, [10], factor=0.5)
    assert float(s(jnp.int32(9))) == pytest.approx(1.0)
    assert float(s(jnp.int32(10))) == pytest.approx(0.5)   # >= milestone


# ---------------------------------------------------------------------------
# horizon clamping (t >= T)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [100, 101, 10_000])
def test_poly_power_clamps_at_and_past_horizon(t):
    s = poly_power(1.6, 100, 1.1)
    v = float(s(jnp.int32(t)))
    assert v == 0.0 and np.isfinite(v)    # clipped frac: never negative/NaN


@pytest.mark.parametrize("t", [100, 150])
def test_cosine_clamps_to_final_frac_past_horizon(t):
    s = cosine(2.0, 100, final_frac=0.1)
    assert float(s(jnp.int32(t))) == pytest.approx(0.2, rel=1e-6)


def test_poly_power_full_lr_at_step_zero():
    assert float(poly_power(1.6, 100, 1.1)(jnp.int32(0))) == pytest.approx(1.6)


# ---------------------------------------------------------------------------
# registry / declarative specs (what OptimizerSpec serializes)
# ---------------------------------------------------------------------------

def test_registry_covers_all_schedules():
    assert schedule_names() == ("constant", "cosine", "poly_power",
                                "step_decay", "warmup")
    assert all(callable(b) for b in SCHEDULES.values())


def test_make_schedule_builds_equivalent_schedule():
    spec = {"name": "poly_power",
            "kwargs": {"lr0": 1.6, "total_steps": 100, "power": 1.1}}
    s, ref = make_schedule(spec), poly_power(1.6, 100, 1.1)
    for t in (0, 37, 100, 200):
        assert float(s(jnp.int32(t))) == float(ref(jnp.int32(t)))


def test_make_schedule_nested_warmup():
    spec = {"name": "warmup",
            "kwargs": {"warmup_steps": 5, "init_lr": 0.1,
                       "base": {"name": "constant", "kwargs": {"lr": 2.4}}}}
    s = make_schedule(spec)
    assert float(s(jnp.int32(0))) == pytest.approx(0.1)
    assert float(s(jnp.int32(5))) == pytest.approx(2.4)


def test_make_schedule_unknown_name():
    with pytest.raises(KeyError, match="unknown schedule"):
        make_schedule({"name": "linear_tri", "kwargs": {}})
