"""Sharding-rule resolution and roofline/HLO-cost unit tests (no mesh >1
needed — pure logic)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.hlo_cost import analyze, _shape_bytes
from repro.models import model_defs
from repro.models.param import ParamDef, abstract, logical_axes
from repro.sharding.rules import spec_for, param_specs, batch_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisibility_guard():
    # gemma-2b: 8 heads cannot shard over model=16 -> replicated
    assert spec_for((2048, 8, 256), ("embed", "heads", "head_dim"), MESH) \
        == P("data", None, None)
    # yi-9b: 32 heads shard fine
    assert spec_for((4096, 32, 128), ("embed", "heads", "head_dim"), MESH) \
        == P("data", "model", None)


def test_axis_exclusivity():
    # experts takes "data"; embed then cannot reuse it
    assert spec_for((160, 5120, 1536), ("experts", "embed", "ffn"), MESH) \
        == P("data", None, "model")


def test_vocab_table_unsharded():
    assert spec_for((256000, 2048), ("vocab_table", "embed"), MESH) \
        == P(None, "data")
    assert spec_for((4096, 64000), ("embed", "vocab"), MESH) \
        == P("data", "model")


def test_every_arch_param_fully_resolves():
    """No tensor may fail to lower: every dim either shards evenly or
    replicates, for every assigned architecture."""
    for name, cfg in ARCHS.items():
        defs = model_defs(cfg)
        specs = param_specs(defs, MESH)
        shapes = abstract(defs)
        for spec, shp in zip(jax.tree.leaves(specs,
                                             is_leaf=lambda x: isinstance(x, P)),
                             jax.tree.leaves(shapes)):
            for dim, ax in zip(shp.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = 1
                for a in axes:
                    total *= MESH.shape[a]
                assert dim % total == 0, (name, shp.shape, spec)


def test_batch_spec_multipod():
    assert batch_spec(MESH3, 2) == P(("pod", "data"), None)
    assert batch_spec(MESH, 3) == P(("data",), None, None)


# ---------------------------------------------------------------------------
# HLO cost model
# ---------------------------------------------------------------------------

def test_shape_bytes_parsing():
    assert _shape_bytes("f32[128,128]{1,0}") == 128 * 128 * 4
    assert _shape_bytes("bf16[2,4096]") == 2 * 4096 * 2
    assert _shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert _shape_bytes("pred[]") == 1  # scalar: one element

def test_analyze_counts_scan_trip():
    import jax.numpy as jnp

    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=5)[0]

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    r = analyze(compiled.as_text())
    assert r["flops"] == 5 * 2 * 64 ** 3


def test_analyze_nested_scan():
    import jax.numpy as jnp

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=4)[0]

    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    r = analyze(compiled.as_text())
    assert r["flops"] == 12 * 2 * 32 ** 3
