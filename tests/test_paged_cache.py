"""Block-allocator property tests: conservation, COW refcounts, and
leak-freedom under randomized alloc/free/share/preempt traffic.  Pure
host-side — no model, no device arrays."""
import numpy as np
import pytest

from repro.serving.paged_cache import (BlockAllocator, PoolExhausted,
                                       n_blocks_for)


def test_n_blocks_for_is_ceil_div():
    assert n_blocks_for(1, 4) == 1
    assert n_blocks_for(4, 4) == 1
    assert n_blocks_for(5, 4) == 2
    assert n_blocks_for(16, 16) == 1
    assert n_blocks_for(17, 16) == 2


def test_alloc_free_conservation_and_exhaustion():
    a = BlockAllocator(n_blocks=8, block_size=4)
    assert a.n_free == 7                       # block 0 reserved
    ids = [a.alloc() for _ in range(7)]
    assert 0 not in ids and len(set(ids)) == 7
    assert a.n_free == 0 and a.used_blocks == 7
    with pytest.raises(PoolExhausted):
        a.alloc()
    for b in ids:
        a.release(b)
    assert a.n_free == 7 and a.used_blocks == 0
    a.check()


def test_cow_retain_release_refcounts():
    a = BlockAllocator(n_blocks=8, block_size=2)
    b = a.alloc()
    key = a.prefix_key(None, (1, 2))
    a.register(key, b)
    assert a.lookup(key) == b
    a.retain(b)
    assert a.refcount(b) == 2
    a.release(b)                               # one owner remains
    assert a.lookup(key) == b and a.refcount(b) == 1
    a.release(b)                               # last owner: unregistered
    assert a.lookup(key) is None and a.n_free == 7
    a.check()


def test_plan_prompt_shares_longest_prefix_chain():
    a = BlockAllocator(n_blocks=16, block_size=2)
    prompt = [1, 2, 3, 4, 5]                   # blocks (1,2) (3,4) + tail 5
    shared, keys = a.plan_prompt(prompt)
    assert shared == [] and len(keys) == 2
    owned = [a.alloc() for _ in range(3)]      # 2 full + 1 partial
    for k, b in zip(keys, owned):
        a.register(k, b)
    # identical prompt: both full blocks shared, refcounts bumped
    shared2, keys2 = a.plan_prompt(prompt)
    assert shared2 == owned[:2] and keys2 == keys
    assert a.refcount(owned[0]) == 2 and a.refcount(owned[1]) == 2
    # diverging second block: only the first chains
    shared3, _ = a.plan_prompt([1, 2, 9, 9])
    assert shared3 == owned[:1]
    for b in shared2 + shared3:
        a.release(b)
    a.check()


def test_no_leaks_after_randomized_preemption_traffic():
    """Random admit/extend/preempt/finish cycles must conserve blocks
    exactly and end with an empty pool."""
    rng = np.random.RandomState(0)
    a = BlockAllocator(n_blocks=32, block_size=4)
    live = {}                                  # rid -> list of block ids
    rid = 0
    for _ in range(300):
        op = rng.randint(3)
        if op == 0:                            # admit with COW plan
            prompt = rng.randint(0, 50, rng.randint(1, 12)).tolist()
            shared, keys = a.plan_prompt(prompt)
            need = n_blocks_for(len(prompt), 4) - len(shared)
            if a.n_free < need:
                for b in shared:
                    a.release(b)
                continue
            ids = shared + [a.alloc() for _ in range(need)]
            for j in range(len(shared), len(keys)):
                a.register(keys[j], ids[j])
            live[rid] = ids
            rid += 1
        elif op == 1 and live:                 # decode growth
            r = rng.choice(list(live))
            if a.n_free:
                live[r].append(a.alloc())
        elif op == 2 and live:                 # preempt or finish: release all
            r = rng.choice(list(live))
            for b in live.pop(r):
                a.release(b)
        a.check()
        total = a.used_blocks + a.n_free
        assert total == a.n_blocks - 1
    for ids in live.values():
        for b in ids:
            a.release(b)
    a.check()
    assert a.used_blocks == 0
