"""Tracker-layer tests: backend fan-out order, callback ordering, JSONL
round-trip, scalarization, and the shared run_steps loop."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.tracker import (CompositeTracker, JsonlTracker, MemoryTracker,
                           NullTracker, StdoutTracker, Tracker, current_tracker,
                           read_jsonl, scalarize, with_tracker)
from repro.tracker.callbacks import (Callback, CallbackRunner, MetricsBuffer,
                                     StepTimer)


# --- scalarization -----------------------------------------------------

def test_scalarize_accepts_scalars_and_device_scalars():
    assert scalarize(3) == 3
    assert scalarize(1.5) == 1.5
    assert scalarize("x") == "x"
    assert scalarize(None) is None
    assert scalarize(True) is True
    v = scalarize(jnp.float32(2.5))
    assert v == 2.5 and isinstance(v, float)
    v = scalarize(np.int32(7))
    assert v == 7 and isinstance(v, int)
    assert scalarize({"a": jnp.int32(1), "b": [np.float64(2.0)]}) == \
        {"a": 1, "b": [2.0]}


def test_scalarize_rejects_nonscalar_arrays():
    with pytest.raises(TypeError, match="scalar"):
        scalarize(jnp.zeros((3,)))
    with pytest.raises(TypeError, match="scalar"):
        scalarize(np.zeros((2, 2)))


# --- backends ----------------------------------------------------------

def test_memory_tracker_records_and_series():
    t = MemoryTracker()
    t.log(0, {"loss": jnp.float32(2.0), "lr": 0.1})
    t.log(1, {"loss": 1.0})
    t.log_summary({"final_loss": 1.0})
    t.finish()
    assert t.steps == [(0, {"loss": 2.0, "lr": 0.1}), (1, {"loss": 1.0})]
    assert t.series("loss") == [2.0, 1.0]
    assert t.series("lr") == [0.1]
    assert t.summary == {"final_loss": 1.0}
    assert t.finished


def test_composite_fans_out_in_registration_order():
    order = []

    class Probe(Tracker):
        def __init__(self, name):
            self.name = name

        def _log(self, step, metrics):
            order.append((self.name, "log", step))

        def _log_summary(self, metrics):
            order.append((self.name, "summary"))

        def finish(self):
            order.append((self.name, "finish"))

    comp = CompositeTracker([Probe("a"), Probe("b"), Probe("c")])
    comp.log(0, {"x": 1})
    comp.log_summary({"y": 2})
    comp.finish()
    assert order == [("a", "log", 0), ("b", "log", 0), ("c", "log", 0),
                     ("a", "summary"), ("b", "summary"), ("c", "summary"),
                     ("a", "finish"), ("b", "finish"), ("c", "finish")]


def test_composite_backends_see_identical_records():
    a, b = MemoryTracker(), MemoryTracker()
    comp = CompositeTracker([a, b])
    comp.log(3, {"loss": jnp.float32(0.5)})
    assert a.steps == b.steps == [(3, {"loss": 0.5})]


def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    t = JsonlTracker(path)
    t.log(0, {"loss": 2.5, "lr": jnp.float32(0.1), "tag": "warmup"})
    t.log(1, {"loss": 1.25})
    t.log_summary({"final_loss": 1.25, "diverged": False})
    t.finish()
    recs = read_jsonl(path)
    assert recs == [
        {"step": 0, "loss": 2.5, "lr": pytest.approx(0.1), "tag": "warmup"},
        {"step": 1, "loss": 1.25},
        {"summary": True, "final_loss": 1.25, "diverged": False},
    ]
    # append mode: a resumed run extends its own stream
    t2 = JsonlTracker(path)
    t2.log(2, {"loss": 1.0})
    t2.finish()
    assert len(read_jsonl(path)) == 4
    with pytest.raises(ValueError, match="finished"):
        t2.log(3, {"loss": 0.9})


def test_stdout_tracker_rate_limits(capsys):
    t = StdoutTracker(every=2)
    for s in range(4):
        t.log(s, {"loss": float(s)})
    t.log_summary({"final_loss": 3.0})
    lines = capsys.readouterr().out.strip().splitlines()
    assert len(lines) == 3             # steps 0, 2 + summary
    assert "step     0" in lines[0] and "step     2" in lines[1]
    assert lines[2].startswith("summary")


def test_ambient_tracker_context():
    assert isinstance(current_tracker(), NullTracker)
    mem = MemoryTracker()
    with with_tracker(mem):
        assert current_tracker() is mem
        current_tracker().log(0, {"x": 1})
    assert isinstance(current_tracker(), NullTracker)
    assert mem.steps == [(0, {"x": 1})]


# --- callbacks ---------------------------------------------------------

def test_callback_runner_ordering_and_merge():
    """Callbacks run in registration order; each sees the metrics the
    previous one produced; derived metrics land in the tracker record."""
    calls = []

    class A(Callback):
        def on_step(self, step, metrics):
            calls.append(("A", step))
            assert "derived_b" not in metrics     # A runs before B
            return {"derived_a": step * 10}

        def on_end(self):
            calls.append(("A", "end"))
            return {"sum_a": 1}

    class B(Callback):
        def on_step(self, step, metrics):
            calls.append(("B", step))
            assert metrics["derived_a"] == step * 10   # B sees A's output
            return {"derived_b": True}

        def on_end(self):
            calls.append(("B", "end"))
            return {"sum_b": 2}

    mem = MemoryTracker()
    runner = CallbackRunner(mem, [A(), B()], flush_every=2)
    for s in range(3):
        runner.push(s, {"loss": float(s)})
    runner.close({"explicit": 3})
    assert calls == [("A", 0), ("B", 0), ("A", 1), ("B", 1),
                     ("A", 2), ("B", 2), ("A", "end"), ("B", "end")]
    assert [s for s, _ in mem.steps] == [0, 1, 2]
    assert mem.steps[1][1]["derived_a"] == 10
    assert mem.steps[1][1]["derived_b"] is True
    # internal _t_* plumbing never reaches the tracker
    assert not any(k.startswith("_") for _, m in mem.steps for k in m)
    assert mem.summary == {"sum_a": 1, "sum_b": 2, "explicit": 3}
    assert mem.finished


def test_callback_runner_buffers_until_flush_boundary():
    mem = MemoryTracker()
    runner = CallbackRunner(mem, flush_every=3)
    runner.push(0, {"loss": 1.0})
    runner.push(1, {"loss": 0.9})
    assert mem.steps == []            # still buffered (device scalars live)
    runner.push(2, {"loss": 0.8})
    assert [s for s, _ in mem.steps] == [0, 1, 2]
    runner.push(3, {"loss": 0.7})
    runner.close()
    assert [s for s, _ in mem.steps] == [0, 1, 2, 3]
    runner.close()                    # idempotent


def test_metrics_buffer_defers_conversion():
    buf = MetricsBuffer()
    buf.push(0, {"loss": jnp.float32(1.5)})
    buf.push(1, {"loss": jnp.float32(0.5)})
    assert len(buf) == 2
    drained = buf.drain()
    assert len(buf) == 0 and buf.drain() == []
    assert [(s, m["loss"]) for s, m in drained] == [(0, 1.5), (1, 0.5)]
    assert all(isinstance(m["loss"], float) for _, m in drained)
    # wall-time stamps are monotone across pushes
    assert drained[0][1]["_t_wall"] <= drained[1][1]["_t_wall"]


def test_step_timer_throughput():
    timer = StepTimer(tokens_per_step=100)
    m0 = timer.on_step(0, {"_t_wall": 10.0, "_t_loop_start": 9.0})
    assert m0["step_time_s"] == pytest.approx(1.0)
    assert m0["tokens_per_s"] == pytest.approx(100.0)
    m1 = timer.on_step(1, {"_t_wall": 10.5})
    assert m1["step_time_s"] == pytest.approx(0.5)
    assert m1["tokens_per_s"] == pytest.approx(200.0)
    end = timer.on_end()
    assert end["wall_time_s"] == pytest.approx(1.5)
    assert end["tokens_per_s"] == pytest.approx(200 / 1.5)


# --- the shared loop ---------------------------------------------------

def test_run_steps_threads_state_and_logs():
    from repro.training import run_steps

    def step_fn(state, batch):
        return state + batch, {"loss": jnp.float32(10 - state)}

    mem = MemoryTracker()
    final = run_steps(step_fn, 0, lambda t: 1, 5, tracker=mem, log_every=2,
                      summary={"done": True})
    assert final == 5
    assert mem.series("loss") == [10.0, 9.0, 8.0, 7.0, 6.0]
    assert mem.summary["done"] is True
    assert mem.finished


def test_run_steps_start_offset():
    from repro.training import run_steps

    mem = MemoryTracker()
    run_steps(lambda s, b: (s, {"loss": 0.0}), 0, lambda t: t, 6,
              start=4, tracker=mem)
    assert [s for s, _ in mem.steps] == [4, 5]
