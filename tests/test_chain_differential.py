"""Differential-testing harness: compiled chains vs the jnp interpreter.

``compile_chain(tx, interpret=True)`` is the reference semantics for
EVERY chain; this module generates randomized chains (Hypothesis-drawn
transforms, orders, hyperparameters) over randomized pytrees (ragged
shapes, scalars, size-0 leaves, fp32/bf16/mixed dtypes) and asserts the
compiled executions agree with it — the guard that keeps the chain ->
multi-tensor compiler honest as patterns grow.

Agreement policy (documented in README "Optimizer API"):

  * matched chains WITHOUT a clip prefix or nesterov: compiled jnp path
    and fused resident path are BIT-identical to each other; vs the
    interpreter they are bit-identical for the sngm/msgd shapes and for
    lamb (fp32 AND bf16), while lars differs only in lr-product
    association (PR 3 precedent) — float-tolerance there;
  * clip-carrying and nesterov chains: lamb stays bit-identical; the
    momentum kinds agree to a few fp32 ulp per step (XLA CPU re-clusters
    the fusion around the clip pre-scale / the nesterov look-ahead and
    flips last-ulp FMA contraction; the kernels compile in isolation on
    real TPU, where this class of drift does not arise) — tight float
    tolerance;
  * SEGMENT PLANS (chains the whole-chain matcher rejects but whose
    suffix lands on a fused kind — mid-chain clip, trailing clip,
    ema_params anywhere, stateless prefixes): fused execution agrees
    with the interpreter under the same per-kind policy — except that a
    jnp prefix node shifts XLA fusion boundaries vs the fully inlined
    interpreter, so prefix-bearing plans use the tight float tolerance —
    EMA shadow slots are bit-identical (pure elementwise), and launch
    counts equal the plan's static annotation exactly;
  * unmatched (novel) chains run the interpreter itself: zero Pallas
    launches, ``ChainOptState``, and a ``UserWarning`` when a fused mode
    was requested;
  * fused-vs-fallback STATE equivalence via ``to_pytree``: the resident
    flat state's pytree view (momentum, or lamb's Adam-moment chain
    state) matches the interpreter's state under the same policy;
  * the engine stays O(1): exact launch-count bookkeeping per kind,
    including the extra raw-norm round of clip-prefixed chains and the
    deferred-apply pass of trailing clips.

Fast lane runs a deterministic grid plus (when Hypothesis is installed —
it is pinned in requirements.txt) a few randomized examples per
property; the wide randomized sweep is ``@pytest.mark.slow`` (nightly).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FlatOptState, OptState, compile_chain, to_pytree
from repro.core import transform as T
from repro.core.multi_tensor import build_layout
from repro.core.schedules import constant, poly_power
from repro.core.transform import ChainOptState
from repro.kernels import count_pallas_launches

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
STEPS = 2
KINDS = ("sngm_global", "sngm_per_tensor", "msgd", "lars", "lamb")

# shapes + dtypes + seed + grad scale; shapes span scalars, ragged sizes,
# a just-past-one-CHUNK leaf and a size-0 leaf
SPEC_GRID = {
    "f32": (((300, 17), (1030,), (), (0,), (4,)),
            ("float32",) * 5, 3, 3.0),
    "bf16": (((33, 5), (1030,), (), (7, 3)),
             ("bfloat16",) * 4, 5, 3.0),
    "mixed": (((129,), (16, 16), (), (0,), (40, 3)),
              ("float32", "bfloat16", "float32", "bfloat16", "float32"),
              7, 1.0),
    "zero_grads": (((65, 3), (17,)), ("float32",) * 2, 9, 0.0),
}


def materialize(spec):
    shapes, dtypes, seed, gscale = spec
    k = jax.random.fold_in(KEY, seed)
    params = {f"p{i}": jax.random.normal(jax.random.fold_in(k, i), s
                                         ).astype(jnp.dtype(d))
              for i, (s, d) in enumerate(zip(shapes, dtypes))}
    grads = {f"p{i}": (gscale * jax.random.normal(
        jax.random.fold_in(k, 1000 + i), s)).astype(jnp.dtype(d))
        for i, (s, d) in enumerate(zip(shapes, dtypes))}
    return params, grads


def build_canonical(kind, clip=None, wd=1e-4, with_wd_stage=True, beta=0.9,
                    sched=None, nesterov=False):
    """The canonical chain for one fused kind, optionally clip-prefixed
    and/or with nesterov momentum (a kind variant since the segment
    compiler)."""
    sched = sched or poly_power(0.3, 10, 1.1)
    prefix = (T.clip_by_global_norm(clip),) if clip is not None else ()
    adw = (T.add_decayed_weights(wd),) if with_wd_stage else ()
    if kind == "lamb":
        body = (T.scale_by_adam(0.9, 0.999, 1e-6),) + adw + \
            (T.scale_by_trust_ratio(), T.scale_by_schedule(sched))
    elif kind == "lars":
        body = (T.trust_ratio(0.001, wd), T.scale_by_schedule(sched),
                T.trace(beta, nesterov=nesterov))
    elif kind == "msgd":
        body = adw + (T.trace(beta, nesterov=nesterov),
                      T.scale_by_schedule(sched))
    else:
        norm = (T.normalize_by_global_norm() if kind == "sngm_global"
                else T.normalize_per_tensor())
        body = adw + (norm, T.trace(beta, nesterov=nesterov),
                      T.scale_by_schedule(sched))
    return T.chain(*(prefix + body))


_POOL = (
    lambda: T.clip_by_global_norm(1.0),
    lambda: T.add_decayed_weights(1e-3),
    lambda: T.normalize_by_global_norm(),
    lambda: T.normalize_per_tensor(),
    lambda: T.trace(0.9),
    lambda: T.trace(0.9, nesterov=True),
    lambda: T.scale_by_adam(0.9, 0.999, 1e-6),
    lambda: T.scale_by_trust_ratio(),
    lambda: T.trust_ratio(0.001, 1e-4),
    lambda: T.scale_by_schedule(constant(0.1)),
    lambda: T.ema_params(0.99),
)


# ---------------------------------------------------------------------------
# comparison helpers (the tolerance policy)
# ---------------------------------------------------------------------------

def assert_trees(a, b, policy, label):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), label
    for x, y in zip(la, lb):
        if policy == "bitwise":
            assert x.dtype == y.dtype, (label, x.dtype, y.dtype)
            assert bool(jnp.array_equal(x, y)), (
                label, np.asarray(x), np.asarray(y))
        else:
            xf = np.asarray(x, np.float32)
            yf = np.asarray(y, np.float32)
            if x.dtype == jnp.bfloat16 or y.dtype == jnp.bfloat16:
                np.testing.assert_allclose(xf, yf, rtol=5e-2, atol=1e-2,
                                           err_msg=label)
            else:
                np.testing.assert_allclose(xf, yf, rtol=5e-4, atol=1e-6,
                                           err_msg=label)


def interp_policy(kind, clip, nesterov=False):
    """Agreement level of a compiled execution vs the interpreter."""
    if kind == "lamb":
        return "bitwise"
    if kind == "lars":
        return "close"                    # lr-product association (PR 3)
    # clip pre-scale and the nesterov look-ahead both re-cluster FMA
    # contraction on XLA CPU (last-ulp drift); unclipped plain momentum
    # chains are bit-exact
    return "bitwise" if clip is None and not nesterov else "close"


def state_trees(state):
    """The param-mirroring accumulators of any state form, as a tuple of
    pytrees (momentum, or Adam m/v), for cross-form comparison."""
    if isinstance(state, FlatOptState):
        return state.moments if state.m_flats else (state.momentum,)
    if isinstance(state, OptState):
        return (state.momentum,)
    out = []
    for s in state.inner:
        if isinstance(s, T.TraceState):
            out.append(s.momentum)
        elif isinstance(s, T.ScaleByAdamState):
            out.extend((s.m, s.v))
    return tuple(out)


def expected_launches(kind, clip, n_buckets):
    base = {"sngm_global": 2, "sngm_per_tensor": 2, "msgd": 2, "lars": 3,
            "lamb": 2}[kind]
    if clip is not None:
        base += 1                         # the raw-norm round
        if kind == "msgd":
            base -= 1                     # clipped msgd skips pass 1
    return base * n_buckets


def run(opt, params, grads, steps=STEPS):
    state = opt.init(params)
    step = jax.jit(opt.step)
    stats = None
    for _ in range(steps):
        params, state, stats = step(grads, state, params)
    return params, state, stats


# ---------------------------------------------------------------------------
# the differential properties
# ---------------------------------------------------------------------------

def check_canonical(tx_kind_clip, spec):
    tx, kind, clip = tx_kind_clip
    nest = any(p.name == "trace" and bool(p.get("nesterov"))
               for p in tx.parts)
    params, grads = materialize(spec)

    interp = compile_chain(tx, interpret=True)
    compiled = compile_chain(tx)                       # jnp kind path
    fused = compile_chain(tx, fused="multi_tensor")    # engine, resident
    assert compiled.kind == fused.kind == kind

    p_i, s_i, st_i = run(interp, params, grads)
    p_c, s_c, st_c = run(compiled, params, grads)
    p_f, s_f, st_f = run(fused, params, grads)
    assert isinstance(s_f, FlatOptState)

    pol = interp_policy(kind, clip, nest)
    assert_trees(p_c, p_i, pol, f"{kind} jnp-vs-interp params")
    assert_trees(p_f, p_i, pol, f"{kind} fused-vs-interp params")
    # compiled jnp and fused engine share the kind implementation: held
    # to the tighter of the two bounds
    assert_trees(p_f, p_c,
                 "bitwise" if clip is None and not nest else "close",
                 f"{kind} fused-vs-jnp params")

    # state equivalence across forms (momentum / Adam moments)
    assert_trees(state_trees(s_f), state_trees(s_i), pol,
                 f"{kind} fused-vs-interp state")
    assert_trees(state_trees(to_pytree(s_f)), state_trees(s_i), pol,
                 f"{kind} to_pytree state")

    # stats: lr is schedule-only (bitwise everywhere); norms follow the
    # policy.  Exemption (PR 3 precedent): the un-clipped msgd chain has
    # no norm-emitting stage, so the interpreter reports the RAW gradient
    # norm where the kind implementation reports the coupled-decayed one.
    assert bool(jnp.array_equal(st_f["lr"], st_i["lr"]))
    keys = {"grad_norm", "update_norm"}
    if kind == "msgd" and clip is None:
        keys -= {"grad_norm"}
    for k in keys:
        assert_trees(st_f[k], st_i[k], pol, f"{kind} stat {k}")
        assert_trees(st_c[k], st_i[k], pol, f"{kind} stat {k} (jnp)")

    # O(1) launches, exact per-kind count (incl. the clip round)
    n_buckets = len(build_layout(params).buckets)
    with count_pallas_launches() as c:
        jax.jit(lambda g, s, p: fused.step(g, s, p)).lower(
            grads, fused.init(params), params)
    assert c["launches"] == expected_launches(kind, clip, n_buckets), \
        (kind, clip, n_buckets, c["launches"])


def check_novel(tx, spec):
    params, grads = materialize(spec)
    assert T.plan_chain(tx).kind is None    # genuinely novel: no fused tail
    interp = compile_chain(tx, interpret=True)
    with pytest.warns(UserWarning, match="does not match any fused kind"):
        fused = compile_chain(tx, fused="multi_tensor")
    assert fused.kind is None
    s0 = fused.init(params)
    assert isinstance(s0, ChainOptState)
    with count_pallas_launches() as c:
        p_f, s_f, st_f = run(fused, params, grads)
    assert c["launches"] == 0             # the interpreter is pure jnp
    p_i, s_i, st_i = run(interp, params, grads)
    assert_trees(p_f, p_i, "bitwise", "novel params")
    assert_trees(s_f, s_i, "bitwise", "novel state")
    for k in ("grad_norm", "lr", "update_norm"):
        # equal_nan: chains without a schedule stage report the lr=nan
        # interpreter fallback on both sides
        assert k in st_f and np.array_equal(np.asarray(st_f[k]),
                                            np.asarray(st_i[k]),
                                            equal_nan=True)


def check_plan(tx, kind, launches_per_bucket, spec, policy):
    """A segment-compiled chain: no whole-chain match, but the planner
    lands its suffix on the engine.  Fused execution must agree with the
    interpreter (params under ``policy``, EMA slots bitwise), the state
    must interconvert through ``to_pytree``, and the launch count must
    equal the plan's static annotation EXACTLY."""
    from repro.core.optim import from_pytree
    from repro.tracker.counters import plan_launches_per_step
    params, grads = materialize(spec)
    assert T.match_chain(tx) is None
    plan = T.plan_chain(tx)
    assert plan.kind == kind, (plan.describe(), plan.blocker)
    assert plan.launches_per_bucket() == launches_per_bucket, plan.describe()

    interp = compile_chain(tx, interpret=True)
    fused = compile_chain(tx, fused="multi_tensor")
    assert fused.kind == kind

    p_i, s_i, st_i = run(interp, params, grads)
    p_f, s_f, st_f = run(fused, params, grads)
    assert isinstance(s_f, FlatOptState)
    assert s_f.form == ("chain", plan.slots)

    assert_trees(p_f, p_i, policy, f"plan[{kind}] params")
    view = to_pytree(s_f)
    assert isinstance(view, ChainOptState)
    assert_trees(state_trees(view), state_trees(s_i), policy,
                 f"plan[{kind}] state")
    # EMA shadow slots: pure elementwise updates, bitwise across paths
    emas_f = [s.ema for s in view.inner if isinstance(s, T.EmaParamsState)]
    emas_i = [s.ema for s in s_i.inner if isinstance(s, T.EmaParamsState)]
    assert len(emas_f) == len(emas_i)
    for ef, ei in zip(emas_f, emas_i):
        assert_trees(ef, ei, "bitwise", f"plan[{kind}] ema slots")
    # round trip back to the flat form, losslessly
    back = from_pytree(view, p_f)
    assert back.form == s_f.form
    assert_trees(tuple(back.p_flats), tuple(s_f.p_flats), "bitwise",
                 f"plan[{kind}] p_flats round-trip")

    assert bool(jnp.array_equal(st_f["lr"], st_i["lr"]))
    for k in ("grad_norm", "update_norm"):
        assert_trees(st_f[k], st_i[k], policy, f"plan[{kind}] stat {k}")

    # EXACT launches: static plan annotation == counters == trace
    n_buckets = len(build_layout(params).buckets)
    with count_pallas_launches() as c:
        jax.jit(lambda g, s, p: fused.step(g, s, p)).lower(
            grads, fused.init(params), params)
    assert c["launches"] == launches_per_bucket * n_buckets, plan.describe()
    assert plan_launches_per_step(fused, params) == c["launches"]


# ---- deterministic grid (fast lane; runs with or without hypothesis) ------

@pytest.mark.parametrize("clip", [None, 0.5])
@pytest.mark.parametrize("kind", sorted(KINDS))
def test_canonical_differential_grid(kind, clip):
    spec_name = {"sngm_global": "f32", "sngm_per_tensor": "bf16",
                 "msgd": "mixed", "lars": "f32", "lamb": "mixed"}[kind]
    tx = build_canonical(kind, clip)
    check_canonical((tx, kind, clip), SPEC_GRID[spec_name])


def test_canonical_differential_zero_grads():
    """Zero gradients: sngm normalizes by eps, lamb's trust ratio hits the
    zero-update-norm branch — both must still agree with the interpreter."""
    for kind in ("sngm_global", "lamb"):
        check_canonical((build_canonical(kind, None), kind, None),
                        SPEC_GRID["zero_grads"])


def test_novel_chain_differential_grid():
    cases = [
        # a stateful non-canonical stage mid-chain blocks fusion outright
        T.chain(T.scale_by_adam(0.9, 0.999, 1e-6), T.trace(0.9),
                T.scale_by_schedule(constant(0.1))),
        # schedule BEFORE trace without trust_ratio matches no grammar
        T.chain(T.scale_by_schedule(constant(0.1)), T.trace(0.9)),
    ]
    for tx in cases:
        assert T.match_chain(tx) is None
        check_novel(tx, SPEC_GRID["f32"])


# ---- deterministic segment-plan grid (the tentpole chains, fast lane) -----

def test_plan_differential_clip_mid():
    """SNGM-semantics chain with the clip between normalize and trace:
    the planner peels (adw, normalize) as jnp nodes and folds the clip
    into an msgd tail — 2 launches/bucket, same as unclipped."""
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.clip_by_global_norm(5.0), T.trace(0.9),
                 T.scale_by_schedule(poly_power(0.3, 10, 1.1)))
    check_plan(tx, "msgd", 2, SPEC_GRID["f32"], "close")
    check_plan(tx, "msgd", 2, SPEC_GRID["mixed"], "close")


def test_plan_differential_suffix_clip():
    """Trailing clip (after the schedule): deferred-apply third pass."""
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.trace(0.9), T.scale_by_schedule(poly_power(0.3, 10, 1.1)),
                 T.clip_by_global_norm(0.01))
    check_plan(tx, "sngm_global", 3, SPEC_GRID["f32"], "close")
    check_plan(tx, "sngm_global", 3, SPEC_GRID["bf16"], "close")


def test_plan_differential_ema():
    """ema_params rides along as a resident f32 shadow slot; the sngm
    tail fuses exactly as without it."""
    tx = T.chain(T.add_decayed_weights(1e-4), T.normalize_by_global_norm(),
                 T.trace(0.9), T.scale_by_schedule(poly_power(0.3, 10, 1.1)),
                 T.ema_params(0.99))
    check_plan(tx, "sngm_global", 2, SPEC_GRID["f32"], "bitwise")
    check_plan(tx, "sngm_global", 2, SPEC_GRID["bf16"], "bitwise")


def test_plan_differential_clip_nesterov_ema():
    """The kitchen-sink plan from the old novel grid: clip prefix,
    nesterov trace, trailing EMA — clipped msgd tail (clip round replaces
    pass 1) + shadow slot, 2 launches/bucket."""
    tx = T.chain(T.clip_by_global_norm(1.0), T.trace(0.9, nesterov=True),
                 T.scale_by_schedule(constant(0.1)), T.ema_params(0.99))
    check_plan(tx, "msgd", 2, SPEC_GRID["f32"], "close")


def test_plan_differential_novel_prefix_interleaves():
    """A genuinely non-canonical composition (double normalization) does
    not de-fuse the suffix: the leading normalize runs as a jnp node and
    the longest canonical tail (adw -> normalize -> trace -> sched) still
    lands on the engine."""
    tx = T.chain(T.normalize_by_global_norm(), T.add_decayed_weights(0.1),
                 T.normalize_by_global_norm(), T.trace(0.9),
                 T.scale_by_schedule(constant(0.1)))
    plan = T.plan_chain(tx)
    assert [n.op for n in plan.nodes] == ["jnp", "fused"]
    assert plan.fused.arg("weight_decay") == 0.1
    # a jnp prefix shifts XLA fusion boundaries vs the fully inlined
    # interpreter, so exact bit-parity is not guaranteed here
    check_plan(tx, "sngm_global", 2, SPEC_GRID["f32"], "close")


# ---- randomized sweep (hypothesis; wide version in the slow lane) ---------

if HAVE_HYPOTHESIS:
    @st.composite
    def tree_specs(draw):
        """Randomized shapes/dtypes/values: ragged sizes, scalars, an
        optional size-0 leaf, fp32 / bf16 / mixed dtypes, and a gradient
        scale that includes exactly zero."""
        n = draw(st.integers(1, 4))
        shapes = [tuple(draw(st.integers(1, 40))
                        for _ in range(draw(st.integers(0, 2))))
                  for _ in range(n)]
        if draw(st.booleans()):
            shapes.append((1030,))        # just past one CHUNK
        if draw(st.booleans()):
            shapes.append((0,))           # empty leaf
        mode = draw(st.sampled_from(["f32", "bf16", "mixed"]))
        dtypes = ["float32" if mode == "f32"
                  or (mode == "mixed" and i % 2 == 0) else "bfloat16"
                  for i in range(len(shapes))]
        seed = draw(st.integers(0, 2**20))
        gscale = draw(st.sampled_from([0.0, 1.0, 3.0]))
        return tuple(shapes), tuple(dtypes), seed, gscale

    @st.composite
    def canonical_chains(draw):
        kind = draw(st.sampled_from(KINDS))
        clip = draw(st.sampled_from([None, 0.5, 10.0]))
        wd = draw(st.sampled_from([0.0, 1e-4, 1e-2]))
        tx = build_canonical(
            kind, clip, wd=wd,
            with_wd_stage=wd != 0.0 or draw(st.booleans()),
            beta=draw(st.sampled_from([0.0, 0.5, 0.9])),
            sched=draw(st.sampled_from([constant(0.1),
                                        poly_power(0.3, 10, 1.1)])),
            nesterov=kind != "lamb" and draw(st.booleans()))
        return tx, kind, clip

    @st.composite
    def plan_chains(draw):
        """Randomized segment-compilable chains: a canonical momentum
        tail with some mix of jnp-prefix stages, mid/trailing clip, and
        EMA slots — the planner must fuse the tail every time."""
        kind = draw(st.sampled_from(("sngm_global", "sngm_per_tensor",
                                     "msgd")))
        sched = draw(st.sampled_from([constant(0.1),
                                      poly_power(0.3, 10, 1.1)]))
        norm = {"sngm_global": (T.normalize_by_global_norm(),),
                "sngm_per_tensor": (T.normalize_per_tensor(),),
                "msgd": ()}[kind]
        prefix = ()
        if draw(st.booleans()):
            prefix += (T.normalize_by_global_norm(),)   # jnp prefix node
        mid_clip = draw(st.booleans())
        body = norm + ((T.clip_by_global_norm(2.0),) if mid_clip else ()) + \
            (T.trace(draw(st.sampled_from([0.0, 0.9])),
                     nesterov=draw(st.booleans())),
             T.scale_by_schedule(sched))
        suffix = ()
        if not mid_clip and draw(st.booleans()):
            suffix += (T.clip_by_global_norm(0.05),)    # deferred apply
        if draw(st.booleans()):
            suffix += (T.ema_params(0.99),)
        tx = T.chain(*(prefix + body + suffix))
        hypothesis.assume(T.match_chain(tx) is None)
        return tx

    @st.composite
    def novel_chains(draw):
        """Random transform sequences neither the whole-chain matcher nor
        the segment planner can place on the engine."""
        idx = draw(st.lists(st.integers(0, len(_POOL) - 1), min_size=2,
                            max_size=5))
        tx = T.chain(*[_POOL[i]() for i in idx])
        hypothesis.assume(T.match_chain(tx) is None)
        hypothesis.assume(T.plan_chain(tx).kind is None)
        return tx

    @settings(max_examples=6, deadline=None, derandomize=True)
    @given(tx_kind_clip=canonical_chains(), spec=tree_specs())
    def test_canonical_chain_differential(tx_kind_clip, spec):
        check_canonical(tx_kind_clip, spec)

    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(tx=novel_chains(), spec=tree_specs())
    def test_novel_chain_differential(tx, spec):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)  # inner pytest.warns
            check_novel(tx, spec)

    def _plan_policy(tx):
        clippy = any(p.name == "clip_by_global_norm" for p in tx.parts)
        nest = any(p.name == "trace" and bool(p.get("nesterov"))
                   for p in tx.parts)
        prefix = any(n.op == "jnp" for n in T.plan_chain(tx).nodes)
        return "close" if clippy or nest or prefix else "bitwise"

    @settings(max_examples=6, deadline=None, derandomize=True)
    @given(tx=plan_chains(), spec=tree_specs())
    def test_plan_chain_differential(tx, spec):
        plan = T.plan_chain(tx)
        assert plan.kind is not None, plan.describe()
        check_plan(tx, plan.kind, plan.launches_per_bucket(), spec,
                   _plan_policy(tx))

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(tx=plan_chains(), spec=tree_specs())
    def test_plan_chain_differential_wide(tx, spec):
        plan = T.plan_chain(tx)
        assert plan.kind is not None, plan.describe()
        check_plan(tx, plan.kind, plan.launches_per_bucket(), spec,
                   _plan_policy(tx))

    @pytest.mark.slow
    @settings(max_examples=50, deadline=None)
    @given(tx_kind_clip=canonical_chains(), spec=tree_specs())
    def test_canonical_chain_differential_wide(tx_kind_clip, spec):
        check_canonical(tx_kind_clip, spec)

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(tx=novel_chains(), spec=tree_specs())
    def test_novel_chain_differential_wide(tx, spec):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            check_novel(tx, spec)


# ---------------------------------------------------------------------------
# deterministic launch-count bookkeeping (no hypothesis needed, fast lane)
# ---------------------------------------------------------------------------

def _launches(opt, params, grads):
    state = opt.init(params)
    with count_pallas_launches() as c:
        jax.jit(lambda g, s, p: opt.step(g, s, p)).lower(grads, state, params)
    return c["launches"]


def test_lamb_and_clip_launch_counts():
    """The de-fusion guard in unit form: one fp32 bucket, exact counts.
    lamb = adam pass + apply; clip adds ONE raw-norm round (two norm
    rounds total for clip->sngm), never a per-leaf fallback."""
    params = {f"p{i}": jnp.ones((65, 3)) for i in range(12)}
    grads = {k: 2.0 * v for k, v in params.items()}
    sched = constant(0.1)

    def chain_for(kind, clip=None):
        pre = (T.clip_by_global_norm(clip),) if clip else ()
        body = {
            "sngm_global": (T.normalize_by_global_norm(), T.trace(0.9),
                            T.scale_by_schedule(sched)),
            "msgd": (T.trace(0.9), T.scale_by_schedule(sched)),
            "lars": (T.trust_ratio(0.001, 1e-4), T.scale_by_schedule(sched),
                     T.trace(0.9)),
            "lamb": (T.scale_by_adam(0.9, 0.999, 1e-6),
                     T.scale_by_trust_ratio(), T.scale_by_schedule(sched)),
        }[kind]
        return compile_chain(T.chain(*(pre + body)), fused="multi_tensor")

    assert _launches(chain_for("lamb"), params, grads) == 2
    assert _launches(chain_for("lamb", 1.0), params, grads) == 3
    assert _launches(chain_for("sngm_global", 1.0), params, grads) == 3
    assert _launches(chain_for("msgd", 1.0), params, grads) == 2
    assert _launches(chain_for("lars", 1.0), params, grads) == 4
    # independent of tree size: 12 leaves above, 40 here, same counts
    big = {f"x{i}": jnp.ones((65, 3)) for i in range(40)}
    gbig = {k: 2.0 * v for k, v in big.items()}
    assert _launches(chain_for("lamb"), big, gbig) == 2
    assert _launches(chain_for("sngm_global", 1.0), big, gbig) == 3

    # segment plans: jnp prefixes and EMA slots are launch-free, a
    # mid-chain clip folds into the coefficient round, a trailing clip
    # costs exactly one deferred-apply pass
    def plan_for(*stages):
        opt = compile_chain(T.chain(*stages), fused="multi_tensor")
        assert opt.kind is not None and opt.plan.kind is not None
        return opt

    clip_mid = plan_for(T.normalize_by_global_norm(),
                        T.clip_by_global_norm(5.0), T.trace(0.9),
                        T.scale_by_schedule(sched))
    assert _launches(clip_mid, params, grads) == 2
    suffix = plan_for(T.normalize_by_global_norm(), T.trace(0.9),
                      T.scale_by_schedule(sched),
                      T.clip_by_global_norm(0.01))
    assert _launches(suffix, params, grads) == 3
    ema = plan_for(T.normalize_by_global_norm(), T.trace(0.9),
                   T.scale_by_schedule(sched), T.ema_params(0.99))
    assert _launches(ema, params, grads) == 2
    nest = compile_chain(build_canonical("sngm_global", nesterov=True,
                                         sched=sched),
                         fused="multi_tensor")
    assert _launches(nest, params, grads) == 2
