"""Table 2 reproduction (reduced scale, synthetic CIFAR proxy):
MSGD small-batch vs {MSGD, LARS, SNGM} large-batch test accuracy.

Expected ordering (paper):  SNGM-large ~ MSGD-small > LARS-large >
MSGD-large.  Hyperparameters mirror the paper's recipe: step-decay for
MSGD, poly-power for LARS/SNGM, warm-up ONLY for the LARS(+wu) row,
weight decay 1e-4, momentum 0.9, gradient accumulation micro-batch 128.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import train_convnet
from repro.core import lars, msgd, sngm
from repro.core.schedules import poly_power, step_decay, warmup
from repro.data.synthetic import synthetic_images

N_TRAIN, N_TEST = 4096, 1024
EPOCHS = 16
B_SMALL, B_LARGE = 64, 1024


def run():
    x, y = synthetic_images(N_TRAIN, seed=0)
    xt, yt = synthetic_images(N_TEST, seed=99)
    steps_small = EPOCHS * N_TRAIN // B_SMALL
    steps_large = EPOCHS * N_TRAIN // B_LARGE

    jobs = [
        ("msgd_small", B_SMALL,
         msgd(step_decay(0.05, [int(steps_small * .6), int(steps_small * .85)]),
              beta=0.9, weight_decay=1e-4), steps_small),
        ("msgd_large", B_LARGE,
         msgd(step_decay(0.4, [int(steps_large * .6), int(steps_large * .85)]),
              beta=0.9, weight_decay=1e-4), steps_large),
        ("lars_large", B_LARGE,
         lars(poly_power(4.0, steps_large, 1.1), beta=0.9, weight_decay=1e-4,
              trust=0.01), steps_large),
        ("lars_large_warmup", B_LARGE,
         lars(warmup(poly_power(6.0, steps_large, 2.0), max(steps_large // 8, 1),
                     0.4), beta=0.9, weight_decay=1e-4, trust=0.01), steps_large),
        ("sngm_large", B_LARGE,
         sngm(poly_power(0.2, steps_large, 1.1), beta=0.9, weight_decay=1e-4),
         steps_large),
    ]
    out = {}
    for name, B, opt, steps in jobs:
        r = train_convnet(opt, x, y, xt, yt, B, steps)
        out[name] = {"batch": B, "test_acc": r["test_acc"],
                     "final_loss": r["final_loss"]}
        print(f"  {name:20s} B={B:5d}: acc={r['test_acc']:.4f} "
              f"loss={r['final_loss']:.4f}")
    gap_msgd = out["msgd_small"]["test_acc"] - out["msgd_large"]["test_acc"]
    gap_sngm = out["msgd_small"]["test_acc"] - out["sngm_large"]["test_acc"]
    print(f"  -> large-batch accuracy gap:  MSGD {gap_msgd:+.4f}   "
          f"SNGM {gap_sngm:+.4f}  (paper Table 2: SNGM closes the gap)")
    return out


if __name__ == "__main__":
    run()
