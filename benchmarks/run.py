"""Benchmark harness — one benchmark per paper table/figure:

  fig1        Figure 1: large-batch MSGD degrades loss & accuracy
  table1      Table 1 / §3-4: complexity-vs-batch scaling, MSGD vs SNGM
  table2      Table 2: CIFAR-proxy — MSGD/LARS/SNGM large-batch accuracy
  table3      Table 3: LM-proxy — SNGM@large-B vs MSGD@small-B at equal C
  overhead    optimizer-update us/call + fused-kernel HBM model
  roofline    render §Roofline table from dry-run artifacts (if present)

``python -m benchmarks.run [names...]`` — default: the fast set.
Results are appended to results/bench/<name>.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

BENCHES = {}


def _register():
    from benchmarks import (bench_fig1_large_batch_drop,
                            bench_table1_complexity,
                            bench_table2_cifar_proxy,
                            bench_table3_lm_proxy,
                            bench_optimizer_overhead,
                            roofline_report)
    BENCHES.update({
        "fig1": bench_fig1_large_batch_drop.run,
        "table1": bench_table1_complexity.run,
        "table2": bench_table2_cifar_proxy.run,
        "table3": bench_table3_lm_proxy.run,
        "overhead": bench_optimizer_overhead.run,
        "roofline": roofline_report.run,
    })


def main() -> None:
    _register()
    names = sys.argv[1:] or ["overhead", "table1", "fig1", "table2", "table3",
                             "roofline"]
    os.makedirs("results/bench", exist_ok=True)
    failures = []
    for name in names:
        print(f"[bench] {name}")
        t0 = time.time()
        try:
            out = BENCHES[name]()
            json.dump({"bench": name, "elapsed_s": round(time.time() - t0, 1),
                       "results": out},
                      open(f"results/bench/{name}.json", "w"), indent=1,
                      default=str)
            print(f"[bench] {name} done in {time.time()-t0:.0f}s\n")
        except Exception as e:  # report and continue
            failures.append(name)
            print(f"[bench] {name} FAILED: {type(e).__name__}: {e}\n")
    if failures:
        raise SystemExit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
