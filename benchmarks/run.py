"""Benchmark harness — one benchmark per paper table/figure:

  fig1        Figure 1: large-batch MSGD degrades loss & accuracy
  table1      Table 1 / §3-4: complexity-vs-batch scaling, MSGD vs SNGM
  table2      Table 2: CIFAR-proxy — MSGD/LARS/SNGM large-batch accuracy
  table3      Table 3: LM-proxy — SNGM@large-B vs MSGD@small-B at equal C
  overhead    optimizer-update us/call + fused-kernel HBM model
  sweep       Fig-1/Table-2/3 ladder, SNGM vs MSGD vs LAMB, fused path
  roofline    render §Roofline table from dry-run artifacts (if present)
  data_pipeline  input stall with/without prefetch + async-save latency

``python -m benchmarks.run [names...] [--quick] [--json-dir DIR]``
(default: the fast set).  Every benchmark's results are written in the
canonical schema-versioned envelope to ``<json-dir>/BENCH_<name>.json``
(``benchmarks/artifact.py``); default json-dir is the repo root, so CI
and local runs land on the same tracked paths.  Exit status is nonzero
when any bench fails.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

BENCHES = {}


def _register():
    from benchmarks import (bench_data_pipeline,
                            bench_fig1_large_batch_drop,
                            bench_table1_complexity,
                            bench_table2_cifar_proxy,
                            bench_table3_lm_proxy,
                            bench_optimizer_overhead,
                            bench_sweep,
                            roofline_report)
    BENCHES.update({
        "fig1": bench_fig1_large_batch_drop.run,
        "table1": bench_table1_complexity.run,
        "table2": bench_table2_cifar_proxy.run,
        "table3": bench_table3_lm_proxy.run,
        "overhead": bench_optimizer_overhead.run,
        "sweep": bench_sweep.run,
        "roofline": roofline_report.run,
        "data_pipeline": bench_data_pipeline.run,
    })


def _call(fn, quick: bool):
    """Invoke a bench's run() passing only the kwargs it accepts; the
    harness owns the artifact write, so self-writing benches are told
    not to (write_artifact=False)."""
    accepted = inspect.signature(fn).parameters
    kwargs = {}
    if "quick" in accepted:
        kwargs["quick"] = quick
    if "write_artifact" in accepted:
        kwargs["write_artifact"] = False
    return fn(**kwargs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*",
                    help="benches to run (default: the fast set)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale for benches that support it")
    ap.add_argument("--json-dir", default=None,
                    help="directory for the canonical BENCH_<name>.json "
                         "artifacts (default: repo root — the tracked, "
                         "committed location CI compares against)")
    args = ap.parse_args(argv)

    from benchmarks.artifact import (bench_artifact_path, environment_info,
                                     write_bench_artifact)
    _register()
    names = args.names or ["overhead", "table1", "fig1", "table2", "table3",
                           "roofline", "data_pipeline"]
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        print(f"[bench] unknown bench(es) {unknown}; "
              f"available: {sorted(BENCHES)}")
        return 2
    failures = []
    for name in names:
        print(f"[bench] {name}")
        t0 = time.time()
        try:
            out = _call(BENCHES[name], args.quick)
            env = {**environment_info(),
                   "elapsed_s": round(time.time() - t0, 1)}
            path = write_bench_artifact(name, out if isinstance(out, dict)
                                        else {"value": out},
                                        quick=args.quick,
                                        json_dir=args.json_dir, env=env)
            print(f"[bench] {name} done in {time.time()-t0:.0f}s "
                  f"-> {path}\n")
        except Exception as e:  # report and continue to the next bench
            failures.append(name)
            print(f"[bench] {name} FAILED: {type(e).__name__}: {e}\n")
    if failures:
        print(f"[bench] failed benches: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
