"""Canonical BENCH artifact format.

Every benchmark converges on ONE schema-versioned envelope, written to
``BENCH_<name>.json`` at the repo root (or ``--json-dir``), committed
per change so the perf trajectory is a tracked curve instead of a
one-off CI artifact:

    {
      "schema_version": 1,
      "bench":   "overhead",          # which benchmark produced it
      "quick":   true,                # CI smoke scale vs full scale
      "results": {...},               # benchmark-specific payload
      "env":     {"jax": "...", ...}  # optional, informational only
    }

``validate_envelope`` is STRICT: unknown top-level fields are rejected
(an artifact with extra fields means a producer and the gate disagree
about the schema — fail loudly, don't guess), as are missing required
fields and unknown schema versions.  ``benchmarks/check_bench.py`` runs
this validation before evaluating any threshold.
"""
from __future__ import annotations

import json
import os
import platform
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1
REQUIRED_FIELDS = ("schema_version", "bench", "quick", "results")
OPTIONAL_FIELDS = ("env",)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_artifact_path(name: str, json_dir: Optional[str] = None) -> str:
    """The canonical location: ``<json_dir or repo root>/BENCH_<name>.json``.
    CI and local runs pass the same ``--json-dir`` (or none) and land on
    the same paths."""
    return os.path.join(json_dir or REPO_ROOT, f"BENCH_{name}.json")


def environment_info() -> Dict[str, Any]:
    import jax
    return {"jax": jax.__version__,
            "python": platform.python_version(),
            "platform": jax.default_backend(),
            "n_devices": jax.device_count()}


def make_envelope(name: str, results: Dict[str, Any], *, quick: bool,
                  env: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    return {"schema_version": SCHEMA_VERSION, "bench": name,
            "quick": bool(quick), "results": results,
            "env": env if env is not None else environment_info()}


def validate_envelope(obj: Any) -> List[str]:
    """Return a list of problems (empty = valid).  Strict by design:
    missing required fields, unknown fields, and unknown schema versions
    all fail."""
    problems = []
    if not isinstance(obj, dict):
        return [f"artifact must be a JSON object, got {type(obj).__name__}"]
    for f in REQUIRED_FIELDS:
        if f not in obj:
            problems.append(f"missing required field {f!r}")
    known = set(REQUIRED_FIELDS) | set(OPTIONAL_FIELDS)
    for f in sorted(set(obj) - known):
        problems.append(f"unknown field {f!r} (producer/gate schema skew)")
    sv = obj.get("schema_version")
    if "schema_version" in obj and sv != SCHEMA_VERSION:
        problems.append(f"unknown schema_version {sv!r} "
                        f"(this gate understands {SCHEMA_VERSION})")
    if "bench" in obj and not isinstance(obj["bench"], str):
        problems.append(f"field 'bench' must be a string, got "
                        f"{type(obj['bench']).__name__}")
    if "quick" in obj and not isinstance(obj["quick"], bool):
        problems.append(f"field 'quick' must be a bool, got "
                        f"{type(obj['quick']).__name__}")
    if "results" in obj and not isinstance(obj["results"], dict):
        problems.append(f"field 'results' must be an object, got "
                        f"{type(obj['results']).__name__}")
    return problems


# Per-run record schema for the sweep artifact (BENCH_sweep.json):
# results = {"record_schema_version": 1, "records": [...], "config": {...}}
# and every record carries at least these fields.  check_bench.py
# validates this shape whenever the artifact's bench name is "sweep".
SWEEP_RECORD_SCHEMA_VERSION = 1
SWEEP_RECORD_REQUIRED = ("name", "arch", "family", "fused", "batch",
                         "steps", "grad_computations", "budget_unit",
                         "final_loss", "wall_time_s", "engine")
SWEEP_ENGINE_REQUIRED = ("launches_per_step", "packed_bytes_per_step",
                         "param_bytes_live")


def validate_sweep_results(results: Any) -> List[str]:
    """Problems with a sweep artifact's ``results`` payload (empty =
    valid): the record-schema version must be known and every record
    must carry the required fields, including the engine counters."""
    problems = []
    if not isinstance(results, dict):
        return ["sweep results must be an object"]
    rsv = results.get("record_schema_version")
    if rsv != SWEEP_RECORD_SCHEMA_VERSION:
        problems.append(f"unknown record_schema_version {rsv!r} "
                        f"(expected {SWEEP_RECORD_SCHEMA_VERSION})")
    records = results.get("records")
    if not isinstance(records, list) or not records:
        problems.append("sweep results must carry a non-empty 'records' list")
        return problems
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            problems.append(f"records[{i}] must be an object")
            continue
        tag = rec.get("name", f"records[{i}]")
        for f in SWEEP_RECORD_REQUIRED:
            if f not in rec:
                problems.append(f"{tag}: missing record field {f!r}")
        eng = rec.get("engine")
        if isinstance(eng, dict):
            for f in SWEEP_ENGINE_REQUIRED:
                if f not in eng:
                    problems.append(f"{tag}: missing engine counter {f!r}")
        elif "engine" in rec:
            problems.append(f"{tag}: 'engine' must be an object")
    return problems


def write_bench_artifact(name: str, results: Dict[str, Any], *,
                         quick: bool = False,
                         json_dir: Optional[str] = None,
                         env: Optional[Dict[str, Any]] = None) -> str:
    path = bench_artifact_path(name, json_dir)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    envelope = make_envelope(name, results, quick=quick, env=env)
    problems = validate_envelope(envelope)
    assert not problems, problems   # producer bug, not user input
    with open(path, "w") as f:
        json.dump(envelope, f, indent=1, default=str, sort_keys=True)
        f.write("\n")
    return path


def load_bench_artifact(path: str) -> Dict[str, Any]:
    """Load + validate; raises ValueError with every problem listed."""
    with open(path) as f:
        obj = json.load(f)
    problems = validate_envelope(obj)
    if problems:
        raise ValueError(f"{path}: invalid BENCH artifact: "
                         + "; ".join(problems))
    return obj
