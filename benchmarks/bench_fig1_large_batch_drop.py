"""Figure 1 reproduction (reduced scale): MSGD small-batch vs MSGD
large-batch on the two-conv-layer network — large batch degrades both
training loss and test accuracy at the same number of epochs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import train_convnet
from repro.core import msgd
from repro.core.schedules import poly_power
from repro.data.synthetic import synthetic_images

N_TRAIN, N_TEST = 4096, 1024
EPOCHS = 16


def run():
    x, y = synthetic_images(N_TRAIN, seed=0)
    xt, yt = synthetic_images(N_TEST, seed=99)
    rows = []
    for batch, lr in ((64, 0.05), (1024, 0.4)):
        steps = EPOCHS * N_TRAIN // batch
        r = train_convnet(msgd(poly_power(lr, steps, 1.1), beta=0.9,
                               weight_decay=1e-4),
                          x, y, xt, yt, batch, steps)
        rows.append((f"fig1_msgd_b{batch}", r))
        print(f"  msgd B={batch:5d}: loss={r['final_loss']:.4f} "
              f"acc={r['test_acc']:.4f}")
    small, large = rows[0][1], rows[1][1]
    print(f"  -> large-batch drop (paper Fig.1): "
          f"acc {small['test_acc']:.3f} -> {large['test_acc']:.3f}, "
          f"loss {small['final_loss']:.3f} -> {large['final_loss']:.3f}")
    return {name: {"final_loss": r["final_loss"], "test_acc": r["test_acc"]}
            for name, r in rows}


if __name__ == "__main__":
    run()
