"""Shared benchmark loops: image-classification (Fig-1/Table-2 convnet)
and LM (Table-3 transformer proxy) training with gradient accumulation
(the paper's large-batch mechanism, §5).

Both loops run on the unified ``TrainState`` path (``opt.init_state`` /
``opt.step_state``, jitted with donation), so a fused resident optimizer
(``fused="multi_tensor"``) keeps its flat buffers as the single
parameter owner exactly as in production training — the sweep harness
(bench_sweep.py) measures the paper's science on the same execution path
the launcher ships.

Every loop logs through ``repro.tracker``: pass ``tracker=`` to stream
per-step records (loss, grad_norm, lr, wall-clock, throughput) to any
backend; an internal MemoryTracker always collects the curve that the
returned result dict summarizes.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optim import Optimizer
from repro.models.convnet import accuracy, ce_loss, init_convnet
from repro.tracker import CompositeTracker, MemoryTracker, NullTracker
from repro.tracker.callbacks import CallbackRunner, StepTimer


def _tracked(tracker, callbacks, log_every):
    """(runner, mem): a CallbackRunner fanning out to the caller's
    tracker plus an internal MemoryTracker that records the full curve."""
    mem = MemoryTracker()
    fan = CompositeTracker([mem, tracker if tracker is not None
                            else NullTracker()])
    return CallbackRunner(fan, callbacks, flush_every=max(1, log_every)), mem


def train_convnet(opt: Optimizer, x, y, xt, yt, batch: int, steps: int,
                  accum_micro: int = 128, seed: int = 0, log_every: int = 0,
                  tracker=None, ghost_batch: Optional[int] = None):
    """Train the Fig-1 convnet with global batch `batch`; batches larger
    than `accum_micro` use gradient accumulation exactly as the paper.
    ``ghost_batch`` turns on parameter-free ghost batch normalization
    (Hoffer et al.) with that virtual batch size — the normalization
    statistics stay small-batch even on the large-batch rungs.  The
    optimizer step runs donated over the unified TrainState, so a
    resident fused optimizer holds ~1x param bytes throughout."""
    ts = opt.init_state(init_convnet(seed))
    n = x.shape[0]
    micro = min(batch, accum_micro)
    n_micro = batch // micro
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, xb, yb: ce_loss(p, xb, yb, ghost_batch=ghost_batch)))
    opt_step = jax.jit(opt.step_state, donate_argnums=(1,))

    runner, mem = _tracked(tracker, [StepTimer(examples_per_step=batch)],
                           log_every or 50)
    rng = np.random.RandomState(seed)
    last_loss = np.inf
    for t in range(steps):
        idx = rng.randint(0, n, size=(batch,))
        # read-only view of the (possibly resident) parameters for the
        # grad passes; the update below consumes the donated state
        params = ts.params_view
        g_sum = None
        l_sum = 0.0
        for m in range(n_micro):
            sl = idx[m * micro:(m + 1) * micro]
            l, g = grad_fn(params, x[sl], y[sl])
            l_sum += float(l)
            g_sum = g if g_sum is None else jax.tree.map(jnp.add, g_sum, g)
        grads = jax.tree.map(lambda a: a / n_micro, g_sum)
        ts, stats = opt_step(grads, ts)
        last_loss = l_sum / n_micro
        runner.push(t, {"loss": last_loss, **stats})
        if log_every and (t + 1) % log_every == 0:
            print(f"    step {t+1}: loss={last_loss:.4f} "
                  f"gnorm={float(stats['grad_norm']):.3f}")
        if not np.isfinite(last_loss):
            break
    diverged = not np.isfinite(last_loss)
    acc = 0.0 if diverged else float(
        accuracy(ts.params_view, xt, yt, ghost_batch=ghost_batch))
    runner.close({"final_loss": last_loss, "test_acc": acc,
                  "diverged": diverged})
    return {"final_loss": last_loss, "test_acc": acc,
            "losses": mem.series("loss"), "diverged": diverged,
            "wall_time_s": mem.summary.get("wall_time_s", 0.0),
            "examples_per_s": mem.summary.get("examples_per_s", 0.0)}


def train_lm(opt: Optimizer, cfg, batch: int, seq: int, steps: int,
             n_micro: int = 1, seed: int = 0, tracker=None,
             log_every: int = 0, runtime=None,
             data_dir: Optional[str] = None, prefetch: int = 0):
    """Train a (smoke-scale) LM config on the learnable synthetic bigram
    language for `steps` steps of global batch `batch` — the Table-3
    equal-C loop, on the donated TrainState path (``make_train_step``,
    ``donate_argnums=(0,)``), shared by bench_table3 and bench_sweep.

    ``data_dir`` switches the input from the in-process ``batch_at``
    stream to an on-disk ``repro-data-pack`` dataset read through the
    ``StreamingLoader`` (``prefetch`` > 0 adds that deep a host→device
    prefetch queue and stamps the input-stall counters into the result)
    — the real-data rung of the sweep."""
    from repro.data import (DiskShardedSource, PrefetchIterator,
                            StreamingLoader, SyntheticLM)
    from repro.models import CPU_RUNTIME, model_defs
    from repro.models.param import materialize
    from repro.tracker.callbacks import PrefetchMonitor
    from repro.training import make_train_step, run_steps

    params = materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    state = opt.init_state(params)
    del params
    step = jax.jit(make_train_step(cfg, runtime or CPU_RUNTIME, opt,
                                   n_micro=n_micro),
                   donate_argnums=(0,))
    callbacks = [StepTimer(tokens_per_step=batch * seq)]
    loader = prefetcher = None
    if data_dir:
        source = DiskShardedSource(data_dir)
        v = source.meta.get("vocab_size")
        if v is not None and v != cfg.vocab_size:
            raise ValueError(f"dataset {data_dir!r} vocab_size {v} != "
                             f"model vocab {cfg.vocab_size}")
        loader = StreamingLoader(source, batch, seed=seed)
        batches = loader
        if prefetch > 0:
            prefetcher = PrefetchIterator(loader, depth=prefetch)
            batches = prefetcher
            callbacks.append(PrefetchMonitor(prefetcher))
        optimal = float(source.meta.get("optimal_loss", float("nan")))
    else:
        data = SyntheticLM(cfg.vocab_size, seq, batch, branching=4)
        batches = data.batch_at
        optimal = float(data.optimal_loss())
    mem = MemoryTracker()
    fan = CompositeTracker([mem, tracker if tracker is not None
                            else NullTracker()])
    run_steps(step, state, batches, steps, tracker=fan,
              log_every=log_every or 50, callbacks=callbacks)
    if prefetcher is not None:
        prefetcher.close()
    elif loader is not None:
        loader.close()
    losses = mem.series("loss")
    out = {"losses": losses, "final_loss": losses[-1],
           "optimal_loss": optimal,
           "wall_time_s": mem.summary.get("wall_time_s", 0.0),
           "tokens_per_s": mem.summary.get("tokens_per_s", 0.0)}
    if prefetcher is not None:
        out["input_stall_s_per_step"] = mem.summary.get(
            "input_stall_s_per_step", 0.0)
        out["prefetch_depth_avg"] = mem.summary.get("prefetch_depth_avg", 0.0)
    return out


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.2f},{derived}"
