"""Shared benchmark utilities: image-classification and LM training loops
with gradient accumulation (the paper's large-batch mechanism, §5)."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optim import Optimizer
from repro.models.convnet import accuracy, ce_loss, init_convnet


def train_convnet(opt: Optimizer, x, y, xt, yt, batch: int, steps: int,
                  accum_micro: int = 128, seed: int = 0, log_every: int = 0):
    """Train the Fig-1 convnet with global batch `batch`; batches larger
    than `accum_micro` use gradient accumulation exactly as the paper."""
    params = init_convnet(seed)
    state = opt.init(params)
    n = x.shape[0]
    micro = min(batch, accum_micro)
    n_micro = batch // micro
    grad_fn = jax.jit(jax.value_and_grad(ce_loss))

    @jax.jit
    def opt_step(grads, state, params):
        return opt.step(grads, state, params)

    rng = np.random.RandomState(seed)
    losses = []
    for t in range(steps):
        idx = rng.randint(0, n, size=(batch,))
        g_sum = None
        l_sum = 0.0
        for m in range(n_micro):
            sl = idx[m * micro:(m + 1) * micro]
            l, g = grad_fn(params, x[sl], y[sl])
            l_sum += float(l)
            g_sum = g if g_sum is None else jax.tree.map(jnp.add, g_sum, g)
        grads = jax.tree.map(lambda a: a / n_micro, g_sum)
        params, state, stats = opt_step(grads, state, params)
        losses.append(l_sum / n_micro)
        if log_every and (t + 1) % log_every == 0:
            print(f"    step {t+1}: loss={losses[-1]:.4f} "
                  f"gnorm={float(stats['grad_norm']):.3f}")
        if not np.isfinite(losses[-1]):
            break
    acc = float(accuracy(params, xt, yt)) if np.isfinite(losses[-1]) else 0.0
    return {"final_loss": losses[-1], "test_acc": acc, "losses": losses,
            "diverged": not np.isfinite(losses[-1])}


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.2f},{derived}"
