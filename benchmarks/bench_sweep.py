"""Paper-scale experiment sweep: the Fig-1 batch-size ladder and the
Table-2/3 proxies, end-to-end on the fused resident TrainState path.

SNGM vs MSGD vs LAMB at MATCHED gradient computations (the paper's
comparison axis, after Keskar et al. 1609.04836 and Hoffer et al.
1705.08741: batch size vs test quality at fixed compute):

  * convnet ladder (Fig-1 / Table-2 proxy, non-transformer — the
    optimizer stack is architecture-agnostic): every batch size sees the
    same `epochs * n_train` example budget, so bigger batches take
    proportionally fewer steps;
  * LM ladder (Table-3 proxy, smoke transformer on the learnable bigram
    language): every batch size sees the same token budget;
  * an optional Hoffer-style "train longer" baseline: MSGD at the
    largest batch with a doubled epoch budget (full mode only);
  * a ghost-batch-norm axis (Hoffer et al.): the largest convnet rung
    again with parameter-free ghost normalization, so the sweep
    separates optimization effects from normalization-statistics
    effects at large batch.

Every run trains through ``benchmarks.common`` (donated TrainState,
``fused="multi_tensor"`` — flat buffers as the single parameter owner),
streams step metrics through ``repro.tracker``, and emits one
schema-versioned record stamped with the engine counters
(launches/packed-bytes/param-residency) that the CI gate tracks.  The
whole sweep lands in canonical ``BENCH_sweep.json`` via
``benchmarks.artifact``.

CLI:  python -m benchmarks.bench_sweep [--quick] [--json-dir DIR]
                                       [--jsonl-dir DIR] [--data-dir DS]
``--quick`` is the CI smoke scale; ``--jsonl-dir`` additionally writes
one per-step JSONL metrics file per run; ``--data-dir`` adds a
real-data LM rung (on-disk pack through the StreamingLoader +
prefetch, records suffixed ``_disk`` and stamped with the input-stall
counters).
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Optional, Sequence

from benchmarks.artifact import (SWEEP_RECORD_SCHEMA_VERSION,
                                 validate_sweep_results,
                                 write_bench_artifact)
from benchmarks.common import train_convnet, train_lm
from repro.core import lamb, msgd, sngm
from repro.core.schedules import poly_power

FAMILIES = ("sngm", "msgd", "lamb")

# base lrs at the smallest ladder rung; larger batches sqrt-scale
_BASE_LR = {"sngm": 0.2, "msgd": 0.05, "lamb": 0.02}
_BASE_LR_LM = {"sngm": 0.5, "msgd": 0.15, "lamb": 0.02}


def make_opt(family: str, steps: int, batch: int, base_batch: int,
             base_lr: Optional[Dict[str, float]] = None,
             fused: Optional[str] = "multi_tensor"):
    """One optimizer family at one ladder rung, on the fused engine.
    lr sqrt-scales with the batch (the common large-batch heuristic);
    schedule/momentum/decay mirror the Table-2 recipe."""
    lr = (base_lr or _BASE_LR)[family] * (batch / base_batch) ** 0.5
    sched = poly_power(lr, steps, 1.1)
    if family == "sngm":
        return sngm(sched, beta=0.9, weight_decay=1e-4, fused=fused)
    if family == "msgd":
        return msgd(sched, beta=0.9, weight_decay=1e-4, fused=fused)
    if family == "lamb":
        return lamb(sched, weight_decay=1e-4, fused=fused)
    raise ValueError(f"unknown family {family!r}")


def _engine_stamp(opt, params) -> Dict[str, int]:
    from repro.tracker.counters import engine_counters
    return engine_counters(opt, params)


def _run_tracker(jsonl_dir: Optional[str], name: str):
    if not jsonl_dir:
        return None
    from repro.tracker import JsonlTracker
    return JsonlTracker(os.path.join(jsonl_dir, f"{name}.jsonl"))


def convnet_ladder(batches: Sequence[int], epochs: int, n_train: int,
                   n_test: int, families: Sequence[str] = FAMILIES,
                   train_longer: bool = False,
                   ghost_batch: Optional[int] = None,
                   jsonl_dir: Optional[str] = None) -> List[dict]:
    """Fig-1/Table-2 proxy: every rung sees epochs*n_train examples.
    ``ghost_batch`` adds a ghost-batch-norm axis: the LARGEST rung again
    with parameter-free ghost normalization (Hoffer et al.) at that
    virtual batch size — the classic control for whether large-batch
    degradation is a normalization-statistics artifact."""
    from repro.data import synthetic_images
    from repro.models.convnet import init_convnet

    x, y = synthetic_images(n_train, seed=0)
    xt, yt = synthetic_images(n_test, seed=99)
    base_batch = min(batches)
    records = []

    jobs = [(b, epochs, "", None) for b in batches]
    if train_longer:
        # Hoffer et al.: "train longer, generalize better" — the largest
        # batch again, with twice the example budget
        jobs.append((max(batches), 2 * epochs, "_longer", None))
    if ghost_batch:
        jobs.append((max(batches), epochs, "_ghost", ghost_batch))

    stamps: Dict[str, Dict[str, int]] = {}
    for family in families:
        for batch, eps, suffix, gb in jobs:
            steps = max(1, eps * n_train // batch)
            opt = make_opt(family, steps, batch, base_batch)
            if family not in stamps:
                stamps[family] = _engine_stamp(opt, init_convnet(0))
            name = f"convnet_{family}_b{batch}{suffix}"
            r = train_convnet(opt, x, y, xt, yt, batch, steps,
                              ghost_batch=gb,
                              tracker=_run_tracker(jsonl_dir, name))
            records.append({
                "name": name, "arch": "convnet", "family": family,
                "fused": "multi_tensor", "batch": batch, "steps": steps,
                "grad_computations": steps * batch,
                "budget_unit": "examples",
                "ghost_batch": gb,
                "final_loss": r["final_loss"], "test_acc": r["test_acc"],
                "diverged": r["diverged"],
                "wall_time_s": r["wall_time_s"],
                "throughput": r["examples_per_s"],
                "engine": stamps[family],
            })
            print(f"  {name:28s} steps={steps:4d}: "
                  f"loss={r['final_loss']:.4f} acc={r['test_acc']:.4f} "
                  f"launches/step={stamps[family]['launches_per_step']}")
    return records


def lm_ladder(batches: Sequence[int], seq: int, tokens_budget: int,
              families: Sequence[str] = FAMILIES,
              jsonl_dir: Optional[str] = None) -> List[dict]:
    """Table-3 proxy: every rung sees the same token budget (equal C)."""
    import jax

    from benchmarks.bench_table3_lm_proxy import proxy_config
    from repro.models import model_defs
    from repro.models.param import materialize

    cfg = proxy_config()
    base_batch = min(batches)
    records = []
    stamps: Dict[str, Dict[str, int]] = {}
    for family in families:
        for batch in batches:
            steps = max(1, tokens_budget // (batch * seq))
            opt = make_opt(family, steps, batch, base_batch,
                           base_lr=_BASE_LR_LM)
            if family not in stamps:
                params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
                stamps[family] = _engine_stamp(opt, params)
                del params
            name = f"lm_{family}_b{batch}"
            r = train_lm(opt, cfg, batch, seq, steps,
                         n_micro=max(1, batch // 16),
                         tracker=_run_tracker(jsonl_dir, name))
            records.append({
                "name": name, "arch": "transformer", "family": family,
                "fused": "multi_tensor", "batch": batch, "steps": steps,
                "grad_computations": steps * batch * seq,
                "budget_unit": "tokens",
                "final_loss": r["final_loss"],
                "optimal_loss": r["optimal_loss"],
                "wall_time_s": r["wall_time_s"],
                "throughput": r["tokens_per_s"],
                "engine": stamps[family],
            })
            print(f"  {name:28s} steps={steps:4d}: "
                  f"loss={r['final_loss']:.4f} "
                  f"(chain entropy {r['optimal_loss']:.3f}) "
                  f"launches/step={stamps[family]['launches_per_step']}")
    return records


def lm_disk_rung(data_dir: str, batch: int, seq: int, tokens_budget: int,
                 families: Sequence[str] = FAMILIES, prefetch: int = 2,
                 jsonl_dir: Optional[str] = None) -> List[dict]:
    """Real-data rung: the Table-3 LM proxy trained from an on-disk
    ``repro-data-pack`` dataset through the StreamingLoader + prefetch.
    Records carry the standard sweep schema (names suffixed ``_disk``)
    plus the measured input-stall counters, so the artifact shows the
    disk pipeline keeping up with the same step the synthetic stream
    feeds.  The dataset's index meta is validated against the proxy
    config up front — a vocab mismatch must fail loudly, not train on
    out-of-range tokens."""
    import jax

    from benchmarks.bench_table3_lm_proxy import proxy_config
    from repro.data import DiskShardedSource, n_examples
    from repro.models import model_defs
    from repro.models.param import materialize

    cfg = proxy_config()
    probe = DiskShardedSource(data_dir)
    meta, total = probe.meta, n_examples(probe)
    probe.close()
    v = meta.get("vocab_size")
    if v is not None and v != cfg.vocab_size:
        raise ValueError(f"--data-dir {data_dir!r}: dataset vocab_size {v} "
                         f"!= LM proxy vocab {cfg.vocab_size} — repack with "
                         f"--vocab {cfg.vocab_size}")
    seq = int(meta.get("seq_len", seq))   # the pack fixes the sequence length
    base_batch = batch
    steps = max(1, tokens_budget // (batch * seq))
    records = []
    stamps: Dict[str, Dict[str, int]] = {}
    print(f"[sweep] disk rung: {data_dir} ({total} examples, seq={seq}) "
          f"B={batch} x {list(families)}, prefetch={prefetch}")
    for family in families:
        opt = make_opt(family, steps, batch, base_batch, base_lr=_BASE_LR_LM)
        if family not in stamps:
            params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
            stamps[family] = _engine_stamp(opt, params)
            del params
        name = f"lm_{family}_b{batch}_disk"
        r = train_lm(opt, cfg, batch, seq, steps,
                     n_micro=max(1, batch // 16),
                     data_dir=data_dir, prefetch=prefetch,
                     tracker=_run_tracker(jsonl_dir, name))
        records.append({
            "name": name, "arch": "transformer", "family": family,
            "fused": "multi_tensor", "batch": batch, "steps": steps,
            "grad_computations": steps * batch * seq,
            "budget_unit": "tokens",
            "data_dir": data_dir,
            "final_loss": r["final_loss"],
            "optimal_loss": r["optimal_loss"],
            "wall_time_s": r["wall_time_s"],
            "throughput": r["tokens_per_s"],
            "input_stall_s_per_step": r.get("input_stall_s_per_step"),
            "prefetch_depth_avg": r.get("prefetch_depth_avg"),
            "engine": stamps[family],
        })
        stall = r.get("input_stall_s_per_step")
        print(f"  {name:28s} steps={steps:4d}: "
              f"loss={r['final_loss']:.4f} "
              f"stall={(stall or 0.0)*1e3:.2f}ms/step "
              f"launches/step={stamps[family]['launches_per_step']}")
    return records


def run(quick: bool = False, json_path: str | None = None,
        json_dir: Optional[str] = None, jsonl_dir: Optional[str] = None,
        convnet_batches: Optional[Sequence[int]] = None,
        convnet_epochs: Optional[int] = None,
        convnet_n_train: Optional[int] = None,
        lm_batches: Optional[Sequence[int]] = None,
        lm_seq: Optional[int] = None,
        lm_tokens_budget: Optional[int] = None,
        families: Sequence[str] = FAMILIES,
        ghost_batch: Optional[int] = None,
        data_dir: Optional[str] = None, prefetch: int = 2,
        write_artifact: bool = True) -> dict:
    """Run the ladder(s) and write canonical BENCH_sweep.json.  The
    explicit knobs exist for the fast-lane pytest smoke, which runs a
    micro ladder and asserts the record shape; ``--quick`` is the CI
    bench-lane scale; defaults are the nightly full sweep."""
    del json_path  # benchmarks.run passes it to every bench; unused here
    if quick:
        cb = convnet_batches or (32, 128)
        ce, cn = convnet_epochs or 2, convnet_n_train or 512
        lb = lm_batches or (8, 32)
        ls = lm_seq or 32
        ltb = lm_tokens_budget or 8 * 32 * 24
        train_longer = False
        gb = ghost_batch or 16
    else:
        cb = convnet_batches or (64, 256, 1024)
        ce, cn = convnet_epochs or 8, convnet_n_train or 4096
        lb = lm_batches or (16, 64, 256)
        ls = lm_seq or 64
        ltb = lm_tokens_budget or 256 * 64 * 8
        train_longer = True
        gb = ghost_batch or 32

    records: List[dict] = []
    if cb:
        print(f"[sweep] convnet ladder B={list(cb)} x {list(families)} "
              f"({ce} epochs x {cn} examples each, ghost batch {gb})")
        records += convnet_ladder(cb, ce, cn, max(cn // 4, 64),
                                  families=families,
                                  train_longer=train_longer,
                                  ghost_batch=gb,
                                  jsonl_dir=jsonl_dir)
    if lb:
        print(f"[sweep] LM ladder B={list(lb)} x {list(families)} "
              f"({ltb} tokens each, seq={ls})")
        records += lm_ladder(lb, ls, ltb, families=families,
                             jsonl_dir=jsonl_dir)
    if data_dir:
        records += lm_disk_rung(data_dir, max(lb), ls, ltb,
                                families=families, prefetch=prefetch,
                                jsonl_dir=jsonl_dir)

    # the Fig-1 readout: per family, quality at the smallest vs largest
    # rung of each ladder (matched compute — the generalization gap)
    gaps = {}
    for arch, key in (("convnet", "test_acc"), ("transformer", "final_loss")):
        for family in families:
            rung = [r for r in records
                    if r["arch"] == arch and r["family"] == family
                    and not r["name"].endswith(("_longer", "_ghost"))]
            if len(rung) >= 2:
                lo = min(rung, key=lambda r: r["batch"])
                hi = max(rung, key=lambda r: r["batch"])
                gaps[f"{arch}_{family}"] = {
                    "metric": key, "small_batch": lo[key],
                    "large_batch": hi[key],
                    "gap": hi[key] - lo[key]}
    for k, g in sorted(gaps.items()):
        print(f"  gap {k:24s} {g['metric']}: {g['small_batch']:.4f} -> "
              f"{g['large_batch']:.4f} ({g['gap']:+.4f})")

    results = {"record_schema_version": SWEEP_RECORD_SCHEMA_VERSION,
               "records": records, "gaps": gaps,
               "config": {"convnet_batches": list(cb),
                          "convnet_epochs": ce, "convnet_n_train": cn,
                          "lm_batches": list(lb), "lm_seq": ls,
                          "lm_tokens_budget": ltb,
                          "families": list(families),
                          "train_longer": train_longer,
                          "ghost_batch": gb,
                          "data_dir": data_dir, "prefetch": prefetch}}
    problems = validate_sweep_results(results)
    assert not problems, problems   # producer-side schema self-check
    if write_artifact:
        path = write_bench_artifact("sweep", results, quick=quick,
                                    json_dir=json_dir)
        print(f"[sweep] wrote {path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (small ladders, few steps)")
    ap.add_argument("--json-dir", default=None,
                    help="directory for BENCH_sweep.json (default: repo root)")
    ap.add_argument("--jsonl-dir", default=None,
                    help="also write one per-step JSONL metrics file per "
                         "run into this directory")
    ap.add_argument("--ghost-batch", type=int, default=None,
                    help="virtual batch size for the ghost-batch-norm rung "
                         "(default: 16 quick / 32 full)")
    ap.add_argument("--data-dir", default=None,
                    help="repro-data-pack dataset dir: adds a real-data LM "
                         "rung (StreamingLoader + prefetch, records "
                         "suffixed _disk with input-stall counters); the "
                         "index meta must match the LM proxy vocab")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="prefetch depth for the --data-dir rung (0 = "
                         "synchronous reads)")
    args = ap.parse_args()
    run(quick=args.quick, json_dir=args.json_dir, jsonl_dir=args.jsonl_dir,
        ghost_batch=args.ghost_batch, data_dir=args.data_dir,
        prefetch=args.prefetch)
