"""Per-op HBM-byte attribution over a compiled train step's HLO.

Where does the memory traffic of one production train step actually go?
This walks the partitioned HLO the dry-run compiles (trip-count-aware,
fusion-level accounting — the same model `launch/hlo_cost.analyze` uses
for the roofline) and prints the top-N byte-heaviest ops, so a regression
in remat policy, gather dtype, or optimizer residency shows up as a
named op instead of a single opaque total.

    PYTHONPATH=src python -m benchmarks.hlo_bytes_breakdown \
        --arch deepseek-v2-236b --shape train_4k --precision opt --top 14

(Replaces the root-level scratch_ds.py dev script.)
"""
# Must run before any other jax import: the production mesh needs 512
# placeholder devices and jax locks the device count on first init.
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re


def attribute_bytes(txt: str):
    """Walk the HLO entry computation like hlo_cost.analyze does, but
    keep the per-op attribution instead of summing it away.  Returns
    {(opcode, result-shape-prefix): bytes} with while-loop trip counts
    multiplied through and fusion bodies charged to their fusion op."""
    from repro.launch import hlo_cost
    comps, shapes = hlo_cost._parse(txt)
    rows = collections.defaultdict(float)

    def walk(cn, in_fusion, mult):
        for op in comps.get(cn, []):
            oc = op.opcode
            trip = 1.0
            called = []
            for m in hlo_cost._CALLED_RE.finditer(op.rest):
                if m.group(1):
                    called.append(m.group(1))
                else:
                    called += re.findall(r"%([\w\.\-]+)", m.group(2))
            if oc == "while":
                tm = hlo_cost._TRIP_RE.search(op.rest)
                trip = float(tm.group(1)) if tm else 1.0
            child_fusion = in_fusion or oc == "fusion"
            for ch in called:
                walk(ch, child_fusion, mult * trip)
            if in_fusion:
                continue
            if oc == "fusion" and called:
                b = hlo_cost._fusion_bytes(comps.get(called[0], []),
                                           op.result)
            elif oc in hlo_cost._FREE_OPS or oc == "while":
                continue
            else:
                opnds = op.operands()
                b = (hlo_cost._shape_bytes(op.result)
                     + sum(hlo_cost._shape_bytes(shapes.get(o, ""))
                           for o in opnds))
            rows[(oc, op.result[:44])] += mult * b

    entry = re.search(r"^ENTRY\s+%([\w\.\-]+)", txt, re.M).group(1)
    walk(entry, False, 1.0)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-236b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--precision", default="opt",
                    choices=["baseline", "opt", "opt-cf1"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lowered
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    built, skip = build_lowered(args.arch, args.shape, mesh, args.precision)
    if skip:
        raise SystemExit(f"skipped: {skip}")
    lowered, cfg, shape = built
    txt = lowered.compile().as_text()
    rows = attribute_bytes(txt)
    print(f"{args.arch} {args.shape} {args.precision}: top {args.top} "
          f"byte-heaviest HLO ops (per device, trip-count weighted)")
    for (oc, result), v in sorted(rows.items(), key=lambda kv: -kv[1])[
            :args.top]:
        print(f"{v / 1e12:8.2f}TB {oc:16s} {result}")
    print(f"total {sum(rows.values()) / 1e12:.2f}TB")


if __name__ == "__main__":
    main()
