"""CI gate for BENCH artifacts: declarative thresholds + trend compare.

Replaces the inline ``python - <<EOF`` heredoc asserts that used to live
in ``.github/workflows/ci.yml``: one tool validates the schema-versioned
artifact envelope, evaluates a committed declarative thresholds file
(``benchmarks/bench_thresholds.json``), prints a readable pass/fail
table, and exits nonzero on any failure — so the guarantees (exact
launch counts, resident packing ratio, 1x param residency, zero
donation warnings) live in reviewable JSON instead of workflow YAML.

    python -m benchmarks.check_bench BENCH_overhead.json
    python -m benchmarks.check_bench fresh.json --trend --baseline BENCH_overhead.json

Threshold ops (each keyed by a dotted path into the artifact's
``results`` payload):

    {"op": "eq",        "value": 2}              value == 2
    {"op": "eq_key",    "key": "a.b"}            value == results[a.b]
    {"op": "gt_key",    "key": "a.b"}            value >  results[a.b]
    {"op": "ratio_eq",  "key": "a.b", "ratio": 2}  value == 2 * results[a.b]
    {"op": "max_ratio", "key": "a.b", "ratio": .5} value <  .5 * results[a.b]
    {"op": "max",       "value": 0.003}          value <= 0.003
    {"op": "min",       "value": 1.0}            value >= 1.0
    {"op": "empty"}                              value is an empty list

A bench section may also carry ``record_checks`` (applied to every
record of a sweep artifact) and ``trend`` (dotted keys compared against
a committed baseline artifact in ``--trend`` mode: an increase beyond
``tol`` fails — lower is always better for the tracked counters).

No jax import: the gate runs in milliseconds anywhere.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

from benchmarks.artifact import (load_bench_artifact, validate_sweep_results)

DEFAULT_THRESHOLDS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "bench_thresholds.json")
THRESHOLDS_SCHEMA_VERSION = 1


class CheckError(ValueError):
    pass


def dotted_get(obj: Any, path: str) -> Any:
    cur = obj
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise CheckError(f"results key {path!r} missing "
                             f"(failed at {part!r})")
        cur = cur[part]
    return cur


def _describe(spec: Dict[str, Any]) -> str:
    op = spec.get("op")
    if op == "eq":
        return f"== {spec['value']}"
    if op == "eq_key":
        return f"== [{spec['key']}]"
    if op == "gt_key":
        return f"> [{spec['key']}]"
    if op == "ratio_eq":
        return f"== {spec['ratio']} * [{spec['key']}]"
    if op == "max_ratio":
        return f"< {spec['ratio']} * [{spec['key']}]"
    if op == "max":
        return f"<= {spec['value']}"
    if op == "min":
        return f">= {spec['value']}"
    if op == "empty":
        return "is empty"
    return f"?{op}?"


def eval_check(results: Dict[str, Any], path: str,
               spec: Dict[str, Any]) -> Tuple[Any, bool]:
    """(observed value, passed).  Unknown ops fail loudly — a typo in the
    thresholds file must not silently pass.  A ``#suffix`` on the check
    path is ignored for the lookup — JSON keys are unique, so the suffix
    is how one results key carries several constraints (e.g. both a
    ratio and an absolute ceiling on the same stall counter)."""
    value = dotted_get(results, path.split("#", 1)[0])
    op = spec.get("op")
    if op == "eq":
        return value, value == spec["value"]
    if op == "eq_key":
        return value, value == dotted_get(results, spec["key"])
    if op == "gt_key":
        return value, value > dotted_get(results, spec["key"])
    if op == "ratio_eq":
        return value, value == spec["ratio"] * dotted_get(results, spec["key"])
    if op == "max_ratio":
        return value, value < spec["ratio"] * dotted_get(results, spec["key"])
    if op == "max":
        return value, value <= spec["value"]
    if op == "min":
        return value, value >= spec["value"]
    if op == "empty":
        return value, isinstance(value, list) and not value
    raise CheckError(f"unknown threshold op {op!r} for {path!r}")


def _table(rows: List[Tuple[str, str, str, bool]], title: str) -> bool:
    """Print rows as CHECK | VALUE | CONSTRAINT | status; return overall
    pass."""
    if not rows:
        return True
    w_name = max(len(r[0]) for r in rows)
    w_val = max(len(r[1]) for r in rows)
    w_con = max(len(r[2]) for r in rows)
    print(f"[check_bench] {title}")
    ok_all = True
    for name, val, con, ok in rows:
        status = "PASS" if ok else "FAIL"
        ok_all &= ok
        print(f"  {name:<{w_name}}  {val:>{w_val}}  {con:<{w_con}}  {status}")
    print(f"[check_bench] {title}: "
          f"{'all ' + str(len(rows)) + ' checks passed' if ok_all else 'FAILED'}")
    return ok_all


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    if isinstance(v, list):
        return f"[{len(v)} items]"
    return str(v)


def run_checks(artifact: Dict[str, Any],
               thresholds: Dict[str, Any]) -> bool:
    """Evaluate the thresholds section matching the artifact's bench
    name.  Returns overall pass; prints the table either way."""
    bench = artifact["bench"]
    results = artifact["results"]
    section = thresholds.get(bench)
    if section is None:
        raise CheckError(f"thresholds file has no section for bench "
                         f"{bench!r} (sections: "
                         f"{sorted(k for k in thresholds if k != 'schema_version')})")
    rows = []
    for path, spec in section.get("checks", {}).items():
        try:
            value, ok = eval_check(results, path, spec)
            rows.append((path, _fmt(value), _describe(spec), ok))
        except CheckError as e:
            rows.append((path, "<missing>", str(e), False))
    # sweep artifacts: structural record-schema validation + per-record
    # checks (every run must satisfy them — e.g. O(1) launches)
    if bench == "sweep":
        problems = validate_sweep_results(results)
        rows.append(("record_schema",
                     f"{len(results.get('records', []))} records",
                     "sweep record schema "
                     + ("valid" if not problems else "; ".join(problems)),
                     not problems))
        if not problems:
            for path, spec in section.get("record_checks", {}).items():
                for rec in results["records"]:
                    try:
                        value, ok = eval_check(rec, path, spec)
                    except CheckError as e:
                        value, ok = f"<{e}>", False
                    rows.append((f"{rec['name']}.{path}", _fmt(value),
                                 _describe(spec), ok))
    return _table(rows, f"{bench} thresholds")


def run_trend(fresh: Dict[str, Any], baseline: Dict[str, Any],
              thresholds: Dict[str, Any]) -> bool:
    """Compare a fresh artifact against the committed baseline on the
    section's ``trend`` keys: fresh > baseline * (1 + tol) is a
    regression.  Quick and full artifacts are not comparable (different
    tree sizes) — that mismatch fails before any number is read."""
    bench = fresh["bench"]
    if baseline["bench"] != bench:
        raise CheckError(f"trend compare across benches: fresh "
                         f"{bench!r} vs baseline {baseline['bench']!r}")
    if baseline["quick"] != fresh["quick"]:
        raise CheckError(
            f"trend compare across scales: fresh quick={fresh['quick']} vs "
            f"baseline quick={baseline['quick']} (byte counters depend on "
            f"the tree size; regenerate the baseline at the same scale)")
    section = thresholds.get(bench, {})
    rows = []
    for path, spec in section.get("trend", {}).items():
        tol = spec.get("tol", 0.0)
        try:
            f_v = dotted_get(fresh["results"], path)
            b_v = dotted_get(baseline["results"], path)
            ok = f_v <= b_v * (1.0 + tol)
            rows.append((path, f"{_fmt(f_v)} vs {_fmt(b_v)}",
                         f"<= baseline * {1.0 + tol:g}", ok))
        except CheckError as e:
            rows.append((path, "<missing>", str(e), False))
    return _table(rows, f"{bench} trend vs baseline")


def load_thresholds(path: str) -> Dict[str, Any]:
    with open(path) as f:
        obj = json.load(f)
    sv = obj.get("schema_version")
    if sv != THRESHOLDS_SCHEMA_VERSION:
        raise CheckError(f"{path}: unknown thresholds schema_version {sv!r}")
    return obj


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="BENCH_<name>.json to validate/gate")
    ap.add_argument("--thresholds", default=DEFAULT_THRESHOLDS,
                    help="declarative thresholds file (committed)")
    ap.add_argument("--trend", action="store_true",
                    help="compare against --baseline instead of absolute "
                         "thresholds")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline artifact for --trend")
    args = ap.parse_args(argv)

    try:
        artifact = load_bench_artifact(args.artifact)
        thresholds = load_thresholds(args.thresholds)
        if args.trend:
            if not args.baseline:
                raise CheckError("--trend requires --baseline")
            baseline = load_bench_artifact(args.baseline)
            ok = run_trend(artifact, baseline, thresholds)
        else:
            ok = run_checks(artifact, thresholds)
    except (CheckError, ValueError, OSError) as e:
        print(f"[check_bench] ERROR: {e}")
        return 2
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
