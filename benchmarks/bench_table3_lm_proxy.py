"""Table 3 reproduction (ImageNet -> LM proxy at CPU scale): a small
decoder-only transformer on a learnable synthetic bigram language;
MSGD small-batch vs SNGM large-batch final loss after the same number of
gradient computations (equal C, the paper's comparison axis).

The training loop is ``benchmarks.common.train_lm`` — the donated
TrainState path shared with the sweep harness — so per-step metrics
stream through ``repro.tracker`` like every other run.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import train_lm
from repro.configs import ARCHS, smoke_variant
from repro.core import msgd, sngm
from repro.core.schedules import poly_power

SEQ = 64
TOKENS_BUDGET = 64 * 64 * 160     # equal-C comparison


def proxy_config():
    return dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                               vocab_size=256, compute_dtype="float32")


def run():
    out = {}
    cfg = proxy_config()
    steps16 = TOKENS_BUDGET // (16 * SEQ)
    steps256 = TOKENS_BUDGET // (256 * SEQ)
    jobs = [
        ("msgd_b16", msgd(poly_power(0.3, steps16, 1.1), beta=0.9,
                          weight_decay=1e-4), 16),
        ("msgd_b256", msgd(poly_power(1.2, steps256, 1.1), beta=0.9,
                           weight_decay=1e-4), 256),
        ("sngm_b256", sngm(poly_power(2.0, steps256, 1.1), beta=0.9,
                           weight_decay=1e-4), 256),
    ]
    h_opt = None
    for name, opt, batch in jobs:
        steps = TOKENS_BUDGET // (batch * SEQ)
        r = train_lm(opt, cfg, batch, SEQ, steps,
                     n_micro=max(1, batch // 16))
        losses, h_opt = r["losses"], r["optimal_loss"]
        out[name] = {"final_loss": losses[-1], "batch": batch,
                     "n_steps": len(losses)}
        print(f"  {name:10s} B={batch:4d} steps={len(losses):3d}: "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"  (chain entropy = {h_opt:.3f} nats; equal gradient budget "
          f"C = {TOKENS_BUDGET} tokens)")
    print(f"  -> SNGM@B=256 vs MSGD@B=16 final-loss gap: "
          f"{out['sngm_b256']['final_loss'] - out['msgd_b16']['final_loss']:+.4f} "
          f"(paper Table 3: large-batch SNGM matches small-batch MSGD)")
    return out


if __name__ == "__main__":
    run()
