"""Table 3 reproduction (ImageNet -> LM proxy at CPU scale): a small
decoder-only transformer on a learnable synthetic bigram language;
MSGD small-batch vs SNGM large-batch final loss after the same number of
gradient computations (equal C, the paper's comparison axis)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_variant
from repro.core import msgd, sngm
from repro.core.schedules import poly_power
from repro.data.synthetic import SyntheticLM
from repro.models import CPU_RUNTIME, model_defs
from repro.models.param import materialize
from repro.training import make_train_step

SEQ = 64
TOKENS_BUDGET = 64 * 64 * 160     # equal-C comparison


def run_one(opt_name, opt, batch):
    cfg = dataclasses.replace(smoke_variant(ARCHS["deepseek-7b"]),
                              vocab_size=256, compute_dtype="float32")
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, SEQ, batch, branching=4)
    state = opt.init_state(params)
    del params
    n_micro = max(1, batch // 16)
    step = jax.jit(make_train_step(cfg, CPU_RUNTIME, opt, n_micro=n_micro),
                   donate_argnums=(0,))
    steps = TOKENS_BUDGET // (batch * SEQ)
    losses = []
    for t in range(steps):
        state, stats = step(state, data.batch_at(t))
        losses.append(float(stats["loss"]))
    return losses, data.optimal_loss()


def run():
    out = {}
    steps16 = TOKENS_BUDGET // (16 * SEQ)
    steps256 = TOKENS_BUDGET // (256 * SEQ)
    jobs = [
        ("msgd_b16", msgd(poly_power(0.3, steps16, 1.1), beta=0.9,
                          weight_decay=1e-4), 16),
        ("msgd_b256", msgd(poly_power(1.2, steps256, 1.1), beta=0.9,
                           weight_decay=1e-4), 256),
        ("sngm_b256", sngm(poly_power(2.0, steps256, 1.1), beta=0.9,
                           weight_decay=1e-4), 256),
    ]
    h_opt = None
    for name, opt, batch in jobs:
        losses, h_opt = run_one(name, opt, batch)
        out[name] = {"final_loss": losses[-1], "batch": batch,
                     "n_steps": len(losses)}
        print(f"  {name:10s} B={batch:4d} steps={len(losses):3d}: "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"  (chain entropy = {h_opt:.3f} nats; equal gradient budget "
          f"C = {TOKENS_BUDGET} tokens)")
    print(f"  -> SNGM@B=256 vs MSGD@B=16 final-loss gap: "
          f"{out['sngm_b256']['final_loss'] - out['msgd_b16']['final_loss']:+.4f} "
          f"(paper Table 3: large-batch SNGM matches small-batch MSGD)")
    return out


if __name__ == "__main__":
    run()
