"""Table 1 / §3-4 reproduction: computation-complexity scaling of MSGD vs
SNGM with batch size, on a controllable-smoothness quadratic.

F(w) = 0.5 w^T H w, eigenvalues in [L/2, L] with L large.  For each batch
size B we TUNE the constant learning rate per optimizer (geometric grid)
and report the best computation complexity C = T*B to reach
||grad F|| <= eps:

  * MSGD's stable lr is capped at (1-b)^2/((1+b)L) (eq. 4) — so T cannot
    fall below ~1/(lr*L) no matter the batch, and C = T*B grows ~linearly
    in B: large batches WASTE gradient computations (eq. 6).
  * SNGM accepts any lr (Theorem 5); with B growing, the tuned lr grows
    and T shrinks ~proportionally: C stays near-flat (Corollary 7's
    B = sqrt(C) regime).

``run(with_lamb=True)`` (CLI ``--with-lamb``) adds the paper's
state-of-the-art large-batch baseline, LAMB, running on the SAME
multi-tensor fused engine as the others since the fused lamb kind landed
— so the headline complexity comparison is apples-to-apples on the hot
path (every optimizer O(1) Pallas launches per step).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import lamb, msgd, sngm
from repro.core.schedules import constant

DIM = 64
L = 500.0
EPS = 1.0
SIGMA = 0.5
MAX_STEPS = 8_000
LR_GRID = [10 ** e for e in np.linspace(-4.5, 0.5, 11)]


def make_problem(seed=0):
    rng = np.random.RandomState(seed)
    evals = np.linspace(L / 2, L, DIM)
    q, _ = np.linalg.qr(rng.randn(DIM, DIM))
    H = jnp.asarray(q @ np.diag(evals) @ q.T, jnp.float32)
    w0 = jnp.asarray(rng.randn(DIM), jnp.float32)
    w0 = w0 / np.linalg.norm(w0) * 4.0
    return H, w0


def steps_to_eps(opt, H, w0, batch, seed=0):
    rng = np.random.RandomState(seed + batch)
    p = {"w": w0}
    state = opt.init(p)
    step = jax.jit(opt.step)
    noises = jnp.asarray(rng.randn(MAX_STEPS, DIM), jnp.float32) \
        * SIGMA / np.sqrt(batch)
    for t in range(MAX_STEPS):
        gtrue = H @ p["w"]
        if float(jnp.linalg.norm(gtrue)) <= EPS:
            return t
        p, state, _ = step({"w": gtrue + noises[t]}, state, p)
        if not np.all(np.isfinite(np.asarray(p["w"]))):
            return MAX_STEPS
    return MAX_STEPS


def best_complexity(make_opt, H, w0, batch):
    best = MAX_STEPS * batch
    best_lr = None
    for lr in LR_GRID:
        t = steps_to_eps(make_opt(lr), H, w0, batch)
        if t < MAX_STEPS and t * batch < best:
            best, best_lr = t * batch, lr
    return best, best_lr


def run(with_lamb: bool = False):
    H, w0 = make_problem()
    batches = [4, 16, 64, 256, 1024]
    out = {}
    print(f"  quadratic with L={L}; tuned constant lr per (optimizer, B); "
          f"C = T*B to ||grad||<= {EPS}")
    head = f"  {'B':>6} | {'MSGD C':>10} {'lr*':>9} | {'SNGM C':>10} {'lr*':>9}"
    if with_lamb:
        head += f" | {'LAMB C':>10} {'lr*':>9}"
    print(head)
    for B in batches:
        c_m, lr_m = best_complexity(
            lambda lr: msgd(constant(lr), beta=0.9), H, w0, B)
        c_s, lr_s = best_complexity(
            lambda lr: sngm(constant(lr), beta=0.9), H, w0, B)
        out[f"msgd_b{B}"] = {"C": c_m, "lr": lr_m}
        out[f"sngm_b{B}"] = {"C": c_s, "lr": lr_s}

        def cell(lr):
            # lr is None when no grid point converged: print '-', and
            # never feed the string through the float format code
            return f"{lr:>9.2g}" if lr else f"{'-':>9}"

        line = (f"  {B:>6} | {c_m:>10} {cell(lr_m)} "
                f"| {c_s:>10} {cell(lr_s)}")
        if with_lamb:
            # the fused engine kind: same O(1)-launch hot path as the rest
            c_l, lr_l = best_complexity(
                lambda lr: lamb(constant(lr), fused="multi_tensor"),
                H, w0, B)
            out[f"lamb_b{B}"] = {"C": c_l, "lr": lr_l}
            line += f" | {c_l:>10} {cell(lr_l)}"
        print(line)
    r_m = out["msgd_b1024"]["C"] / max(out["msgd_b4"]["C"], 1)
    r_s = out["sngm_b1024"]["C"] / max(out["sngm_b4"]["C"], 1)
    msg = (f"  -> C(B=1024)/C(B=4):  MSGD {r_m:.1f}x   SNGM {r_s:.1f}x  "
           f"(paper: SNGM's complexity is batch-size-robust, Table 1)")
    if with_lamb:
        r_l = out["lamb_b1024"]["C"] / max(out["lamb_b4"]["C"], 1)
        msg += f"   LAMB {r_l:.1f}x"
    print(msg)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--with-lamb", action="store_true",
                    help="add the LAMB baseline (fused multi-tensor kind)")
    run(with_lamb=ap.parse_args().with_lamb)
