"""Input-pipeline benchmark: prefetch hides host read latency.

Two rungs over the same loader and the same (deliberately slow) source —
every ``read()`` sleeps a fixed ``READ_DELAY_S``, modeling disk/decode
latency an order of magnitude above the CPU container's real npz reads,
while the consumer "computes" for ``COMPUTE_S`` per step:

  * ``sync``     — ``next(loader)`` inline: every step pays the read
                   latency in full, so input stall/step ~= read delay;
  * ``prefetch`` — ``PrefetchIterator`` (depth 2, double buffering): the
                   worker reads WHILE the consumer computes, so measured
                   input stall/step ~= 0.  This is the number CI gates
                   (``bench_thresholds.json``: an absolute ceiling plus a
                   ratio vs the sync rung) — the acceptance claim of the
                   streaming-data subsystem.

Plus the async-checkpoint rung: ``AsyncCheckpointer.save()`` must return
in device->host-copy time even when the commit itself is slowed
(``commit_delay_s``) — gated as a ratio against the delayed commit wall
time, so "training never blocks on commit I/O" stays a measured claim.

CLI:  python -m benchmarks.bench_data_pipeline [--quick] [--json OUT]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Tuple

import numpy as np

from benchmarks.artifact import make_envelope, validate_envelope

READ_DELAY_S = 0.006     # per source.read() call — synthetic "slow disk"
COMPUTE_S = 0.012        # per consumer step — the window prefetch hides in


class DelayedSource:
    """A ``DataSource`` whose every ``read`` sleeps — latency injection
    for the stall measurement (values still deterministic)."""

    def __init__(self, inner, delay_s: float):
        self.inner = inner
        self.delay_s = delay_s
        self.reads = 0

    def shard_lengths(self) -> Tuple[int, ...]:
        return self.inner.shard_lengths()

    def read(self, shard: int, start: int, count: int):
        time.sleep(self.delay_s)
        self.reads += 1
        return self.inner.read(shard, start, count)


def _make_loader(n: int, batch: int, delay_s: float):
    from repro.data import MemorySource, StreamingLoader
    base = MemorySource(
        {"tokens": np.arange(n * 8, dtype=np.int32).reshape(n, 8),
         "loss_mask": np.ones((n, 8), np.float32)},
        shard_size=batch)          # ~one read per batch
    return StreamingLoader(DelayedSource(base, delay_s), batch, shuffle=True)


def _consume_sync(loader, steps: int, compute_s: float) -> Dict[str, float]:
    stall = 0.0
    for _ in range(steps):
        t0 = time.perf_counter()
        next(loader)
        stall += time.perf_counter() - t0
        time.sleep(compute_s)
    return {"input_stall_s": stall, "input_stall_s_per_step": stall / steps,
            "steps": steps}


def _consume_prefetch(loader, steps: int, compute_s: float,
                      depth: int) -> Dict[str, float]:
    from repro.data import PrefetchIterator
    # place=None: keep the rung jax-free — placement cost is the same for
    # both rungs and is not what this bench isolates
    with PrefetchIterator(loader, depth=depth, place=None) as pf:
        for _ in range(steps):
            next(pf)
            time.sleep(compute_s)
        c = pf.counters()
    c["steps"] = steps
    return c


def _bench_async_save(quick: bool) -> Dict[str, float]:
    import jax.numpy as jnp

    from repro.checkpoint import AsyncCheckpointer, save_checkpoint
    import os
    import shutil
    import tempfile

    tree = {f"p{i}": jnp.arange(2048, dtype=jnp.float32) for i in range(8)}
    delay = 0.02 if quick else 0.05
    base = tempfile.mkdtemp(prefix="bench_async_ckpt_")
    try:
        t0 = time.perf_counter()
        save_checkpoint(os.path.join(base, "sync"), tree, 0)
        sync_commit_s = time.perf_counter() - t0

        with AsyncCheckpointer(commit_delay_s=delay) as ac:
            t0 = time.perf_counter()
            ac.save(os.path.join(base, "async"), tree, 0)
            save_call_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            ac.wait()
            commit_wait_s = time.perf_counter() - t0
        return {"save_call_s": save_call_s,
                "delayed_commit_s": commit_wait_s,
                "sync_commit_s": sync_commit_s}
    finally:
        shutil.rmtree(base, ignore_errors=True)


def run(quick: bool = False, json_path: str | None = None):
    steps = 30 if quick else 120
    batch, depth = 16, 2
    n = batch * 64
    print(f"  {steps} steps, read delay {READ_DELAY_S*1e3:.0f}ms, "
          f"compute {COMPUTE_S*1e3:.0f}ms/step")

    sync = _consume_sync(_make_loader(n, batch, READ_DELAY_S),
                         steps, COMPUTE_S)
    print(f"  sync      stall {sync['input_stall_s_per_step']*1e3:6.2f} "
          f"ms/step")
    pf = _consume_prefetch(_make_loader(n, batch, READ_DELAY_S),
                           steps, COMPUTE_S, depth)
    print(f"  prefetch  stall {pf['input_stall_s_per_step']*1e3:6.2f} "
          f"ms/step  (depth avg {pf['prefetch_depth_avg']:.2f}/{depth})")
    async_save = _bench_async_save(quick)
    print(f"  async save() {async_save['save_call_s']*1e3:.2f}ms vs "
          f"delayed commit {async_save['delayed_commit_s']*1e3:.0f}ms")

    out = {"read_delay_s": READ_DELAY_S, "compute_s": COMPUTE_S,
           "sync": sync, "prefetch": pf, "async_save": async_save}
    if json_path:
        import json
        import os
        envelope = make_envelope("data_pipeline", out, quick=quick)
        assert not validate_envelope(envelope)
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(envelope, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps (CI smoke lane)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the canonical BENCH artifact to this path")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
