"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSONs in results/dryrun/."""
from __future__ import annotations

import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir="results/dryrun", mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(outdir, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])
                             if r["shape"] in ORDER else 9))
    return recs


def markdown_table(recs):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | bound "
        "| peak GB/chip | useful-FLOP frac | top collective |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | {r['reason'][:40]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | {r.get('error','')[:40]} |")
            continue
        top = max(r["coll_breakdown"].items(), key=lambda kv: kv[1])[0] \
            if r["coll_breakdown"] else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['bottleneck']} | {r['peak_bytes_per_chip']/1e9:.2f} | "
            f"{r['useful_flop_frac']:.2f} | {top} |")
    return "\n".join(lines)


def run():
    recs = load()
    print(markdown_table(recs))
    ok = [r for r in recs if r.get("status") == "ok"]
    print(f"\n{len(ok)} ok / {len(recs)} total")
    return {"n_ok": len(ok), "n_total": len(recs)}


if __name__ == "__main__":
    run()
