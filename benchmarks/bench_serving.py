"""Serving benchmark: paged engine vs dense baseline under open-loop
traffic, plus the paged-attention kernel's differential error and the
paged-vs-dense bitwise parity bit.

One deterministic workload (fixed seed, varied prompt lengths, requests
arriving on a fixed schedule regardless of completion — open loop) is
served twice at EQUAL slot count:

  * ``dense`` — ``launch.serve.ContinuousBatcher``: per-length prefill
    compiles, one host sync per token, O(n_slots x ctx) cache;
  * ``paged`` — ``serving.scheduler.PagedScheduler``: bucket-padded
    batched prefill (compiles bounded by bucket count), chunked
    on-device decode, block-pool memory = O(used blocks).

Gated claims (``bench_thresholds.json`` "serving", enforced by
``check_bench.py`` in CI):

  * paged throughput >= dense at equal slots (the compile-count and
    host-sync savings must show up end to end, cold start included);
  * paged prefill compiles strictly below dense's and bounded by the
    bucket count; decode compiles to ONE shape;
  * paged peak KV bytes (pool bytes/block x peak used blocks) at most
    the dense engine's O(n_slots x ctx) allocation;
  * kernel-vs-ref max abs err within the documented tolerance policy
    (fp32 few-ulp online-vs-two-pass softmax, bf16 input rounding);
  * paged decode logits BITWISE equal to the dense engine at matched
    geometry.

CLI:  python -m benchmarks.bench_serving [--quick] [--json OUT]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks.artifact import make_envelope, validate_envelope

ARCH = "deepseek-7b"
BLOCK_SIZE = 4
DECODE_CHUNK = 4


def _setup():
    import jax

    from repro.configs import ARCHS, smoke_variant
    from repro.models import model_defs
    from repro.models.param import materialize
    cfg = dataclasses.replace(smoke_variant(ARCHS[ARCH]),
                              compute_dtype="float32")
    params = materialize(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n_req: int, max_new: int, ctx_max: int):
    """Deterministic open-loop workload: varied prompt lengths (so the
    dense baseline pays one prefill compile per distinct length) and an
    arrival schedule of two requests per scheduler round."""
    rng = np.random.RandomState(0)
    lengths = [int(rng.randint(5, ctx_max - max_new)) for _ in range(n_req)]
    prompts = [rng.randint(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in lengths]
    arrivals = [i // 2 for i in range(n_req)]       # round at which i arrives
    return prompts, arrivals


def _bench_paged(cfg, params, prompts, arrivals, n_slots, max_new, ctx_max,
                 n_blocks) -> Dict:
    from repro.models.runtime import CPU_RUNTIME
    from repro.serving.paged_cache import paged_kv_bytes_per_block
    from repro.serving.scheduler import PagedScheduler, ServeRequest

    sched = PagedScheduler(cfg, params, CPU_RUNTIME, n_slots=n_slots,
                           block_size=BLOCK_SIZE, n_blocks=n_blocks,
                           ctx_max=ctx_max, decode_chunk=DECODE_CHUNK)
    t0 = time.monotonic()
    rnd, i = 0, 0
    while i < len(prompts) or not sched.idle:
        while i < len(prompts) and arrivals[i] <= rnd:
            sched.submit(ServeRequest(rid=i, prompt=prompts[i],
                                      max_new=max_new))
            i += 1
        sched.step()
        rnd += 1
    wall = time.monotonic() - t0

    fin = sched.finished
    total = sum(len(r.out) for r in fin)
    tok_lat = [t - r.t_submit for r in fin for t in r.token_times]
    return {
        "wall_s": wall,
        "tokens": total,
        "tok_s": total / wall,
        "token_latency_p50_s": float(np.percentile(tok_lat, 50)),
        "token_latency_p99_s": float(np.percentile(tok_lat, 99)),
        "decode_steps": sched.stats["decode_steps"],
        "prefill_compiles": sched.compile_counts()["prefill"],
        "decode_compiles": sched.compile_counts()["decode"],
        "peak_used_blocks": sched.stats["peak_used_blocks"],
        "pool_blocks": n_blocks - 1,
        "pool_utilization": sched.stats["peak_used_blocks"] / (n_blocks - 1),
        "preemptions": sched.stats["preemptions"],
        "kv_bytes_peak": (paged_kv_bytes_per_block(sched.paged)
                          * sched.stats["peak_used_blocks"]),
        "leaked_blocks": sched.alloc.used_blocks,
    }


def _bench_dense(cfg, params, prompts, arrivals, n_slots, max_new,
                 ctx_max) -> Dict:
    import jax.numpy as jnp

    from repro.launch.serve import ContinuousBatcher, Request
    from repro.serving.engine import cache_abstract
    from repro.serving.paged_cache import dense_kv_bytes

    b = ContinuousBatcher(cfg, params, n_slots, ctx_max)
    queue: List[Request] = []
    finished: List[Request] = []
    tok_lat: List[float] = []
    t0 = time.monotonic()
    rnd, i, steps = 0, 0, 0
    while i < len(prompts) or queue or any(s is not None for s in b.slots):
        while i < len(prompts) and arrivals[i] <= rnd:
            queue.append(Request(i, jnp.asarray(prompts[i])[None], max_new,
                                 t_submit=time.monotonic()))
            i += 1
        for s in b.free_slots():
            if queue:
                b._admit(queue.pop(0), s)
        if any(s is not None for s in b.slots):
            active = [r for r in b.slots if r is not None]
            finished += b.decode_step()
            steps += 1
            now = time.monotonic()
            tok_lat += [now - r.t_submit for r in active]
        rnd += 1
    wall = time.monotonic() - t0

    total = sum(len(r.out) for r in finished)
    return {
        "wall_s": wall,
        "tokens": total,
        "tok_s": total / wall,
        "token_latency_p50_s": float(np.percentile(tok_lat, 50)),
        "token_latency_p99_s": float(np.percentile(tok_lat, 99)),
        "decode_steps": steps,
        "prefill_compiles": len(b.prefill_shapes),
        "kv_bytes": dense_kv_bytes(cache_abstract(cfg, n_slots, ctx_max)),
    }


def _bench_kernel() -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention.kernel import paged_decode_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    key = jax.random.PRNGKey(0)
    B, H, K, hd, bs, nb, nbt = 3, 8, 2, 64, 8, 17, 4
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H, hd))
    kp = jax.random.normal(jax.random.fold_in(key, 2), (nb, bs, K, hd))
    vp = jax.random.normal(jax.random.fold_in(key, 3), (nb, bs, K, hd))
    ids = np.random.RandomState(0).permutation(
        np.arange(1, nb))[:B * nbt].reshape(B, nbt).astype(np.int32)
    bt = jnp.asarray(ids)
    pos = jnp.asarray([5, 17, 31], jnp.int32)

    def err(qq, kk, vv):
        o = paged_decode_attention(qq, kk, vv, bt, pos, interpret=True)
        r = paged_attention_ref(qq, kk, vv, bt, pos)
        return float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                     - r.astype(jnp.float32))))

    return {"max_abs_err_fp32": err(q, kp, vp),
            "max_abs_err_bf16": err(q.astype(jnp.bfloat16),
                                    kp.astype(jnp.bfloat16),
                                    vp.astype(jnp.bfloat16))}


def _bench_parity(cfg, params) -> Dict:
    """Matched-geometry bitwise parity: paged decode logits vs dense."""
    import jax
    import jax.numpy as jnp

    from repro.models.runtime import CPU_RUNTIME
    from repro.serving import paged_cache as pc
    from repro.serving.engine import (make_prefill_step, make_serve_step,
                                      pad_cache)

    prefill = make_prefill_step(cfg, CPU_RUNTIME)
    step = make_serve_step(cfg, CPU_RUNTIME)
    rng = np.random.RandomState(0)
    B, S0, steps, bs = 2, 9, 5, BLOCK_SIZE
    prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S0)), jnp.int32)
    nbmax = pc.n_blocks_for(S0 + steps, bs)
    T = nbmax * bs

    logits, dense = prefill(params, prompt)
    dense = pad_cache(dense, T - S0)
    paged = pc.paged_cache_init(cfg, B, bs, n_blocks=32, nbmax=nbmax)
    alloc = pc.BlockAllocator(32, bs)
    _, dense2 = prefill(params, prompt)
    for row in range(B):
        ids = [alloc.alloc() for _ in range(nbmax)]
        paged = pc.set_block_table(paged, row, ids)
        paged = pc.splice_prefill(paged, dense2, row, row, ids)

    tok_d = tok_p = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((B,), S0, jnp.int32)
    bitwise = True
    for _ in range(steps):
        tok_d, lg_d, dense = step(params, dense, tok_d[:, None], pos)
        tok_p, lg_p, paged = step(params, paged, tok_p[:, None], pos)
        bitwise &= bool(jax.numpy.all(lg_d == lg_p))
        pos = pos + 1
    return {"bitwise": bitwise, "steps": steps}


def run(quick: bool = False, json_path: str | None = None):
    from repro.serving.paged_cache import n_blocks_for

    n_req = 6 if quick else 12
    max_new = 8 if quick else 16
    n_slots = 3 if quick else 4
    ctx_max = 32 if quick else 48

    cfg, params = _setup()
    prompts, arrivals = _workload(cfg, n_req, max_new, ctx_max)
    n_blocks = 1 + n_slots * n_blocks_for(ctx_max, BLOCK_SIZE)
    print(f"  {n_req} requests, {n_slots} slots, max_new {max_new}, "
          f"ctx {ctx_max}, {len(set(len(p) for p in prompts))} distinct "
          f"prompt lengths, pool {n_blocks - 1} blocks")

    dense = _bench_dense(cfg, params, prompts, arrivals, n_slots, max_new,
                         ctx_max)
    print(f"  dense  {dense['tok_s']:7.1f} tok/s  "
          f"{dense['prefill_compiles']} prefill compiles  "
          f"p99 {dense['token_latency_p99_s']:.2f}s")
    paged = _bench_paged(cfg, params, prompts, arrivals, n_slots, max_new,
                         ctx_max, n_blocks)
    print(f"  paged  {paged['tok_s']:7.1f} tok/s  "
          f"{paged['prefill_compiles']} prefill compiles  "
          f"p99 {paged['token_latency_p99_s']:.2f}s  "
          f"pool {paged['peak_used_blocks']}/{paged['pool_blocks']} blocks")
    kernel = _bench_kernel()
    print(f"  kernel err fp32 {kernel['max_abs_err_fp32']:.2e} "
          f"bf16 {kernel['max_abs_err_bf16']:.2e}")
    parity = _bench_parity(cfg, params)
    print(f"  paged-vs-dense bitwise over {parity['steps']} steps: "
          f"{parity['bitwise']}")

    out = {
        "workload": {"requests": n_req, "slots": n_slots, "max_new": max_new,
                     "ctx_max": ctx_max, "block_size": BLOCK_SIZE,
                     "decode_chunk": DECODE_CHUNK},
        "dense": dense,
        "paged": paged,
        "memory": {"paged_over_dense_kv":
                   paged["kv_bytes_peak"] / dense["kv_bytes"]},
        "kernel": kernel,
        "parity": parity,
    }
    if json_path:
        import json
        import os
        envelope = make_envelope("serving", out, quick=quick)
        assert not validate_envelope(envelope)
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(envelope, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload (CI smoke lane)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the canonical BENCH artifact to this path")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
