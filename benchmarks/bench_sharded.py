"""Sharded resident-engine benchmark: the distributed scale-out gate.

Runs the multi-tensor engine on a 2x2 data x model host mesh (8 forced
CPU devices, the same lane the multidevice tests use) and records, for
sngm / msgd / lamb / clip->sngm:

  * kernel LAUNCHES per step with the resident state sharded over the
    mesh — the shard_map two-level norm must NOT add launches (the body
    traces once; the gather is a collective, not a kernel), so the
    counts are pinned to the single-device numbers (sngm 2, msgd 2,
    lamb 2, clip->sngm 3);
  * bitwise PARITY booleans: the donated sharded resident step against
    the undonated single-device canonical — fp32 bit-identity is the
    two-level norm's contract (per-shard Pallas partials + tiled gather
    + the canonical per-segment fold);
  * param-bytes RESIDENCY under sharding: the donated TrainState holds
    ~1x raw param bytes (flat buffers only; shard padding is the only
    overhead, bounded by the 1.5x gate);
  * DONATION warnings under sharding: the donated step must consume
    every sharded buffer (zero warnings).

CLI:  python -m benchmarks.bench_sharded [--quick] [--json OUT]
``--json`` writes the canonical schema-versioned BENCH artifact
(benchmarks/artifact.py envelope) that ``check_bench.py`` gates against
the ``sharded`` section of bench_thresholds.json.
"""
from __future__ import annotations

import os

# the mesh lane needs multiple host devices BEFORE jax initializes
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.artifact import make_envelope, validate_envelope
from benchmarks.common import csv_row
from repro.core import compile_chain, lamb, msgd, sngm
from repro.core import transform as T
from repro.core.multi_tensor import FlatOptState, mesh_shards, unflatten
from repro.core.schedules import constant
from repro.launch.mesh import make_host_mesh
from repro.tracker.counters import (capture_donation_warnings,
                                    launches_per_step, param_bytes_live)

SHAPES = [(512, 512)] * 6 + [(1024, 256)] * 2 + [(512,)] * 8
SHAPES_QUICK = [(256, 256)] * 6 + [(256,)] * 8


def make_tree(seed, shapes, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {f"p{i}": scale * jax.random.normal(jax.random.fold_in(k, i), s)
            for i, s in enumerate(shapes)}


def _state_tree(st: FlatOptState):
    slots = [st.p_flats, st.u_flats, st.m_flats, st.v_flats]
    return [unflatten(f, st.layout, keep_dtype=True) for f in slots if f]


def _bitwise(st_a: FlatOptState, st_b: FlatOptState) -> bool:
    for ta, tb in zip(_state_tree(st_a), _state_tree(st_b)):
        for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
            if not bool(jnp.array_equal(a, b)):
                return False
    return True


def time_call(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False, json_path: str | None = None):
    shapes = SHAPES_QUICK if quick else SHAPES
    iters = 3 if quick else 5
    mesh = make_host_mesh(2, 2)
    assert mesh_shards(mesh) == 4, dict(mesh.shape)
    params = make_tree(0, shapes)
    grads = [make_tree(1 + t, shapes, 3.0) for t in range(2)]
    n_params = sum(int(np.prod(s)) for s in shapes)
    rows = []

    def clip_sngm(**kw):
        tx = T.chain(T.clip_by_global_norm(1.0), T.add_decayed_weights(1e-4),
                     T.normalize_by_global_norm(), T.trace(0.9),
                     T.scale_by_schedule(constant(0.1)))
        return compile_chain(tx, fused="multi_tensor", **kw)

    builders = {
        "sngm": lambda **kw: sngm(constant(0.1), beta=0.9,
                                  weight_decay=1e-4,
                                  fused="multi_tensor", **kw),
        "msgd": lambda **kw: msgd(constant(0.1), beta=0.9,
                                  weight_decay=1e-4,
                                  fused="multi_tensor", **kw),
        "lamb": lambda **kw: lamb(constant(0.1), weight_decay=1e-4,
                                  fused="multi_tensor", **kw),
        "clip_sngm": clip_sngm,
    }

    launches, parity, us = {}, {}, {}
    for name, mk in builders.items():
        opt_1, opt_s = mk(), mk(mesh=mesh)
        st_1, st_s = opt_1.init(params), opt_s.init(params)
        launches[f"{name}_single"] = launches_per_step(
            opt_1, grads[0], st_1, None)
        launches[name] = launches_per_step(opt_s, grads[0], st_s, None)
        # canonical single-device numerics (undonated) vs the production
        # configuration: sharded resident state, donated step
        step_1 = jax.jit(opt_1.step)
        step_s = jax.jit(opt_s.step, donate_argnums=(1,))
        for g in grads:
            _, st_1, _ = step_1(g, st_1, None)
            _, st_s, _ = step_s(g, st_s, None)
        parity[name] = _bitwise(st_1, st_s)
        us[name] = time_call(
            jax.jit(opt_s.step), grads[0], opt_s.init(params), None,
            iters=iters)
        rows.append(csv_row(
            f"sharded_{name}", us[name],
            f"launches/step={launches[name]} (single "
            f"{launches[f'{name}_single']}), bitwise_parity={parity[name]}"))
        print(f"  {rows[-1]}")

    # residency: the sharded resident TrainState still holds ~1x raw
    # param bytes — shard padding (buckets rounded up to shards*TILE) is
    # the only overhead, and the 1.5x gate bounds it
    opt_s = builders["sngm"](mesh=mesh)
    ts = opt_s.init_state(make_tree(0, shapes))
    pb_live = param_bytes_live(ts)
    param_bytes = sum(int(np.prod(s)) * 4 for s in shapes)
    rows.append(csv_row("sharded_param_bytes_live", pb_live,
                        f"raw={param_bytes} "
                        f"ratio={pb_live / param_bytes:.3f}"))
    print(f"  {rows[-1]}")

    # donation under sharding: every donated sharded buffer consumed
    _, warnings = capture_donation_warnings(
        opt_s.step_state, grads[0], ts, donate_argnums=(1,))
    for msg in warnings:
        print(f"  DONATION WARNING: {msg}")
    print(f"  donated sharded resident step: {len(warnings)} donation "
          f"warnings")

    out = {"rows": rows, "n_params": n_params,
           "mesh": {"data": 2, "model": 2, "shards": 4},
           "launches_per_step": launches,
           "parity_bitwise": parity,
           "us_per_step": us,
           "param_bytes_live": {"resident": int(pb_live),
                                "raw_params": int(param_bytes)},
           "donation_warnings": warnings}
    if json_path:
        import json

        envelope = make_envelope("sharded", out, quick=quick)
        assert not validate_envelope(envelope)
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(envelope, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small tree + few iters (CI smoke lane)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write results JSON to this path")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
