"""Optimizer-update micro-benchmark.

Three things per optimizer:
  * us/call for the jnp path on a transformer-sized parameter tree;
  * kernel LAUNCHES per step for the fused paths — the multi-tensor
    engine must be O(1) in tree size while the per-leaf path is
    O(n_leaves) (this is the engine's reason to exist: on TPU each
    launch costs ~2-5us of dispatch that CPU wall-time cannot show);
  * us/call for per-leaf vs multi-tensor fused paths in interpret mode
    (CPU correctness path; the multi-tensor path must be no slower).

Plus the HBM-traffic model for the fused update vs the unfused XLA
lowering (the kernel's win is bandwidth, which CPU wall-time cannot
show — we report both), and the flat-buffer packing count: the
flat-buffer-resident state (FlatOptState) must pack only gradient-sized
buffers per steady-state step, ~1/3 of the per-step path's
params+grads+momentum re-pack on an fp32 tree.

Also benchmarks the gradient-transform chain interpreter on a novel
composition (adam -> trace -> schedule, which neither the matcher nor
the segment planner can fuse) against the compiled sngm chain, so the
jnp-fallback overhead stays visible — plus the segment-compiled plans
(mid-chain clip, nesterov, EMA slots), whose launch counts the CI gate
pins exactly.

CLI:  python -m benchmarks.bench_optimizer_overhead [--quick] [--json OUT]
``--quick`` shrinks the tree and iteration counts for the CI smoke lane;
``--json`` writes the canonical schema-versioned BENCH artifact
(benchmarks/artifact.py envelope — what ``check_bench.py`` gates on).

The launch/packing/residency counters live in ``repro.tracker.counters``
(shared with the sweep harness and trainable loops); this benchmark
composes them into the tracked BENCH_overhead.json trajectory.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.artifact import make_envelope, validate_envelope
from benchmarks.common import csv_row
from repro.core import compile_chain, lars, lamb, msgd, sngd, sngm, to_pytree
from repro.core import transform as T
from repro.core.schedules import constant
from repro.tracker.counters import (capture_donation_warnings,
                                    launches_per_step, packed_bytes_per_step,
                                    param_bytes_live)

SHAPES = [(1024, 1024)] * 8 + [(4096, 1024)] * 4 + [(1024,)] * 16
SHAPES_QUICK = [(256, 256)] * 4 + [(1024, 256)] * 2 + [(256,)] * 10


def make_tree(seed, shapes, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {f"p{i}": scale * jax.random.normal(jax.random.fold_in(k, i), s)
            for i, s in enumerate(shapes)}


def time_call(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False, json_path: str | None = None):
    shapes = SHAPES_QUICK if quick else SHAPES
    iters = 5 if quick else 20
    params = make_tree(0, shapes)
    grads = make_tree(1, shapes, 3.0)
    n_params = sum(int(np.prod(s)) for s in shapes)
    n_leaves = len(shapes)
    rows = []

    def bench(name, opt, extra=""):
        state = opt.init(params)
        step = jax.jit(opt.step)
        us = time_call(step, grads, state, params, iters=iters)
        launches = launches_per_step(opt, grads, state, params)
        rows.append(csv_row(f"opt_update_{name}", us,
                            f"params={n_params} leaves={n_leaves} "
                            f"launches/step={launches}{extra}"))
        print(f"  {rows[-1]}")
        return us, launches

    # --- jnp reference paths -------------------------------------------
    for name, opt in [("sngm", sngm(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("sngm_per_tensor", sngm(constant(0.1), beta=0.9,
                                               norm_mode="per_tensor")),
                      ("sngd", sngd(constant(0.1))),
                      ("msgd", msgd(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("lars", lars(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("lamb", lamb(constant(0.1), weight_decay=1e-4))]:
        bench(name, opt)

    # --- chain interpreter: a novel composition no fused kind covers ----
    # (Adam moments feeding a momentum trace; since the segment compiler,
    # clip/nesterov/EMA compositions all fuse, so the novel row needs a
    # stateful non-canonical stage the PLANNER genuinely rejects too);
    # measures the jnp fallback's overhead vs the compiled sngm above
    novel = T.chain(T.scale_by_adam(0.9, 0.999, 1e-6), T.trace(0.9),
                    T.scale_by_schedule(constant(0.1)))
    assert T.match_chain(novel) is None
    assert T.plan_chain(novel).kind is None
    bench("chain_interpreter_novel", compile_chain(novel))

    # --- fused: per-leaf (O(n_leaves) launches) vs multi-tensor (O(1)) --
    us_pl, l_pl = bench("sngm_fused_per_leaf",
                        sngm(constant(0.1), beta=0.9, weight_decay=1e-4,
                             fused="per_leaf"))
    us_mt, l_mt = bench("sngm_fused_multi_tensor",
                        sngm(constant(0.1), beta=0.9, weight_decay=1e-4,
                             fused="multi_tensor"))
    bench("lars_fused_multi_tensor",
          lars(constant(0.1), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor"))
    bench("msgd_fused_multi_tensor",
          msgd(constant(0.1), beta=0.9, weight_decay=1e-4,
               fused="multi_tensor"))

    # --- fused LAMB (Adam-moment pass + apply pass, 2 launches) ---------
    opt_lamb = lamb(constant(0.1), weight_decay=1e-4, fused="multi_tensor")
    us_lamb, l_lamb = bench("lamb_fused_multi_tensor", opt_lamb)

    # --- clip->sngm: the two-round-norm compilation (3 launches) --------
    clip_sngm_tx = T.chain(T.clip_by_global_norm(1.0),
                           T.add_decayed_weights(1e-4),
                           T.normalize_by_global_norm(), T.trace(0.9),
                           T.scale_by_schedule(constant(0.1)))
    opt_clip = compile_chain(clip_sngm_tx, fused="multi_tensor")
    assert opt_clip.kind == "sngm_global"
    us_clip, l_clip = bench("clip_sngm_fused_multi_tensor", opt_clip)

    # --- segment plans: nesterov variant, mid-chain clip, EMA slots -----
    # nesterov fuses into the update kernel (no extra launch); a clip
    # BETWEEN normalize and trace folds into the tail's coefficient round
    # (jnp prefix nodes are launch-free); ema_params becomes a resident
    # f32 shadow slot advanced elementwise (no launch, no packing)
    opt_nest = sngm(constant(0.1), beta=0.9, weight_decay=1e-4,
                    nesterov=True, fused="multi_tensor")
    us_nest, l_nest = bench("nesterov_sngm_fused_multi_tensor", opt_nest)
    clip_mid_tx = T.chain(T.add_decayed_weights(1e-4),
                          T.normalize_by_global_norm(),
                          T.clip_by_global_norm(5.0), T.trace(0.9),
                          T.scale_by_schedule(constant(0.1)))
    opt_cm = compile_chain(clip_mid_tx, fused="multi_tensor")
    assert T.match_chain(clip_mid_tx) is None and opt_cm.kind == "msgd"
    us_cm, l_cm = bench("sngm_clip_mid_fused_multi_tensor", opt_cm)
    opt_ema = sngm(constant(0.1), beta=0.9, weight_decay=1e-4,
                   ema_decay=0.999, fused="multi_tensor")
    us_ema, l_ema = bench("sngm_ema_fused_multi_tensor", opt_ema)

    assert l_pl == n_leaves, (l_pl, n_leaves)
    assert l_mt <= 3, l_mt          # norm pass + update pass per dtype bucket
    summary = (f"multi-tensor: {l_mt} launches/step vs per-leaf {l_pl} "
               f"({n_leaves} leaves); step time {us_mt:.0f}us vs {us_pl:.0f}us"
               f" (interpret mode)")
    rows.append(csv_row("sngm_multi_tensor_vs_per_leaf_speedup",
                        us_pl / max(us_mt, 1e-9), summary))
    print(f"  {summary}")

    # --- flat-buffer packing: resident (FlatOptState) vs per-step -------
    # the resident path flattens only the gradients each step; the
    # per-step path (OptState into the fused step) re-packs p+g+u.  On an
    # all-fp32 tree the ratio is exactly 1/3.
    opt_mt = sngm(constant(0.1), beta=0.9, weight_decay=1e-4,
                  fused="multi_tensor")
    state_res = opt_mt.init(params)              # FlatOptState, resident
    state_tree = to_pytree(state_res)            # OptState, per-step path
    b_res = packed_bytes_per_step(opt_mt, grads, state_res, params)
    b_per = packed_bytes_per_step(opt_mt, grads, state_tree, params)
    # no assert here: the JSON must be able to RECORD a regression — CI's
    # bench-smoke step reads packed_bytes_per_step and enforces the bound
    rows.append(csv_row("sngm_packed_bytes_per_step_resident", b_res,
                        "FlatOptState: gradients only"))
    rows.append(csv_row("sngm_packed_bytes_per_step_per_step", b_per,
                        "OptState: params+grads+momentum"))
    print(f"  flat-buffer packing: resident {b_res} B/step vs per-step "
          f"{b_per} B/step ({b_res / b_per:.2f}x)")
    # fused lamb: Adam moments resident too, so steady state still packs
    # only the gradients; clip->sngm packs the gradients twice (raw for
    # the round-0 norm + clipped for the update)
    b_lamb = packed_bytes_per_step(opt_lamb, grads, opt_lamb.init(params),
                                   params)
    b_clip = packed_bytes_per_step(opt_clip, grads, opt_clip.init(params),
                                   params)
    rows.append(csv_row("lamb_packed_bytes_per_step_resident", b_lamb,
                        "FlatOptState(m,v): gradients only"))
    rows.append(csv_row("clip_sngm_packed_bytes_per_step_resident", b_clip,
                        "raw + clipped gradient packing"))
    print(f"  lamb resident packing {b_lamb} B/step; clip->sngm {b_clip} "
          f"B/step (2x grads: raw norm round + clipped update)")
    # segment plans: nesterov and EMA stay at gradient-only packing
    # (shadow slots update flats in place); mid-chain clip packs the
    # prefix output twice, same 2x as the clip-prefixed whole match
    b_nest = packed_bytes_per_step(opt_nest, grads, opt_nest.init(params),
                                   params)
    b_cm = packed_bytes_per_step(opt_cm, grads, opt_cm.init(params), params)
    b_ema = packed_bytes_per_step(opt_ema, grads, opt_ema.init(params),
                                  params)
    rows.append(csv_row("nesterov_sngm_packed_bytes_per_step_resident",
                        b_nest, "gradients only"))
    rows.append(csv_row("sngm_clip_mid_packed_bytes_per_step_resident",
                        b_cm, "prefix output: clip round + tail packing"))
    rows.append(csv_row("sngm_ema_packed_bytes_per_step_resident", b_ema,
                        "gradients only; EMA slots update in place"))
    print(f"  plan packing: nesterov {b_nest} B/step, clip-mid {b_cm} "
          f"B/step, ema {b_ema} B/step")

    # --- parameter residency: live param bytes held across steps --------
    # the donated TrainState on the resident path holds the params ONCE
    # (in FlatOptState.p_flats; TrainState.params is None).  The legacy
    # (params pytree, FlatOptState) pairing held them twice — that is the
    # number the donation refactor reclaimed.
    param_bytes = sum(int(np.prod(s)) * 4 for s in shapes)
    ts_res = opt_mt.init_state(make_tree(0, shapes))
    pb_live = param_bytes_live(ts_res)
    pb_legacy = pb_live + param_bytes        # old API: pytree copy + flats
    rows.append(csv_row("sngm_param_bytes_live_resident", pb_live,
                        "TrainState: p_flats only (~1x param bytes)"))
    print(f"  param bytes live: resident TrainState {pb_live} "
          f"(raw params {param_bytes}; legacy two-copy {pb_legacy})")

    # --- donation: the donated step must consume every donated buffer --
    _, donation_warnings = capture_donation_warnings(
        opt_mt.step_state, grads, ts_res, donate_argnums=(1,))
    for msg in donation_warnings:
        print(f"  DONATION WARNING: {msg}")
    print(f"  donated resident step: {len(donation_warnings)} donation "
          f"warnings")

    # HBM-traffic model (bytes/param): naive = read g,u,p + write u,p each
    # pass of {decay, scale+momentum, apply} vs fused single pass
    naive = (3 + 2) * 4 * 2.2   # measured XLA lowering ~2.2 passes equivalent
    fused = (3 + 2) * 4
    rows.append(csv_row("sngm_hbm_bytes_per_param_naive", naive, "model"))
    rows.append(csv_row("sngm_hbm_bytes_per_param_fused_kernel", fused,
                        "pallas multi_tensor/fused_sngm"))
    print(f"  fused-kernel HBM model: {naive:.0f} -> {fused:.0f} bytes/param")

    out = {"rows": rows, "n_params": n_params, "n_leaves": n_leaves,
           "launches_per_step": {"per_leaf": l_pl, "multi_tensor": l_mt,
                                 "lamb_fused": l_lamb,
                                 "clip_sngm": l_clip,
                                 "nesterov_sngm": l_nest,
                                 "sngm_clip_mid": l_cm,
                                 "sngm_ema": l_ema},
           "us_per_step": {"per_leaf": us_pl, "multi_tensor": us_mt,
                           "lamb_fused": us_lamb, "clip_sngm": us_clip,
                           "nesterov_sngm": us_nest,
                           "sngm_clip_mid": us_cm, "sngm_ema": us_ema},
           "packed_bytes_per_step": {"resident": int(b_res),
                                     "per_step": int(b_per),
                                     "ratio": b_res / b_per,
                                     "lamb_resident": int(b_lamb),
                                     "clip_sngm_resident": int(b_clip),
                                     "nesterov_resident": int(b_nest),
                                     "sngm_clip_mid_resident": int(b_cm),
                                     "sngm_ema_resident": int(b_ema)},
           "param_bytes_live": {"resident": int(pb_live),
                                "raw_params": int(param_bytes),
                                "legacy_two_copies": int(pb_legacy)},
           "donation_warnings": donation_warnings}
    if json_path:
        import json
        import os

        # canonical schema-versioned envelope — the exact format
        # check_bench.py validates and the committed BENCH_overhead.json
        # baseline stores
        envelope = make_envelope("overhead", out, quick=quick)
        assert not validate_envelope(envelope)
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(envelope, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"  wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small tree + few iters (CI smoke lane)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write results JSON to this path")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
