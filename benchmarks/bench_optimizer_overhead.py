"""Optimizer-update micro-benchmark: us/call for each optimizer's update
on a transformer-sized parameter tree, plus the HBM-traffic model for the
fused Pallas SNGM kernel vs the unfused XLA lowering (the kernel's win is
bandwidth, which CPU wall-time cannot show — we report both)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import lars, lamb, msgd, sngd, sngm
from repro.core.schedules import constant

SHAPES = [(1024, 1024)] * 8 + [(4096, 1024)] * 4 + [(1024,)] * 16


def make_tree(seed, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {f"p{i}": scale * jax.random.normal(jax.random.fold_in(k, i), s)
            for i, s in enumerate(SHAPES)}


def time_call(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    params = make_tree(0)
    grads = make_tree(1, 3.0)
    n_params = sum(int(np.prod(s)) for s in SHAPES)
    rows = []
    for name, opt in [("sngm", sngm(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("sngm_per_tensor", sngm(constant(0.1), beta=0.9,
                                               norm_mode="per_tensor")),
                      ("sngd", sngd(constant(0.1))),
                      ("msgd", msgd(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("lars", lars(constant(0.1), beta=0.9, weight_decay=1e-4)),
                      ("lamb", lamb(constant(0.1), weight_decay=1e-4))]:
        state = opt.init(params)
        step = jax.jit(opt.step)
        us = time_call(step, grads, state, params)
        rows.append(csv_row(f"opt_update_{name}", us,
                            f"params={n_params}"))
        print(f"  {rows[-1]}")

    # HBM-traffic model (bytes/param): naive = read g,u,p + write u,p each
    # pass of {decay, scale+momentum, apply} vs fused single pass
    naive = (3 + 2) * 4 * 2.2   # measured XLA lowering ~2.2 passes equivalent
    fused = (3 + 2) * 4
    rows.append(csv_row("sngm_hbm_bytes_per_param_naive", naive, "model"))
    rows.append(csv_row("sngm_hbm_bytes_per_param_fused_kernel", fused,
                        "pallas fused_sngm"))
    print(f"  fused-kernel HBM model: {naive:.0f} -> {fused:.0f} bytes/param")
    return {"rows": rows}


if __name__ == "__main__":
    run()
